from repro.data.pipeline import DataConfig, SyntheticPipeline
