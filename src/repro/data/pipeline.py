"""Deterministic, stateless data pipeline.

batch_at(step) is a pure function of (seed, step) — no iterator state — so
a restart from checkpoint step K replays exactly the batches K, K+1, ...
(the exact-resume property the fault-tolerant loop relies on; DESIGN.md §5).
Synthetic corpus: a Zipf-ish token stream with document structure (repeated
canonical chunks) so serving examples exercise real cross-request reuse.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    family: str = "dense"         # vlm/audio add stub modality inputs
    d_model: int = 0
    vlm_patches: int = 0
    enc_seq: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        kt, kc, kp, kf = jax.random.split(key, 4)
        # Zipf-ish marginal (squared uniform) + copy structure: with prob
        # 1/2 a token repeats its predecessor — a learnable bigram signal
        # (training-loss sanity checks depend on learnability)
        u = jax.random.uniform(kt, (c.global_batch, c.seq_len + 1))
        fresh = (jnp.square(u) * (c.vocab - 1)).astype(jnp.int32)
        copy = jax.random.bernoulli(kc, 0.5, fresh.shape)

        def chain(prev, inp):
            f, cp = inp
            tok = jnp.where(cp, prev, f)
            return tok, tok

        _, toks = jax.lax.scan(
            chain, fresh[:, 0],
            (fresh.T, copy.T))
        tokens = toks.T
        batch = {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}
        if c.family == "vlm":
            batch["patch_embeds"] = 0.02 * jax.random.normal(
                kp, (c.global_batch, c.vlm_patches, c.d_model), jnp.bfloat16)
        if c.family == "audio":
            batch["frame_embeds"] = 0.02 * jax.random.normal(
                kf, (c.global_batch, c.enc_seq, c.d_model), jnp.bfloat16)
        return batch

    @staticmethod
    def for_model(mcfg, seq_len: int, global_batch: int, seed: int = 0):
        return SyntheticPipeline(DataConfig(
            vocab=mcfg.vocab,
            seq_len=seq_len if mcfg.family != "vlm"
            else seq_len - mcfg.vlm_patches,
            global_batch=global_batch, seed=seed, family=mcfg.family,
            d_model=mcfg.d_model, vlm_patches=mcfg.vlm_patches,
            enc_seq=mcfg.enc_seq))


def canonical_corpus(n_chunks: int, chunk_tokens: int, vocab: int,
                     seed: int = 1) -> np.ndarray:
    """Provider-curated canonical chunks (§1): (n_chunks, chunk_tokens)
    immutable token blocks, shared across tenants."""
    rng = np.random.RandomState(seed)
    return rng.randint(0, vocab, (n_chunks, chunk_tokens)).astype(np.int32)
