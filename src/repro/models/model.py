"""Composable model assembly: one ModelConfig drives all 10 assigned
architectures (dense GQA/MHA, MLA, MoE, SSM, hybrid, enc-dec, VLM).

Layer stacks are scan-over-layers (stacked params, lax.scan) so 60-96-layer
configs lower to compact HLO; remat is applied at block boundaries.

Step functions (consumed by launch/dryrun.py and the train loop):
  * forward / loss_fn      — training forward + chunked-CE loss
  * prefill                — forward returning the KV/latent caches
  * init_decode_state      — cache pytree (abstract or concrete)
  * decode_step            — one token against a seq_len cache (serve_step)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.distributed import policy as POL
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.module import KeyGen, Param, init_stacked, param, split


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    vocab: int
    # attention (gqa family)
    attn_type: str = "gqa"           # gqa | mla | none
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # mlp
    d_ff: int = 0
    mlp_kind: str = "swiglu"
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    # MLA
    mla: Optional[MLA.MLAConfig] = None
    # MoE
    moe: Optional[MOE.MoEConfig] = None
    first_k_dense: int = 0
    # SSM / hybrid
    ssm: Optional[SSM.Mamba2Config] = None
    hybrid_group: int = 0            # zamba2: shared attn after every group
    # enc-dec (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500
    # vlm (llava)
    vlm_patches: int = 0
    # selection (paper technique: DSA-style top-k decode for long context)
    selection_k: int = 0
    # loss
    loss_chunk: int = 512
    remat: bool = True

    @property
    def attn_cfg(self) -> A.AttnConfig:
        return A.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.head_dim, self.qkv_bias, self.qk_norm,
                            self.rope_theta,
                            use_rope=not self.encdec)

    @property
    def kv_bytes_token_layer(self) -> int:
        """FETCH-side payload coefficient for the predicate (§5.4)."""
        if self.attn_type == "mla":
            return self.mla.d_qk * 2
        if self.attn_type == "none":
            return 0
        return self.attn_cfg.kv_bytes_token_layer

    def norm_init(self):
        return (L.init_rmsnorm if self.norm_kind == "rmsnorm"
                else L.init_layernorm)

    def norm_apply(self):
        return L.rmsnorm if self.norm_kind == "rmsnorm" else L.layernorm


# ---------------------------------------------------------------------------
# MoE execution: under a mesh policy, run the expert layer inside shard_map
# (DESIGN.md §5): activations replicated over the expert (`model`) axis
# within a data shard, each shard computes its resident experts, one psum
# combines. Plain-GSPMD lowering of the sort-based dispatch replicates the
# (T*k, d) dispatch buffers and all-reduces them — measured 18.9 TB/device
# per step on qwen3-moe train_4k (EXPERIMENTS.md §Perf A2).
# ---------------------------------------------------------------------------

def _moe_call(p_moe, cfg: ModelConfig, x, ep_axis=None):
    from jax.sharding import PartitionSpec as P
    pol = POL.current()
    if pol is None or "model" not in pol.mesh.axis_names:
        y, aux = MOE.moe_apply(p_moe, cfg.moe, x, ep_axis)
        return y, aux
    mesh = pol.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    b_entry = (dp if len(dp) > 1 else dp[0]) \
        if dp and x.shape[0] % dp_size == 0 else None
    x_spec = P(b_entry, None, None)
    p_specs = {}
    for k in p_moe:
        if k == "router":
            p_specs[k] = P(None, None)
        elif k in ("gate", "up", "down"):
            p_specs[k] = P("model", None, None)      # expert-sharded stacks
        elif k in ("sh_gate", "sh_up"):
            p_specs[k] = P(None, "model")            # shared FFN width
        else:                                        # sh_down
            p_specs[k] = P("model", None)

    def f(pm, xx):
        y, aux = MOE.moe_apply(pm, cfg.moe, xx, ep_axis="model")
        axes = dp + (() if True else ())
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    y, aux = compat.shard_map(
        f, mesh=mesh, in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()))(p_moe, x)
    return y, aux


# ---------------------------------------------------------------------------
# Blocks (one layer each). Each block has: init(kg) -> params,
# fwd(p, x, pos) -> (x', cache_entries), dec(p, x, cache, pos, widx)
# -> (x', new_cache).
# ---------------------------------------------------------------------------

def _init_dense_block(kg, cfg: ModelConfig, moe_block: bool):
    ni = cfg.norm_init()
    p = {"ln1": ni(cfg.d_model), "ln2": ni(cfg.d_model)}
    if cfg.attn_type == "mla":
        p["attn"] = MLA.init_mla(kg, cfg.mla)
    else:
        p["attn"] = A.init_attn(kg, cfg.attn_cfg)
    if moe_block:
        p["moe"] = MOE.init_moe(kg, cfg.moe)
    else:
        p["mlp"] = L.init_mlp(kg, cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    return p


def _dense_block_fwd(p, cfg: ModelConfig, x, positions, moe_block: bool,
                     ep_axis=None):
    na = cfg.norm_apply()
    h = na(p["ln1"], x)
    if cfg.attn_type == "mla":
        attn_out, cache = MLA.mla_attention(p["attn"], cfg.mla, h, positions)
    else:
        attn_out, cache = A.attention(p["attn"], cfg.attn_cfg, h, positions)
    x = x + attn_out
    h = na(p["ln2"], x)
    if moe_block:
        mo, aux = _moe_call(p["moe"], cfg, h, ep_axis)
        return x + mo, cache, aux
    return x + L.mlp(p["mlp"], h, cfg.mlp_kind), cache, jnp.float32(0)


def _dense_block_dec(p, cfg: ModelConfig, x, cache, positions, widx,
                     moe_block: bool, ep_axis=None):
    na = cfg.norm_apply()
    h = na(p["ln1"], x)
    if cfg.attn_type == "mla":
        attn_out, new_cache = _mla_decode_cached(p["attn"], cfg, h, cache,
                                                 positions, widx)
    else:
        attn_out, new_cache = _gqa_decode_cached(p["attn"], cfg.attn_cfg, h,
                                                 cache, positions, widx)
    x = x + attn_out
    h = na(p["ln2"], x)
    if moe_block:
        mo, _ = _moe_call(p["moe"], cfg, h, ep_axis)
        return x + mo, new_cache
    return x + L.mlp(p["mlp"], h, cfg.mlp_kind), new_cache


def _gqa_decode_cached(p, acfg: A.AttnConfig, x, cache, positions, widx):
    """Write the new entry into the cache, then attend over the full cache."""
    k_cache, v_cache = cache
    q, k_new, v_new = A._project(p, acfg, x, x, positions, positions)
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k_new, widx, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v_new, widx, axis=1)
    out = A._sdpa(acfg, q, k_cache, v_cache, None)
    out = jnp.einsum("bshd,hdm->bsm", out, p["o"])
    return out, (k_cache, v_cache)


def _mla_decode_cached(p, cfg: ModelConfig, x, ckv_cache, positions, widx):
    """Absorbed MLA decode over the latent cache. With selection_k > 0,
    attends only the indexer's top-k entries (DSA regime, §5.4) — the
    sub-quadratic path that long_500k requires."""
    mcfg = cfg.mla
    q_nope, q_rope = MLA.project_q(p, mcfg, x, positions)
    q_abs = MLA.absorb_query(p, mcfg, q_nope, q_rope)     # (B,1,H,576)
    new_entry = MLA.latent_cache_entries(p, mcfg, x, positions)
    ckv_cache = lax.dynamic_update_slice_in_dim(ckv_cache, new_entry, widx,
                                                axis=1)
    if cfg.selection_k:
        # lightweight indexer: score = mean-head absorbed q . c^KV (latent
        # part); top-k tokens attended in place (no re-rotation — §3.3).
        qi = jnp.mean(q_abs[..., : mcfg.kv_lora_rank], axis=2)    # (B,1,dc)
        scores = jnp.einsum("bqc,bsc->bqs", qi,
                            ckv_cache[..., : mcfg.kv_lora_rank])
        _, sel = lax.top_k(scores[:, 0], cfg.selection_k)          # (B,k)
        sel_ckv = jnp.take_along_axis(ckv_cache, sel[..., None], axis=1)
        part = jax.vmap(lambda qb, cb: MLA.absorbed_partial(mcfg, qb, cb))(
            q_abs, sel_ckv)
    else:
        part = jax.vmap(lambda qb, cb: MLA.absorbed_partial(mcfg, qb, cb))(
            q_abs, ckv_cache)
    out = MLA.unabsorb_output(p, mcfg, part.o[..., : mcfg.kv_lora_rank]
                              .astype(x.dtype))
    return out, ckv_cache


# ---------------------------------------------------------------------------
# Stage runners: scan over stacked layer params.
# ---------------------------------------------------------------------------

def _scan_fwd(stacked, x, positions, block_fwd, remat=True, with_cache=True):
    f = jax.checkpoint(block_fwd) if remat else block_fwd

    def body(carry, lp):
        x = carry
        # sequence-parallel residual constraint (policy-controlled; no-op
        # without an installed policy)
        x = POL.constrain(x, "residual")
        x, cache, aux = f(lp, x)
        return x, (cache if with_cache else None, aux)

    x, (caches, auxs) = lax.scan(body, x, stacked)
    return x, caches, jnp.sum(auxs)


def _scan_dec(stacked, caches, x, block_dec):
    def body(carry, inp):
        x = carry
        lp, lc = inp
        x, nc = block_dec(lp, x, lc)
        return x, nc

    x, new_caches = lax.scan(body, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_model(cfg: ModelConfig, key) -> dict:
    kg = KeyGen(key)
    p: Dict[str, Any] = {"embed": L.init_embed(kg, cfg.vocab, cfg.d_model),
                         "final_norm": cfg.norm_init()(cfg.d_model)}

    if cfg.family in ("dense", "vlm"):
        p["blocks"] = init_stacked(kg(), cfg.n_layers,
                                   lambda k: _init_dense_block(k, cfg, False))
    elif cfg.family == "moe":
        if cfg.first_k_dense:
            p["dense_blocks"] = init_stacked(
                kg(), cfg.first_k_dense,
                lambda k: _init_dense_block(k, cfg, False))
        p["blocks"] = init_stacked(
            kg(), cfg.n_layers - cfg.first_k_dense,
            lambda k: _init_dense_block(k, cfg, True))
    elif cfg.family == "ssm":
        p["blocks"] = init_stacked(
            kg(), cfg.n_layers,
            lambda k: {"ln": cfg.norm_init()(cfg.d_model),
                       "mamba": SSM.init_mamba2(k, cfg.ssm)})
    elif cfg.family == "hybrid":
        g = cfg.hybrid_group
        n_groups, rem = cfg.n_layers // g, cfg.n_layers % g
        p["groups"] = init_stacked(
            kg(), n_groups,
            lambda k: init_stacked(k(), g,
                                   lambda k2: {"ln": cfg.norm_init()(cfg.d_model),
                                               "mamba": SSM.init_mamba2(k2, cfg.ssm)}))
        if rem:
            p["rem"] = init_stacked(
                kg(), rem,
                lambda k: {"ln": cfg.norm_init()(cfg.d_model),
                           "mamba": SSM.init_mamba2(k, cfg.ssm)})
        # the SHARED attention block (one set of weights, reused per group —
        # Zamba2's shared transformer block, simplified: no per-invocation
        # LoRA, DESIGN.md §4)
        p["shared_attn"] = {"ln": cfg.norm_init()(cfg.d_model),
                            "attn": A.init_attn(kg, cfg.attn_cfg),
                            "ln2": cfg.norm_init()(cfg.d_model),
                            "mlp": L.init_mlp(kg, cfg.d_model, cfg.d_ff,
                                              cfg.mlp_kind)}
    elif cfg.family == "audio":
        enc_cfg = dataclasses.replace(cfg.attn_cfg, causal=False)
        p["enc_blocks"] = init_stacked(
            kg(), cfg.n_enc_layers,
            lambda k: {"ln1": cfg.norm_init()(cfg.d_model),
                       "attn": A.init_attn(k, enc_cfg),
                       "ln2": cfg.norm_init()(cfg.d_model),
                       "mlp": L.init_mlp(k, cfg.d_model, cfg.d_ff,
                                         cfg.mlp_kind)})
        p["enc_norm"] = cfg.norm_init()(cfg.d_model)
        p["blocks"] = init_stacked(
            kg(), cfg.n_layers,
            lambda k: {"ln1": cfg.norm_init()(cfg.d_model),
                       "attn": A.init_attn(k, cfg.attn_cfg),
                       "lnx": cfg.norm_init()(cfg.d_model),
                       "xattn": A.init_attn(k, cfg.attn_cfg),
                       "ln2": cfg.norm_init()(cfg.d_model),
                       "mlp": L.init_mlp(k, cfg.d_model, cfg.d_ff,
                                         cfg.mlp_kind)})
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch):
    """tokens (+ stub modality embeddings) -> x (B, S, D), positions."""
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    if cfg.family == "vlm":
        # anyres frontend stub: precomputed patch embeddings prepended
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def forward(params, cfg: ModelConfig, batch, ep_axis=None,
            return_caches=False):
    """Training/prefill forward -> (logits, caches, aux_loss)."""
    if cfg.family == "audio":
        return _forward_audio(params, cfg, batch, return_caches)
    x, positions = _embed_inputs(params, cfg, batch)

    aux_total = jnp.float32(0)
    caches = {}
    if cfg.family in ("dense", "vlm"):
        fwd = lambda lp, h: _dense_block_fwd(lp, cfg, h, positions, False)
        x, c, _ = _scan_fwd(params["blocks"], x, positions, fwd, cfg.remat,
                            return_caches)
        caches["blocks"] = c
    elif cfg.family == "moe":
        if cfg.first_k_dense:
            fwd_d = lambda lp, h: _dense_block_fwd(lp, cfg, h, positions, False)
            x, c, _ = _scan_fwd(params["dense_blocks"], x, positions, fwd_d,
                                cfg.remat, return_caches)
            caches["dense_blocks"] = c
        fwd_m = lambda lp, h: _dense_block_fwd(lp, cfg, h, positions, True,
                                               ep_axis)
        x, c, aux = _scan_fwd(params["blocks"], x, positions, fwd_m,
                              cfg.remat, return_caches)
        caches["blocks"] = c
        aux_total = aux_total + aux
    elif cfg.family == "ssm":
        def fwd_s(lp, h):
            y, (hf, cs) = SSM.mamba2_forward(lp["mamba"], cfg.ssm,
                                             cfg.norm_apply()(lp["ln"], h))
            return h + y, (hf, cs), jnp.float32(0)
        x, c, _ = _scan_fwd(params["blocks"], x, positions, fwd_s, cfg.remat,
                            return_caches)
        caches["blocks"] = c
    elif cfg.family == "hybrid":
        x, caches = _forward_hybrid(params, cfg, x, positions, return_caches)
    logits = L.unembed(params["embed"],
                       cfg.norm_apply()(params["final_norm"], x))
    if cfg.family == "vlm":
        logits = logits[:, cfg.vlm_patches:]     # loss over text positions
    return logits, (caches if return_caches else None), aux_total


def _forward_hybrid(params, cfg: ModelConfig, x, positions, return_caches):
    na = cfg.norm_apply()

    def mamba_layer(lp, h):
        y, (hf, cs) = SSM.mamba2_forward(lp["mamba"], cfg.ssm, na(lp["ln"], h))
        return h + y, (hf, cs), jnp.float32(0)

    def group(gp, h):
        h, states, _ = _scan_fwd(gp, h, positions, mamba_layer, cfg.remat,
                                 return_caches)
        sa = params["shared_attn"]
        attn_out, kv = A.attention(sa["attn"], cfg.attn_cfg, na(sa["ln"], h),
                                   positions)
        h = h + attn_out
        h = h + L.mlp(sa["mlp"], na(sa["ln2"], h), cfg.mlp_kind)
        return h, (states, kv), jnp.float32(0)

    x, caches, _ = _scan_fwd(params["groups"], x, positions, group,
                             remat=False, with_cache=return_caches)
    rem_caches = None
    if "rem" in params:
        x, rem_caches, _ = _scan_fwd(params["rem"], x, positions, mamba_layer,
                                     cfg.remat, return_caches)
    return x, {"groups": caches, "rem": rem_caches}


def _forward_audio(params, cfg: ModelConfig, batch, return_caches):
    """Whisper-style enc-dec. batch: frame_embeds (B, S_enc, D) [conv
    frontend stub], tokens (B, S_dec)."""
    na = cfg.norm_apply()
    enc_cfg = dataclasses.replace(cfg.attn_cfg, causal=False)
    xe = batch["frame_embeds"]
    B, Se = xe.shape[:2]
    pos_e = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))

    def enc_block(lp, h):
        ao, _ = A.attention(lp["attn"], enc_cfg, na(lp["ln1"], h), pos_e)
        h = h + ao
        return h + L.mlp(lp["mlp"], na(lp["ln2"], h), cfg.mlp_kind), None, \
            jnp.float32(0)

    xe, _, _ = _scan_fwd(params["enc_blocks"], xe, pos_e, enc_block,
                         cfg.remat, with_cache=False)
    xe = na(params["enc_norm"], xe)

    xd = L.embed(params["embed"], batch["tokens"])
    Sd = xd.shape[1]
    pos_d = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32)[None], (B, Sd))

    def dec_block(lp, h):
        ao, self_kv = A.attention(lp["attn"], cfg.attn_cfg, na(lp["ln1"], h),
                                  pos_d)
        h = h + ao
        xo, cross_kv = A.attention(lp["xattn"], enc_cfg, na(lp["lnx"], h),
                                   pos_d, x_kv=xe, kv_positions=pos_e)
        h = h + xo
        return h + L.mlp(lp["mlp"], na(lp["ln2"], h), cfg.mlp_kind), \
            (self_kv, cross_kv), jnp.float32(0)

    xd, caches, _ = _scan_fwd(params["blocks"], xd, pos_d, dec_block,
                              cfg.remat, return_caches)
    logits = L.unembed(params["embed"], na(params["final_norm"], xd))
    return logits, ({"blocks": caches} if return_caches else None), \
        jnp.float32(0)


# ---------------------------------------------------------------------------
# Loss (chunked CE to bound the f32 logit footprint)
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch, ep_axis=None):
    logits, _, aux = forward(params, cfg, batch, ep_axis)
    targets = batch["targets"]
    if cfg.family == "vlm":
        pass                                  # logits already text-only
    B, S, V = logits.shape
    # largest chunk <= loss_chunk that divides S (VLM text spans etc.)
    n_chunks = max(1, S // min(cfg.loss_chunk, S))
    while S % n_chunks:
        n_chunks += 1
    chunk = S // n_chunks

    def ce_chunk(_, i):
        lg = lax.dynamic_slice_in_dim(logits, i * chunk, chunk, axis=1)
        tg = lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        lg = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
        return None, jnp.sum(lse - gold)

    _, losses = lax.scan(ce_chunk, None, jnp.arange(n_chunks))
    loss = jnp.sum(losses) / (B * chunk * n_chunks)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serve_step): one token against a seq_len cache.
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                      abstract: bool = False, dtype=jnp.bfloat16):
    """The cache pytree. abstract=True -> ShapeDtypeStructs (dry-run)."""
    mk = (lambda s, dt=dtype: jax.ShapeDtypeStruct(s, dt)) if abstract \
        else (lambda s, dt=dtype: jnp.zeros(s, dt))
    acfg = cfg.attn_cfg

    def gqa_cache(n_layers, s=seq_len):
        return (mk((n_layers, batch, s, acfg.n_kv_heads, acfg.hd)),
                mk((n_layers, batch, s, acfg.n_kv_heads, acfg.hd)))

    def mla_cache(n_layers):
        return mk((n_layers, batch, seq_len, cfg.mla.d_qk))

    def ssm_state(*lead):
        s = cfg.ssm
        return (mk(lead + (batch, s.n_heads, s.head_dim, s.d_state),
                   jnp.float32),
                mk(lead + (batch, s.d_conv - 1, s.d_inner + 2 * s.d_state)))

    if cfg.family in ("dense", "vlm"):
        n = cfg.n_layers
        return {"blocks": mla_cache(n) if cfg.attn_type == "mla"
                else gqa_cache(n)}
    if cfg.family == "moe":
        st = {}
        if cfg.first_k_dense:
            st["dense_blocks"] = (mla_cache(cfg.first_k_dense)
                                  if cfg.attn_type == "mla"
                                  else gqa_cache(cfg.first_k_dense))
        n = cfg.n_layers - cfg.first_k_dense
        st["blocks"] = mla_cache(n) if cfg.attn_type == "mla" else gqa_cache(n)
        return st
    if cfg.family == "ssm":
        return {"blocks": ssm_state(cfg.n_layers)}
    if cfg.family == "hybrid":
        g = cfg.hybrid_group
        ng, rem = cfg.n_layers // g, cfg.n_layers % g
        st = {"groups": ssm_state(ng, g), "shared_kv": gqa_cache(ng)}
        if rem:
            st["rem"] = ssm_state(rem)
        return st
    if cfg.family == "audio":
        n = cfg.n_layers
        return {"self": gqa_cache(n),
                "cross": gqa_cache(n, s=cfg.enc_seq)}
    raise ValueError(cfg.family)


def decode_step(params, cfg: ModelConfig, state, token, pos, widx,
                ep_axis=None):
    """token (B, 1) -> (logits (B, 1, V), new state). pos (B, 1) absolute
    positions; widx: static-shape cache write index (scalar int32)."""
    x = L.embed(params["embed"], token)
    na = cfg.norm_apply()

    if cfg.family in ("dense", "vlm", "moe"):
        def dec_dense(lp, h, lc):
            return _dense_block_dec(lp, cfg, h, lc, pos, widx, False)

        def dec_moe(lp, h, lc):
            return _dense_block_dec(lp, cfg, h, lc, pos, widx, True, ep_axis)

        new_state = {}
        if cfg.family == "moe" and cfg.first_k_dense:
            x, nc = _scan_dec(params["dense_blocks"], state["dense_blocks"],
                              x, dec_dense)
            new_state["dense_blocks"] = nc
        dec = dec_moe if cfg.family == "moe" else dec_dense
        x, nc = _scan_dec(params["blocks"], state["blocks"], x, dec)
        new_state["blocks"] = nc
    elif cfg.family == "ssm":
        def dec_s(lp, h, lc):
            y, ns = SSM.mamba2_decode(lp["mamba"], cfg.ssm,
                                      na(lp["ln"], h), lc)
            return h + y, ns
        x, nc = _scan_dec(params["blocks"], state["blocks"], x, dec_s)
        new_state = {"blocks": nc}
    elif cfg.family == "hybrid":
        x, new_state = _decode_hybrid(params, cfg, state, x, pos, widx)
    elif cfg.family == "audio":
        x, new_state = _decode_audio(params, cfg, state, x, pos, widx)
    else:
        raise ValueError(cfg.family)

    logits = L.unembed(params["embed"], na(params["final_norm"], x))
    return logits, new_state


def _decode_hybrid(params, cfg, state, x, pos, widx):
    na = cfg.norm_apply()

    def dec_mamba(lp, h, lc):
        y, ns = SSM.mamba2_decode(lp["mamba"], cfg.ssm, na(lp["ln"], h), lc)
        return h + y, ns

    def dec_group(carry, inp):
        h = carry
        gp, gstate, kv = inp
        h, ns = _scan_dec(gp, gstate, h, dec_mamba)
        sa = params["shared_attn"]
        ao, nkv = _gqa_decode_cached(sa["attn"], cfg.attn_cfg,
                                     na(sa["ln"], h), kv, pos, widx)
        h = h + ao
        h = h + L.mlp(sa["mlp"], na(sa["ln2"], h), cfg.mlp_kind)
        return h, (ns, nkv)

    x, (gstates, kvs) = lax.scan(dec_group, x,
                                 (params["groups"], state["groups"],
                                  state["shared_kv"]))
    new_state = {"groups": gstates, "shared_kv": kvs}
    if "rem" in params:
        x, ns = _scan_dec(params["rem"], state["rem"], x, dec_mamba)
        new_state["rem"] = ns
    return x, new_state


def _decode_audio(params, cfg, state, x, pos, widx):
    na = cfg.norm_apply()
    enc_cfg = dataclasses.replace(cfg.attn_cfg, causal=False)

    def dec(carry, inp):
        h = carry
        lp, self_kv, cross_kv = inp
        ao, nkv = _gqa_decode_cached(lp["attn"], cfg.attn_cfg,
                                     na(lp["ln1"], h), self_kv, pos, widx)
        h = h + ao
        ck, cv = cross_kv
        q = jnp.einsum("bsm,mhd->bshd", na(lp["lnx"], h), lp["xattn"]["q"])
        xo = A._sdpa(enc_cfg, q, ck, cv, None)
        h = h + jnp.einsum("bshd,hdm->bsm", xo, lp["xattn"]["o"])
        h = h + L.mlp(lp["mlp"], na(lp["ln2"], h), cfg.mlp_kind)
        return h, nkv

    x, nkvs = lax.scan(dec, x, (params["blocks"], state["self"],
                                state["cross"]))
    return x, {"self": nkvs, "cross": state["cross"]}


# ---------------------------------------------------------------------------
# Prefill: forward + caches, reshaped into decode-state layout.
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, ep_axis=None):
    """Returns (last-token logits, caches). Cache layouts match forward's
    scan outputs: (L, B, S, ...) — the same leading-layer layout
    init_decode_state uses."""
    logits, caches, _ = forward(params, cfg, batch, ep_axis,
                                return_caches=True)
    return logits[:, -1:], caches
