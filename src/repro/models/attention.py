"""Standard attention (MHA/GQA/MQA) with optional QKV bias (Qwen1.5/2.5),
qk-norm (Qwen3), RoPE, and a KV cache for decode.

The KV cache entry here is the FETCH-heavy contrast case of the paper (§2.1):
per token per layer it is 2 * n_kv * head_dim * 2 B — for a kv=8, d=128 GQA
that is 4 KB vs MLA's 1.152 KB, and for MHA (kv=40) 20 KB. The predicate's
payload_for() consumes exactly these numbers per architecture.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.module import KeyGen, param, zeros


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: Optional[int] = None     # explicit (Qwen3) or d_model/n_heads
    qkv_bias: bool = False             # Qwen1.5/2.5
    qk_norm: bool = False              # Qwen3
    rope_theta: float = 10000.0
    causal: bool = True                # False for encoder self-attn
    use_rope: bool = True              # False for Whisper (learned pos emb)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def scale(self) -> float:
        return 1.0 / np.sqrt(self.hd)

    @property
    def kv_bytes_token_layer(self) -> int:
        return 2 * self.n_kv_heads * self.hd * 2    # K+V, bf16


def init_attn(kg: KeyGen, cfg: AttnConfig, dtype=jnp.bfloat16,
              d_kv_src: Optional[int] = None):
    """d_kv_src: source dim for K/V (cross-attention reads encoder states)."""
    dm, hd = cfg.d_model, cfg.hd
    dkv = d_kv_src or dm
    p = {
        "q": param(kg(), (dm, cfg.n_heads, hd), ("embed", "heads", None), dtype),
        "k": param(kg(), (dkv, cfg.n_kv_heads, hd), ("embed", "kv", None), dtype),
        "v": param(kg(), (dkv, cfg.n_kv_heads, hd), ("embed", "kv", None), dtype),
        "o": param(kg(), (cfg.n_heads, hd, dm), ("heads", None, "embed"), dtype),
    }
    if cfg.qkv_bias:
        p["q_b"] = zeros((cfg.n_heads, hd), ("heads", None), dtype)
        p["k_b"] = zeros((cfg.n_kv_heads, hd), ("kv", None), dtype)
        p["v_b"] = zeros((cfg.n_kv_heads, hd), ("kv", None), dtype)
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd, dtype)
        p["k_norm"] = L.init_rmsnorm(hd, dtype)
    return p


def _project(p, cfg: AttnConfig, x, x_kv, positions, kv_positions):
    q = jnp.einsum("bsm,mhd->bshd", x, p["q"])
    k = jnp.einsum("bsm,mhd->bshd", x_kv, p["k"])
    v = jnp.einsum("bsm,mhd->bshd", x_kv, p["v"])
    if "q_b" in p:
        q, k, v = q + p["q_b"], k + p["k_b"], v + p["v_b"]
    if "q_norm" in p:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        qc, qs = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
        q = L.apply_rope(q, qc[:, :, None], qs[:, :, None])
        kc, ks = L.rope_cos_sin(kv_positions, cfg.hd, cfg.rope_theta)
        k = L.apply_rope(k, kc[:, :, None], ks[:, :, None])
    return q, k, v


def _sdpa(cfg: AttnConfig, q, k, v, mask):
    """q (B,Sq,H,d), k/v (B,Sk,Hkv,d). GQA: repeat kv heads by group."""
    groups = cfg.n_heads // cfg.n_kv_heads
    B, Sq, H, d = q.shape
    qg = q.reshape(B, Sq, cfg.n_kv_heads, groups, d)
    # mixed-precision dots (bf16 K/V operands, f32 accumulate): explicit
    # f32 upcasts make XLA materialize f32 copies of the whole KV cache
    # around the layer scan (EXPERIMENTS.md §Perf P2)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * cfg.scale
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, d).astype(q.dtype)


def attention(p, cfg: AttnConfig, x, positions, x_kv=None, kv_positions=None,
              mask=None):
    """Full-sequence form (train / prefill / encoder / cross-attn).

    Returns (out, (k, v)) — the cache entries, so prefill fills the KV store
    in the same pass."""
    x_kv = x if x_kv is None else x_kv
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project(p, cfg, x, x_kv, positions, kv_positions)
    Sq, Sk = q.shape[1], k.shape[1]
    if cfg.causal:
        causal = jnp.tril(jnp.ones((Sq, Sk), bool), Sk - Sq)[None]
        mask = causal if mask is None else (mask & causal)
    out = _sdpa(cfg, q, k, v, mask)
    out = jnp.einsum("bshd,hdm->bsm", out, p["o"])
    return out, (k, v)


def decode_step(p, cfg: AttnConfig, x, kv_cache, positions, cache_len=None):
    """One-token decode against a (B, S, Hkv, d) K/V cache.

    kv_cache: (k, v); cache_len: valid prefix length (static cache shape).
    Returns (out (B,1,D), new (k,v) entry (B,1,Hkv,d))."""
    k_cache, v_cache = kv_cache
    q, k_new, v_new = _project(p, cfg, x, x, positions, positions)
    k = jnp.concatenate([k_cache, k_new], axis=1)
    v = jnp.concatenate([v_cache, v_new], axis=1)
    S = k.shape[1]
    if cache_len is not None:
        valid = (jnp.arange(S)[None] < cache_len[:, None]) | \
                (jnp.arange(S)[None] == S - 1)      # (B, S)
        mask = valid[:, None, :]                    # (B, Sq=1, Sk=S)
    else:
        mask = None
    out = _sdpa(cfg, q, k, v, mask)
    out = jnp.einsum("bshd,hdm->bsm", out, p["o"])
    return out, (k_new, v_new)
