"""Shared layers: norms, MLPs (SwiGLU / squared-ReLU / GELU), embeddings, RoPE.

Logical axis names used on params (mapped to mesh axes by
repro.distributed.sharding):
    "embed"  : d_model            -> fsdp ("data") shard
    "mlp"    : d_ff               -> "model" shard
    "heads"  : flattened head dim -> "model" shard
    "kv"     : flattened kv dim   -> "model" if divisible else replicated
    "vocab"  : vocabulary         -> "model" shard
    "expert" : MoE expert dim     -> "model" shard (expert parallelism)
    "layer"  : scan-stacked layer dim -> never sharded
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import KeyGen, Param, ones, param, zeros


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.bfloat16):
    return {"scale": ones((d,), ("embed",), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.bfloat16):
    return {"scale": ones((d,), ("embed",), dtype),
            "bias": zeros((d,), ("embed",), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def init_dense(kg: KeyGen, d_in: int, d_out: int, axes, bias: bool = False,
               dtype=jnp.bfloat16):
    p = {"w": param(kg(), (d_in, d_out), axes, dtype)}
    if bias:
        p["b"] = zeros((d_out,), (axes[1],), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_mlp(kg: KeyGen, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.bfloat16):
    """kind: swiglu (gate+up+down) | squared_relu (up+down) | gelu (up+down).

    `kind` is static config — pass the same value to mlp(); it is not stored
    in the param tree (param trees hold arrays only)."""
    p = {}
    if kind == "swiglu":
        p["gate"] = init_dense(kg, d_model, d_ff, ("embed", "mlp"), dtype=dtype)
        p["up"] = init_dense(kg, d_model, d_ff, ("embed", "mlp"), dtype=dtype)
    else:
        p["up"] = init_dense(kg, d_model, d_ff, ("embed", "mlp"),
                             bias=(kind == "gelu"), dtype=dtype)
    p["down"] = init_dense(kg, d_ff, d_model, ("mlp", "embed"),
                           bias=(kind == "gelu"), dtype=dtype)
    return p


def mlp(p, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(dense(p["up"], x)))
    elif kind == "gelu":
        h = jax.nn.gelu(dense(p["up"], x), approximate=True)
    else:
        raise ValueError(kind)
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embed(kg: KeyGen, vocab: int, d_model: int, dtype=jnp.bfloat16):
    return {"table": param(kg(), (vocab, d_model), ("vocab", "embed"), dtype,
                           scale=1.0)}


def embed(p, tokens):
    # apply fns receive plain value trees (post module.split()).
    return p["table"][tokens]


def unembed(p, x):
    # tied head: the table is unit-scale for the input lookup, so the head
    # side is scaled 1/sqrt(d) to keep initial logits O(1) (initial CE ~
    # ln V instead of ~sqrt(d) x ln V)
    d = x.shape[-1]
    return (x @ p["table"].T) * (1.0 / np.sqrt(d))


# ---------------------------------------------------------------------------
# RoPE (NeoX half-split pairing). rope(p + delta) = R(delta) . rope(p)
# per frequency pair — the composition property the FETCH delta-rotation
# splice (paper §2.2) relies on.
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float = 10000.0):
    """positions (...,) -> cos/sin (..., head_dim/2) in f32."""
    freqs = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., head_dim); cos/sin broadcastable (..., head_dim/2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def delta_rotate(x: jax.Array, delta: jax.Array | int, head_dim: int,
                 theta: float = 10000.0) -> jax.Array:
    """Re-home a RoPE-encoded band from cached position p to p + delta.

    This is the FETCH splice's per-layer hot-spot (paper §2.2): a purely
    positional rotation, independent of the token's original position —
    which is what makes the splice flat in chunk size.
    """
    cos, sin = rope_cos_sin(jnp.asarray(delta), head_dim, theta)
    return apply_rope(x, cos, sin)
