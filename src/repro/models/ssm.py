"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Train/prefill form: the chunked SSD algorithm — intra-chunk quadratic
("attention-like") term + inter-chunk recurrence over per-chunk states;
O(S * Q) compute for chunk size Q, sub-quadratic in S (this is why the
SSM/hybrid archs run the long_500k shape the full-attention archs skip).

Decode form: the O(1) recurrence  h_t = a_t h_{t-1} + dt_t * B_t x_t^T,
y_t = C_t h_t — the "cache" is a fixed-size state (H, hd, N), which is why
the paper's per-chunk redistribution question is inapplicable to pure SSMs
(DESIGN.md §4): there is nothing chunk-shaped to route to; state handoff is
a one-shot fixed-size FETCH.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers as L
from repro.models.module import KeyGen, Param, param, zeros


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    d_conv: int = 4
    chunk: int = 64             # SSD chunk length Q

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(kg: KeyGen, cfg: Mamba2Config, dtype=jnp.bfloat16):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    # in_proj emits [z (di) | x (di) | B (n) | C (n) | dt (h)]
    d_in_proj = 2 * di + 2 * n + h
    p = {
        "in_proj": param(kg(), (cfg.d_model, d_in_proj), ("embed", "mlp"), dtype),
        "conv_w": param(kg(), (cfg.d_conv, di + 2 * n), (None, "mlp"), dtype,
                        scale=0.5),
        "conv_b": zeros((di + 2 * n,), ("mlp",), dtype),
        "a_log": Param(jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
                       ("heads",)),
        "dt_bias": zeros((h,), ("heads",), jnp.float32),
        "d_skip": Param(jnp.ones((h,), jnp.float32), ("heads",)),
        "norm": L.init_rmsnorm(di, dtype),
        "out_proj": param(kg(), (di, cfg.d_model), ("mlp", "embed"), dtype),
    }
    return p


def _split_proj(cfg: Mamba2Config, zxbcdt):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(p, xbc, conv_state=None):
    """Depthwise causal conv over the sequence axis. xbc (B, S, C).
    conv_state (B, d_conv-1, C) carries the left context for decode."""
    w = p["conv_w"].astype(jnp.float32)               # (K, C)
    K = w.shape[0]
    x = xbc.astype(jnp.float32)
    if conv_state is None:
        pad = jnp.zeros_like(x[:, : K - 1])
    else:
        pad = conv_state.astype(jnp.float32)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, C)
    out = sum(w[i] * xp[:, i: i + x.shape[1]] for i in range(K))
    out = jax.nn.silu(out + p["conv_b"].astype(jnp.float32))
    new_state = xp[:, -(K - 1):]
    return out.astype(xbc.dtype), new_state.astype(xbc.dtype)


def ssd_chunked(cfg: Mamba2Config, x, dt, A, B, C, h0=None,
                use_kernel: bool = False):
    """Chunked SSD scan.

    x (b, s, h, p); dt (b, s, h) (post-softplus); A (h) negative decay;
    B, C (b, s, n). Returns (y (b, s, h, p), h_final (b, h, p, n)).

    use_kernel=True routes the intra-chunk quadratic term through the
    fused Pallas kernel (kernels/ssd_chunk) — no (Q,Q,h) HBM
    intermediates; the inter-chunk recurrence stays a lax.scan
    (EXPERIMENTS.md §Perf M1).
    """
    b, s, h, pdim = x.shape
    n = B.shape[-1]
    Q = cfg.chunk
    assert s % Q == 0, (s, Q)
    nc = s // Q
    # reshape to chunks
    xc = x.reshape(b, nc, Q, h, pdim)
    dtc = dt.reshape(b, nc, Q, h)
    Bc = B.reshape(b, nc, Q, n)
    Cc = C.reshape(b, nc, Q, n)

    if use_kernel:
        from repro.kernels.ssd_chunk import ssd_intra_chunk
        hb = min(8, h)
        while h % hb:
            hb -= 1
        y_intra, states, cum = ssd_intra_chunk(
            xc, dtc.astype(jnp.float32), A.astype(jnp.float32),
            Bc, Cc, hb=hb)
        seg_sum = cum[:, :, -1]
    else:
        da = dtc * A[None, None, None]                 # log-decay per step
        cum = jnp.cumsum(da, axis=2)                   # (b, nc, Q, h)
        seg_sum = cum[:, :, -1]                        # total chunk decay

        # --- intra-chunk (quadratic within Q): y_intra[t] =
        #     sum_{u<=t} C_t.B_u exp(cum_t - cum_u) dt_u x_u
        # mask the exponent BEFORE exp: the t<u entries have positive
        # exponents that overflow, and a post-exp where() would leak NaN
        # into the gradient.
        expo = cum[:, :, :, None] - cum[:, :, None]         # (b,nc,Q,Q,h)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        expo = jnp.where(causal[None, None, :, :, None], expo, -jnp.inf)
        Lmat = jnp.exp(expo)
        CB = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
        G = CB[..., None] * Lmat                            # (b,nc,Q,Q,h)
        y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", G,
                             dtc.astype(jnp.float32),
                             xc.astype(jnp.float32))

        # --- per-chunk output state:
        #     S_c = sum_u exp(seg - cum_u) dt_u B_u x_u^T
        decay_out = jnp.exp(seg_sum[:, :, None] - cum)      # (b,nc,Q,h)
        states = jnp.einsum("bckh,bckh,bckn,bckhp->bchpn",
                            decay_out, dtc.astype(jnp.float32),
                            Bc.astype(jnp.float32), xc.astype(jnp.float32))

    # --- inter-chunk recurrence over chunk states
    def step(hprev, inp):
        st, seg = inp                                      # (b,h,p,n), (b,h)
        hnew = hprev * jnp.exp(seg)[:, :, None, None] + st
        return hnew, hprev                                 # emit state BEFORE chunk

    if h0 is None:
        h0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    states_t = jnp.moveaxis(states, 1, 0)                  # (nc, b, h, p, n)
    segs_t = jnp.moveaxis(seg_sum, 1, 0)                   # (nc, b, h)
    h_final, h_prefix = lax.scan(step, h0, (states_t, segs_t))
    h_prefix = jnp.moveaxis(h_prefix, 0, 1)                # (b, nc, h, p, n)

    # --- inter-chunk contribution: y_inter[t] = C_t . (exp(cum_t) h_prefix)
    decay_in = jnp.exp(cum)                                # (b,nc,Q,h)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         Cc.astype(jnp.float32), h_prefix, decay_in)
    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    return y, h_final


def mamba2_forward(p, cfg: Mamba2Config, x, h0=None, conv_state=None):
    """Full-sequence form. x (B, S, D) -> (y (B, S, D), (h_final, conv_state)).
    """
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(p, xbc, conv_state)
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    xs = xbc[..., :di].reshape(*x.shape[:2], h, cfg.head_dim)
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y, h_final = ssd_chunked(cfg, xs, dt, A, B, C, h0)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], (h_final, conv_state)


def mamba2_decode(p, cfg: Mamba2Config, x, state):
    """One-token recurrence. x (B, 1, D); state = (h (B,H,P,N), conv_state).
    Returns (y (B, 1, D), new state). The entire 'cache' is this fixed-size
    state — the SSM arch's answer to the paper's transport question."""
    h_prev, conv_state = state
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(p, xbc, conv_state)
    di, n, hh = cfg.d_inner, cfg.d_state, cfg.n_heads
    xs = xbc[..., :di].reshape(x.shape[0], 1, hh, cfg.head_dim)
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,1,H)
    A = -jnp.exp(p["a_log"])
    a_t = jnp.exp(dt[:, 0] * A[None])                  # (B, H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0].astype(jnp.float32),
                     B[:, 0].astype(jnp.float32),
                     xs[:, 0].astype(jnp.float32))
    h_new = h_prev * a_t[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), h_new)
    y = y + p["d_skip"][None, :, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], (h_new, conv_state)
