"""Minimal module system: params are pytrees of Param(value, logical_axes).

No flax/haiku on this box; this keeps full control of sharding. init_*
functions return trees with Param leaves; split() separates them into a value
tree (fed to apply fns / pjit) and a logical-axes tree (mapped to mesh
PartitionSpecs by repro.distributed.sharding).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
class Param:
    """A parameter leaf: array value + static logical-axes metadata.

    Registered pytree with `axes` as aux data so vmap/scan/eval_shape treat
    the value as the only child (strings never become leaves)."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"


def is_param(x) -> bool:
    return isinstance(x, Param)


def split(tree):
    """(params_with_axes) -> (values, axes)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def param(key, shape, axes, dtype=jnp.bfloat16, scale: Optional[float] = None,
          abstract: bool = False) -> Param:
    """Truncated-normal init with 1/sqrt(fan_in) default scale."""
    assert len(axes) == len(shape), (shape, axes)
    if abstract:
        return Param(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
    if scale is None:
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        scale = 1.0 / np.sqrt(max(1, fan_in))
    v = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return Param(v.astype(dtype), tuple(axes))


def zeros(shape, axes, dtype=jnp.bfloat16, abstract: bool = False) -> Param:
    if abstract:
        return Param(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
    return Param(jnp.zeros(shape, dtype), tuple(axes))


def ones(shape, axes, dtype=jnp.bfloat16, abstract: bool = False) -> Param:
    if abstract:
        return Param(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
    return Param(jnp.ones(shape, dtype), tuple(axes))


class KeyGen:
    """Splits a PRNG key on demand; passes None through in abstract mode."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        if self._key is None:
            return None
        self._key, sub = jax.random.split(self._key)
        return sub


def count_params(values_tree) -> int:
    leaves = jax.tree.leaves(values_tree)
    return int(sum(np.prod(l.shape) for l in leaves))


def init_stacked(key, n: int, init_fn):
    """Stack n independently-initialized copies of init_fn(kg) along a
    leading 'layer' axis (scan-over-layers layout)."""
    keys = jax.random.split(key, n)
    stacked = jax.vmap(lambda k: init_fn(KeyGen(k)))(keys)
    return jax.tree.map(lambda p: Param(p.value, ("layer",) + p.axes),
                        stacked, is_leaf=is_param)


def abstract_init(init_fn):
    """Run an init function without allocating (ShapeDtypeStruct leaves) —
    what the 512-device dry-run feeds to .lower()."""
    return jax.eval_shape(init_fn)
