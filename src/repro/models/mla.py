"""Multi-head Latent Attention (DeepSeek-V2 family) — the paper's home regime.

Two execution forms over one parameterization:

* train/prefill form: decompress c^KV -> per-head K/V, standard attention.
* absorbed decode form: fold W_uk into the query ("absorbed" q, width
  d_qk = kv_lora_rank + rope_dim = 576), attend directly against the latent
  cache, fold W_uv into the output. The absorbed query row IS the routed
  wire object of the paper (§2.1: "a routed query row and a cached token are
  the same d_qk-wide object").

The latent cache entry per token is [c_kv (512) | k_rope (64)]: the k_rope
band is the only position-dependent part — the delta-rotation splice
(core/splice.py, kernels/delta_rotate) re-homes exactly that band.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.merge import Partial, partial_from_logits
from repro.models import layers as L
from repro.models.module import KeyGen, param


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int = 2048
    n_heads: int = 16
    kv_lora_rank: int = 512          # d_c — latent value/nope-key width
    q_lora_rank: Optional[int] = None  # None => direct q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def d_qk(self) -> int:           # absorbed query row width (576 for V2)
        return self.kv_lora_rank + self.qk_rope_head_dim

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    @property
    def scale(self) -> float:
        return 1.0 / np.sqrt(self.qk_head_dim)

    @property
    def cache_width(self) -> int:    # latent cache entry bytes/2 (bf16)
        return self.kv_lora_rank + self.qk_rope_head_dim


def init_mla(kg: KeyGen, cfg: MLAConfig, dtype=jnp.bfloat16):
    h, dm = cfg.n_heads, cfg.d_model
    p = {}
    if cfg.q_lora_rank:
        p["q_down"] = param(kg(), (dm, cfg.q_lora_rank), ("embed", None), dtype)
        p["q_norm"] = L.init_rmsnorm(cfg.q_lora_rank, dtype)
        p["q_up"] = param(kg(), (cfg.q_lora_rank, h, cfg.qk_head_dim),
                          (None, "heads", None), dtype)
    else:
        p["q_proj"] = param(kg(), (dm, h, cfg.qk_head_dim),
                            ("embed", "heads", None), dtype)
    # Latent down-projection: c_kv plus the shared decoupled-rope key band.
    p["kv_down"] = param(kg(), (dm, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                         ("embed", None), dtype)
    p["kv_norm"] = L.init_rmsnorm(cfg.kv_lora_rank, dtype)
    p["k_up"] = param(kg(), (cfg.kv_lora_rank, h, cfg.qk_nope_head_dim),
                      (None, "heads", None), dtype)
    p["v_up"] = param(kg(), (cfg.kv_lora_rank, h, cfg.v_head_dim),
                      (None, "heads", None), dtype)
    p["o_proj"] = param(kg(), (h, cfg.v_head_dim, dm),
                        ("heads", None, "embed"), dtype)
    return p


# ---------------------------------------------------------------------------
# Shared projections
# ---------------------------------------------------------------------------

def project_q(p, cfg: MLAConfig, x, positions):
    """x (B, S, D) -> q_nope (B, S, H, dn), q_rope (B, S, H, dr) (rotated)."""
    if "q_down" in p:
        qc = L.rmsnorm(p["q_norm"], x @ p["q_down"])
        q = jnp.einsum("bsc,chd->bshd", qc, p["q_up"])
    else:
        q = jnp.einsum("bsm,mhd->bshd", x, p["q_proj"])
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = q[..., cfg.qk_nope_head_dim:]
    cos, sin = L.rope_cos_sin(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
    q_rope = L.apply_rope(q_rope, cos[:, :, None, :], sin[:, :, None, :])
    return q_nope, q_rope


def latent_cache_entries(p, cfg: MLAConfig, x, positions):
    """x (B, S, D) -> c^KV entries (B, S, d_qk): [c_kv | rotated k_rope].

    This is the canonical, position-invariant-modulo-rope-band cache object
    the paper's chunk store partitions across instances.
    """
    kv = x @ p["kv_down"]
    c_kv = L.rmsnorm(p["kv_norm"], kv[..., :cfg.kv_lora_rank])
    k_rope = kv[..., cfg.kv_lora_rank:]
    cos, sin = L.rope_cos_sin(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
    k_rope = L.apply_rope(k_rope, cos, sin)
    return jnp.concatenate([c_kv, k_rope], axis=-1)


def absorb_query(p, cfg: MLAConfig, q_nope, q_rope):
    """Fold W_uk into q: (B, S, H, dn) -> absorbed q (B, S, H, d_qk=576).

    The result is the paper's 1152-byte wire row (bf16)."""
    q_abs = jnp.einsum("bshd,chd->bshc", q_nope, p["k_up"])
    return jnp.concatenate([q_abs, q_rope], axis=-1)


def unabsorb_output(p, cfg: MLAConfig, o_latent):
    """Latent partial output (B, S, H, d_c) -> model output (B, S, D):
    fold W_uv then o_proj."""
    o = jnp.einsum("bshc,chd->bshd", o_latent, p["v_up"])
    return jnp.einsum("bshd,hdm->bsm", o, p["o_proj"])


# ---------------------------------------------------------------------------
# Absorbed partial attention — the holder-side compute of ROUTE (§6.3).
# ---------------------------------------------------------------------------

def absorbed_partial(cfg: MLAConfig, q_abs, ckv, mask=None) -> Partial:
    """q_abs (..., H, d_qk) x ckv (S, d_qk) -> Partial over the resident set.

    Pure-jnp oracle; the Pallas kernel (kernels/mla_decode) computes the same.

    Mixed-precision dots (bf16 operands, f32 accumulate via
    preferred_element_type) — an explicit .astype(f32) on ckv makes XLA
    materialize an f32 copy of the WHOLE cache stack around the layer scan
    (measured: 134 GB/step on deepseek decode_32k — EXPERIMENTS.md §Perf
    P2). The MXU natively consumes bf16 with f32 accumulation.
    """
    logits = jnp.einsum("...hc,sc->...hs", q_abs, ckv,
                        preferred_element_type=jnp.float32) * cfg.scale
    values = ckv[:, :cfg.kv_lora_rank]
    if mask is not None:
        if mask.ndim < logits.ndim:   # (S,)-style residency masks
            mask = mask.reshape((1,) * (logits.ndim - mask.ndim) + mask.shape)
        return partial_from_logits(logits, values, mask)
    return partial_from_logits(logits, values)


def absorbed_decode(p, cfg: MLAConfig, x, ckv_cache, positions, *,
                    partial_fn=None):
    """Single decode step in absorbed form.

    x (B, 1, D); ckv_cache (B, S, d_qk); positions (B, 1) absolute position of
    the new token. Returns (out (B, 1, D), new_entry (B, 1, d_qk)).
    partial_fn overrides the attention inner op (e.g. the Pallas kernel)."""
    q_nope, q_rope = project_q(p, cfg, x, positions)
    q_abs = absorb_query(p, cfg, q_nope, q_rope)          # (B, 1, H, 576)
    new_entry = latent_cache_entries(p, cfg, x, positions)  # (B, 1, 576)
    full = jnp.concatenate([ckv_cache, new_entry], axis=1)  # (B, S+1, 576)
    fn = partial_fn or (lambda q, c: jax.vmap(
        lambda qb, cb: absorbed_partial(cfg, qb, cb))(q, c))
    part = fn(q_abs, full)                                 # Partial over cache
    out = unabsorb_output(p, cfg, part.o[..., :cfg.kv_lora_rank].astype(x.dtype))
    return out, new_entry


# ---------------------------------------------------------------------------
# Train / prefill form (decompressed, causal).
# ---------------------------------------------------------------------------

def mla_attention(p, cfg: MLAConfig, x, positions, mask=None):
    """Causal self-attention, train form. x (B, S, D) -> (B, S, D).

    Also returns the latent cache entries so prefill fills the c^KV store in
    the same pass (prefill == train-forward + cache write)."""
    B, S, _ = x.shape
    q_nope, q_rope = project_q(p, cfg, x, positions)
    entries = latent_cache_entries(p, cfg, x, positions)   # (B, S, 576)
    c_kv = entries[..., :cfg.kv_lora_rank]
    k_rope = entries[..., cfg.kv_lora_rank:]
    k_nope = jnp.einsum("bsc,chd->bshd", c_kv, p["k_up"])
    v = jnp.einsum("bsc,chd->bshd", c_kv, p["v_up"])
    # logits = q_nope.k_nope + q_rope.k_rope (k_rope shared across heads);
    # mixed-precision dots, f32 accumulate (§Perf P2)
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * cfg.scale
    causal = jnp.tril(jnp.ones((S, S), bool))
    if mask is not None:
        causal = causal & mask
    logits = jnp.where(causal[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshd,hdm->bsm", o, p["o_proj"])
    return out, entries
