"""Mixture-of-Experts: token-choice top-k router, shared + routed experts
(DeepSeek-V2 / Qwen3-MoE geometry), with a TPU-native expert-parallel
execution strategy.

EP strategy (DESIGN.md §5): activations are replicated over the `model`
(expert) axis inside a data shard, so each expert shard *filters* the
(token, k) pairs routed to its resident experts, computes them at capacity,
scatters back weighted, and a single psum over the expert axis combines
contributions. Communication = one (T, d) all-reduce — no global sort, no
all-to-all of activations; dispatch is sort-within-shard (MaxText-style
capacity grouping). Compiled FLOPs stay ~ 6 * N_active * D (the roofline's
MODEL_FLOPS ratio check depends on this — dense one-hot dispatch would
inflate HLO FLOPs quadratically).

All functions also run without a mesh axis (ep_axis=None) for smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.module import KeyGen, param


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int                  # per-expert FFN width (e.g. 1536)
    n_experts: int                 # routed experts
    top_k: int
    n_shared: int = 0              # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    router_dtype = jnp.float32


def init_moe(kg: KeyGen, cfg: MoEConfig, dtype=jnp.bfloat16):
    e, dm, dff = cfg.n_experts, cfg.d_model, cfg.d_expert
    p = {
        "router": param(kg(), (dm, e), ("embed", None), jnp.float32),
        # stacked routed experts, sharded on the expert axis (EP)
        "gate": param(kg(), (e, dm, dff), ("expert", "embed", None), dtype),
        "up": param(kg(), (e, dm, dff), ("expert", "embed", None), dtype),
        "down": param(kg(), (e, dff, dm), ("expert", None, "embed"), dtype),
    }
    if cfg.n_shared:
        s = cfg.n_shared
        p["sh_gate"] = param(kg(), (dm, s * dff), ("embed", "mlp"), dtype)
        p["sh_up"] = param(kg(), (dm, s * dff), ("embed", "mlp"), dtype)
        p["sh_down"] = param(kg(), (s * dff, dm), ("mlp", "embed"), dtype)
    return p


def _router(p, cfg: MoEConfig, x):
    """x (T, d) -> top-k (indices (T,k), weights (T,k)) — softmax-then-topk
    with renormalization (DeepSeek-V2 style)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return idx, w.astype(x.dtype), probs


def _expert_ffn(gate, up, down, x_ecd):
    """x (E_local, cap, d) through this shard's stacked SwiGLU experts."""
    g = jnp.einsum("ecd,edf->ecf", x_ecd, gate)
    u = jnp.einsum("ecd,edf->ecf", x_ecd, up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, down)


def _dispatch_compute(p, cfg: MoEConfig, x, idx, w, e_lo, capacity):
    """Capacity-grouped dispatch for this shard's resident experts.

    Under shard_map, p["gate"/"up"/"down"] are already the local expert
    slices (shape (E_local, ...)); e_lo is the shard's first global expert
    id (may be traced: lax.axis_index). x (T, d); idx/w (T, k). Returns the
    shard's weighted contribution (T, d).

    Sort-based grouping (MaxText-style): stable-sort (token, k) pairs by
    expert, position within expert group = rank - group start; drop beyond
    capacity. No global sort, no all-to-all: activations are replicated over
    the expert axis within a data shard (DESIGN.md §5).
    """
    n_local = p["gate"].shape[0]
    T, k = idx.shape
    flat_e = idx.reshape(-1)                          # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    group_start = jnp.searchsorted(se, jnp.arange(cfg.n_experts))
    pos = jnp.arange(T * k) - group_start[se]         # rank within expert
    local = (se >= e_lo) & (se < e_lo + n_local) & (pos < capacity)
    e_local = jnp.where(local, se - e_lo, n_local)    # n_local = trash row
    c_local = jnp.where(local, pos, 0)
    # gather tokens into (n_local+1, capacity, d); last row is the trash bin
    buf = jnp.zeros((n_local + 1, capacity, x.shape[-1]), x.dtype)
    buf = buf.at[e_local, c_local].set(
        jnp.where(local[:, None], x[st], 0.0), mode="drop")
    out_ecd = _expert_ffn(p["gate"], p["up"], p["down"], buf[:n_local])
    # scatter back, weighted
    contrib = out_ecd[jnp.where(local, e_local, 0),
                      c_local] * (sw * local)[:, None]
    y = jnp.zeros_like(x)
    y = y.at[st].add(contrib.astype(x.dtype), mode="drop")
    return y


def moe_apply(p, cfg: MoEConfig, x, ep_axis: Optional[str] = None):
    """x (..., d) -> (..., d). Under shard_map, ep_axis names the expert
    axis: each shard computes its resident experts' contribution and the
    results psum. aux: load-balancing loss terms."""
    shape = x.shape
    xt = x.reshape(-1, shape[-1])
    T = xt.shape[0]
    idx, w, probs = _router(p, cfg, xt)
    capacity = int(max(1, cfg.capacity_factor * T * cfg.top_k
                       // max(1, cfg.n_experts)))
    if ep_axis is None:
        e_lo = 0
    else:
        e_lo = lax.axis_index(ep_axis) * p["gate"].shape[0]
    y = _dispatch_compute(p, cfg, xt, idx, w, e_lo, capacity)
    if cfg.n_shared:
        # under shard_map the shared-expert FFN width is sharded over the
        # same axis: its partial joins the routed psum (one collective)
        h = jax.nn.silu(xt @ p["sh_gate"]) * (xt @ p["sh_up"])
        y = y + (h @ p["sh_down"]).astype(y.dtype)
    if ep_axis is not None:
        y = lax.psum(y, ep_axis)
    # GShard-style load-balance aux loss inputs
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], cfg.n_experts, dtype=jnp.float32),
                  axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return y.reshape(shape), aux
