"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000; anyres tiling frontend is a STUB —
input_specs() provides precomputed patch embeddings (task spec).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified tier]"""

from repro.models.model import ModelConfig

N_PATCHES = 576            # one anyres base tile (24x24 @ patch 14, 336px)


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm", n_layers=32,
        d_model=4096, vocab=32000, attn_type="gqa", n_heads=32,
        n_kv_heads=8, d_ff=14336, mlp_kind="swiglu", rope_theta=1e6,
        vlm_patches=N_PATCHES,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke", family="vlm", n_layers=2, d_model=64,
        vocab=256, attn_type="gqa", n_heads=4, n_kv_heads=2, d_ff=128,
        mlp_kind="swiglu", vlm_patches=8,
    )
