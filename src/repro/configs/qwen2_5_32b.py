"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf-verified tier]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
        vocab=152064, attn_type="gqa", n_heads=40, n_kv_heads=8,
        qkv_bias=True, d_ff=27648, mlp_kind="swiglu", rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke", family="dense", n_layers=2, d_model=64,
        vocab=256, attn_type="gqa", n_heads=4, n_kv_heads=2,
        qkv_bias=True, d_ff=128, mlp_kind="swiglu",
    )
