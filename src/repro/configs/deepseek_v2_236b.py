"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA kv_lora=512
(q_lora=1536, nope=128, rope=64, v=128), MoE: 160 routed top-6 + 2 shared,
d_expert=1536, first layer dense (d_ff=12288), vocab=102400.
[arXiv:2405.04434; hf-verified tier]

The paper's home regime: the latent c^KV entry is the routed wire object.
long_500k uses the DSA-style top-k selection path (selection_k=2048 — the
V3.2/GLM-5.1 budget, §5.4)."""

from repro.models.mla import MLAConfig
from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
        vocab=102400, attn_type="mla",
        n_heads=128, n_kv_heads=128,
        mla=MLAConfig(d_model=5120, n_heads=128, kv_lora_rank=512,
                      q_lora_rank=1536, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128),
        d_ff=12288, first_k_dense=1,
        moe=MoEConfig(d_model=5120, d_expert=1536, n_experts=160, top_k=6,
                      n_shared=2),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe", n_layers=3, d_model=64,
        vocab=256, attn_type="mla", n_heads=4, n_kv_heads=4,
        mla=MLAConfig(d_model=64, n_heads=4, kv_lora_rank=32,
                      q_lora_rank=48, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        d_ff=128, first_k_dense=1,
        moe=MoEConfig(d_model=64, d_expert=32, n_experts=8, top_k=2,
                      n_shared=1),
    )
