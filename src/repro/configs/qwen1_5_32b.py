"""qwen1.5-32b [dense] — 64L d_model=5120 40H (kv=40, MHA) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf-verified tier]

The paper's "standard model" contrast case: no compression, kv cache
20 KB/token-layer, so the predicate picks FETCH/LOCAL far more often
(DESIGN.md §4)."""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
        vocab=152064, attn_type="gqa", n_heads=40, n_kv_heads=40,
        qkv_bias=True, d_ff=27392, mlp_kind="swiglu", rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense", n_layers=2, d_model=64,
        vocab=256, attn_type="gqa", n_heads=4, n_kv_heads=4,
        qkv_bias=True, d_ff=128, mlp_kind="swiglu",
    )
