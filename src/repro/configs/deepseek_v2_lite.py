"""deepseek-v2-lite — the paper's measured instance (§3): 27L, d_model=2048,
16H MLA (kv_lora=512, rope=64 => d_qk=576, the 1152-B wire row), MoE 64
routed top-6 + 2 shared, d_expert=1408, first dense layer d_ff=10944,
vocab=102400. Used by the benchmark suite to reproduce the paper's numbers.
[arXiv:2405.04434; hf-verified tier]"""

from repro.models.mla import MLAConfig
from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite", family="moe", n_layers=27, d_model=2048,
        vocab=102400, attn_type="mla", n_heads=16, n_kv_heads=16,
        mla=MLAConfig(d_model=2048, n_heads=16, kv_lora_rank=512,
                      q_lora_rank=None, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128),
        d_ff=10944, first_k_dense=1,
        moe=MoEConfig(d_model=2048, d_expert=1408, n_experts=64, top_k=6,
                      n_shared=2),
    )


def smoke() -> ModelConfig:
    from repro.configs.deepseek_v2_236b import smoke as _smoke
    return _smoke()
