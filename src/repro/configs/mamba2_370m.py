"""mamba2-370m [ssm] — 48L d_model=1024 (attn-free), ssm_state=128,
head_dim=64, expand=2 (d_inner=2048, 32 SSD heads), vocab=50280.
SSD (state-space duality). [arXiv:2405.21060; unverified tier]

Technique inapplicability (DESIGN.md §4): no KV cache exists; the paper's
per-chunk ROUTE/FETCH/LOCAL question degenerates — cross-instance handoff is
a one-shot fixed-size state FETCH."""

from repro.models.model import ModelConfig
from repro.models.ssm import Mamba2Config


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
        vocab=50280, attn_type="none", d_ff=0,
        ssm=Mamba2Config(d_model=1024, d_state=128, head_dim=64, expand=2,
                         chunk=128),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
        vocab=256, attn_type="none", d_ff=0,
        ssm=Mamba2Config(d_model=64, d_state=16, head_dim=8, expand=2,
                         chunk=8),
    )
