"""whisper-large-v3 [audio] — enc-dec, 32L each, d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866, GELU + LayerNorm; conv frontend is a STUB —
input_specs() provides precomputed frame embeddings (task spec; enc_seq=1500
= 30 s of 20 ms frames). [arXiv:2212.04356; unverified tier]

Note: the assigned decode shapes (32k-token decoder cache) exceed Whisper's
released max_target_positions (448); the decoder's learned-position table is
sized to the assigned shape — a structural-lowering choice, DESIGN.md §4."""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio", n_layers=32,
        n_enc_layers=32, d_model=1280, vocab=51866, attn_type="gqa",
        n_heads=20, n_kv_heads=20, d_ff=5120, mlp_kind="gelu",
        norm_kind="layernorm", encdec=True, enc_seq=1500,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio", n_layers=2, n_enc_layers=2,
        d_model=64, vocab=256, attn_type="gqa", n_heads=4, n_kv_heads=4,
        d_ff=128, mlp_kind="gelu", norm_kind="layernorm", encdec=True,
        enc_seq=16,
    )
