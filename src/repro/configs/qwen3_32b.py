"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8, head_dim=128 explicit)
d_ff=25600 vocab=151936, qk_norm, no bias. [hf:Qwen/Qwen3-8B family; hf]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
        vocab=151936, attn_type="gqa", n_heads=64, n_kv_heads=8,
        head_dim=128, qk_norm=True, d_ff=25600, mlp_kind="swiglu",
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense", n_layers=2, d_model=64,
        vocab=256, attn_type="gqa", n_heads=4, n_kv_heads=2, head_dim=32,
        qk_norm=True, d_ff=128, mlp_kind="swiglu",
    )
