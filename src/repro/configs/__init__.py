"""Assigned-architecture registry: --arch <id> resolves here.

Each arch module exposes config() (the exact published geometry) and smoke()
(a reduced same-family config for CPU smoke tests). Sources/verification
tiers are recorded per module docstring.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

ARCH_IDS = [
    "qwen1_5_32b",
    "qwen2_5_32b",
    "qwen3_32b",
    "nemotron_4_340b",
    "deepseek_v2_236b",
    "qwen3_moe_235b",
    "llava_next_mistral_7b",
    "zamba2_7b",
    "mamba2_370m",
    "whisper_large_v3",
]

# canonical task ids -> module names
ALIASES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-32b": "qwen3_32b",
    "nemotron-4-340b": "nemotron_4_340b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-7b": "zamba2_7b",
    "mamba2-370m": "mamba2_370m",
    "whisper-large-v3": "whisper_large_v3",
    "deepseek-v2-lite": "deepseek_v2_lite",   # the paper's measured instance
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention (task spec): run for SSM / hybrid /
# selection-capable MLA; skip for pure full-attention archs (DESIGN.md §4).
LONG_CTX_ARCHS = {"deepseek_v2_236b", "zamba2_7b", "mamba2_370m"}


def _mod(arch: str):
    name = ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str):
    return _mod(arch).config()


def get_smoke_config(arch: str):
    return _mod(arch).smoke()


def supported_shapes(arch: str) -> List[str]:
    name = ALIASES.get(arch, arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_CTX_ARCHS:
        out.append("long_500k")
    return out


def all_cells():
    """Every runnable (arch, shape) dry-run cell."""
    return [(a, s) for a in ARCH_IDS for s in supported_shapes(a)]
