"""zamba2-7b [hybrid] — 81L d_model=3584, Mamba2 backbone (ssm_state=64,
head_dim=64) + SHARED attention block (32H kv=32, d_ff=14336) applied after
every 6-layer group (simplified: no per-invocation LoRA — DESIGN.md §4).
vocab=32000. [arXiv:2411.15242; unverified tier]"""

from repro.models.model import ModelConfig
from repro.models.ssm import Mamba2Config


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
        vocab=32000, attn_type="gqa", n_heads=32, n_kv_heads=32,
        d_ff=14336, mlp_kind="swiglu",
        ssm=Mamba2Config(d_model=3584, d_state=64, head_dim=64, expand=2,
                         chunk=128),
        hybrid_group=6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", n_layers=7, d_model=64,
        vocab=256, attn_type="gqa", n_heads=4, n_kv_heads=4, d_ff=128,
        mlp_kind="swiglu",
        ssm=Mamba2Config(d_model=64, d_state=16, head_dim=8, expand=2,
                         chunk=8),
        hybrid_group=3,
    )
