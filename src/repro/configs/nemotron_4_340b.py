"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP, LayerNorm. [arXiv:2402.16819; unverified]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense", n_layers=96, d_model=18432,
        vocab=256000, attn_type="gqa", n_heads=96, n_kv_heads=8,
        d_ff=73728, mlp_kind="squared_relu", norm_kind="layernorm",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense", n_layers=2, d_model=96,
        vocab=256, attn_type="gqa", n_heads=6, n_kv_heads=2,
        d_ff=384, mlp_kind="squared_relu", norm_kind="layernorm",
    )
