"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4, head_dim=128)
MoE: 128 experts top-8, d_expert=1536, vocab=151936, qk_norm.
[hf:Qwen/Qwen3-30B-A3B family; hf-verified tier]"""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
        vocab=151936, attn_type="gqa", n_heads=64, n_kv_heads=4,
        head_dim=128, qk_norm=True,
        moe=MoEConfig(d_model=4096, d_expert=1536, n_experts=128, top_k=8),
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe", n_layers=2, d_model=64,
        vocab=256, attn_type="gqa", n_heads=4, n_kv_heads=2, head_dim=32,
        qk_norm=True,
        moe=MoEConfig(d_model=64, d_expert=32, n_experts=8, top_k=2),
    )
