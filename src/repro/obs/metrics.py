"""Metrics registry: counters, gauges, and streaming-percentile
histograms with bounded memory (ISSUE 9).

Design constraints, in order:

1. **No unbounded sample storage.** Histograms fold observations into
   log-spaced fixed buckets plus (count, sum, min, max); percentiles are
   reconstructed by interpolating within the winning bucket. Memory per
   histogram is O(n_buckets) forever.
2. **Cheap writes.** A counter increment is one dict lookup + int add.
   The registry interns each (name, labels) series once and hands back
   the metric object, so hot callers hold a direct reference and never
   re-resolve labels per event.
3. **Deterministic export.** ``snapshot()`` sorts series by key so two
   runs over the same trace produce byte-identical JSON (used by the
   trace/metrics determinism tests).

Series are keyed ``name{k=v,...}`` with labels sorted by key — the
Prometheus convention, kept so the glossary in README maps 1:1.
"""

from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Tuple


def series_key(name: str, labels: Dict[str, object]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic accumulator. ``inc`` accepts float deltas so byte and
    second totals share the type with event counts."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, delta: float = 1.0) -> None:
        self.value += delta


class Gauge:
    """Last-write-wins point-in-time value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Streaming log-spaced histogram.

    Buckets span [lo, hi) decades with ``per_decade`` buckets per 10x;
    observations outside the span clamp into the first/last bucket (the
    exact min/max are kept separately so clamping never loses range
    information). Quantiles interpolate linearly inside the winning
    bucket — a ~(1/per_decade) relative-error estimator, plenty for
    telemetry and bounded forever.
    """

    __slots__ = ("lo", "per_decade", "buckets", "count", "sum",
                 "min", "max")

    N_DECADES = 12  # 1e-9 .. 1e3 by default covers ns..kiloseconds

    def __init__(self, lo: float = 1e-9, per_decade: int = 8) -> None:
        self.lo = lo
        self.per_decade = per_decade
        self.buckets = [0] * (self.N_DECADES * per_decade)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if x <= self.lo:
            idx = 0
        else:
            idx = int(math.log10(x / self.lo) * self.per_decade)
            if idx < 0:
                idx = 0
            elif idx >= len(self.buckets):
                idx = len(self.buckets) - 1
        self.buckets[idx] += 1

    def _bucket_edges(self, idx: int) -> Tuple[float, float]:
        lo = self.lo * 10.0 ** (idx / self.per_decade)
        hi = self.lo * 10.0 ** ((idx + 1) / self.per_decade)
        return lo, hi

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        target = q * self.count
        seen = 0
        for idx, n in enumerate(self.buckets):
            if n == 0:
                continue
            if seen + n >= target:
                lo, hi = self._bucket_edges(idx)
                frac = (target - seen) / n
                est = lo + (hi - lo) * frac
                # the true extrema are known exactly; never extrapolate
                # past them out of a clamped edge bucket
                return min(max(est, self.min), self.max)
            seen += n
        return self.max

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.sum / self.count,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """One flat namespace of counters/gauges/histograms.

    ``counter()``/``gauge()``/``histogram()`` intern the series and
    return the live metric object; callers on hot-ish paths should hold
    the reference rather than re-resolving every event.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, **labels: object) -> Counter:
        key = series_key(name, labels)
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels: object) -> Gauge:
        key = series_key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, **labels: object) -> Histogram:
        key = series_key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram()
        return h

    def snapshot(self) -> Dict[str, object]:
        """Deterministic (sorted-key) dump of every series."""
        return {
            "counters": {k: self._counters[k].value
                         for k in sorted(self._counters)},
            "gauges": {k: self._gauges[k].value
                       for k in sorted(self._gauges)},
            "histograms": {k: self._histograms[k].summary()
                           for k in sorted(self._histograms)},
        }

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        text = json.dumps(self.snapshot(), indent=indent, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def counter_value(self, name: str, **labels: object) -> float:
        key = series_key(name, labels)
        c = self._counters.get(key)
        return 0.0 if c is None else c.value

    def find(self, prefix: str) -> List[str]:
        """Series keys (all kinds) starting with ``prefix`` — test and
        glossary helper."""
        keys = [k for k in self._counters if k.startswith(prefix)]
        keys += [k for k in self._gauges if k.startswith(prefix)]
        keys += [k for k in self._histograms if k.startswith(prefix)]
        return sorted(keys)
