"""Drift monitor: the §7 "tracks to within ~7%" claim as a continuously
checked invariant (ISSUE 9).

Every MeasuredReport pairs the analytic timeline (what the fabric-table
cost model priced) with the measured one (the same flow structure carrying
wall-clock stage durations from the shard_map backend). This module folds
each matched stage's **relative residual**

    r = (measured_duration - planned_duration) / planned_duration

into an EWMA keyed ``(primitive, fabric_idx, stage)``, and trips when the
EWMA magnitude exceeds a configurable threshold after a minimum sample
count. On calibrated hardware with a fitted fabric table the paper's
claim puts |r| around 0.07; when the calibration constants rot (wrong
bandwidth, stale probe latency) the affected (fabric, stage) cells drift
away while the rest stay put — the per-cell keying is what makes the trip
attributable.

On FORCED HOST devices (CI) measured walls are dominated by collective
launch overhead and run 10^1–10^4× over the model; drift monitoring there
is a machinery smoke with a deliberately loose threshold (see the CI
multi-host job), not a calibration check.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

DriftKey = Tuple[str, int, str]   # (primitive, fabric_idx, stage name)


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    threshold: float = 0.07   # |EWMA| above this trips (the §7 envelope)
    alpha: float = 0.25       # EWMA weight of the newest residual
    min_samples: int = 3      # no verdict before this many residuals


@dataclasses.dataclass
class DriftStat:
    ewma: float = 0.0
    n: int = 0
    last: float = 0.0
    worst: float = 0.0        # max |residual| ever folded into this cell

    def fold(self, r: float, alpha: float) -> None:
        self.ewma = r if self.n == 0 else \
            (1.0 - alpha) * self.ewma + alpha * r
        self.n += 1
        self.last = r
        if abs(r) > abs(self.worst):
            self.worst = r


def _flow_fabric_idx(flow) -> int:
    """The fabric index of a flow's wire link, -1 for linkless flows."""
    for s in flow.stages:
        if s.resource is not None and s.resource[0] == "link":
            return int(s.resource[2])
    return -1


class DriftMonitor:
    """Accumulates measured-vs-planned residuals; ``tripped()`` reports
    cells whose EWMA left the envelope."""

    def __init__(self, config: Optional[DriftConfig] = None) -> None:
        self.config = config or DriftConfig()
        self.cells: Dict[DriftKey, DriftStat] = {}
        self.n_reports = 0
        self.n_residuals = 0
        self.n_unmatched = 0      # measured flows with no analytic partner

    # -- folding -------------------------------------------------------------

    def observe_residual(self, key: DriftKey, r: float) -> None:
        """Unit-test / synthetic entry point: fold one residual."""
        stat = self.cells.get(key)
        if stat is None:
            stat = self.cells[key] = DriftStat()
        stat.fold(float(r), self.config.alpha)
        self.n_residuals += 1

    def observe_report(self, report) -> int:
        """Fold one MeasuredReport. Flows are matched by key — the planner
        and the measured rebuild share the exact
        ``{prim}:{chunk}@{holder}#{i}`` format, so matching is total on a
        healthy step. Returns the number of residuals folded."""
        planned = {f.key: f for f in report.analytic.flows}
        folded = 0
        for mf in report.measured.flows:
            pf = planned.get(mf.key)
            if pf is None or len(pf.stages) != len(mf.stages):
                self.n_unmatched += 1
                continue
            prim = pf.primitive or mf.primitive or \
                mf.key.split(":", 1)[0]
            fab = _flow_fabric_idx(pf)
            for ps, ms in zip(pf.stages, mf.stages):
                if ps.duration_s <= 0.0:
                    continue   # no model prediction to drift from
                r = (ms.duration_s - ps.duration_s) / ps.duration_s
                self.observe_residual((prim, fab, ps.name), r)
                folded += 1
        self.n_reports += 1
        return folded

    # -- verdicts ------------------------------------------------------------

    def tripped(self) -> List[Tuple[DriftKey, DriftStat]]:
        cfg = self.config
        out = [(k, s) for k, s in sorted(self.cells.items())
               if s.n >= cfg.min_samples and abs(s.ewma) > cfg.threshold]
        out.sort(key=lambda ks: -abs(ks[1].ewma))
        return out

    def summary_lines(self, top: int = 12) -> List[str]:
        """Human-readable per-cell state, worst EWMA first."""
        rows = sorted(self.cells.items(), key=lambda ks: -abs(ks[1].ewma))
        lines = [
            f"drift: {self.n_residuals} residuals over {self.n_reports} "
            f"reports, {len(self.cells)} cells, threshold "
            f"{self.config.threshold:g} (min {self.config.min_samples} "
            f"samples)" + (f", {self.n_unmatched} unmatched flows"
                           if self.n_unmatched else "")
        ]
        tripped = {k for k, _ in self.tripped()}
        for key, s in rows[:top]:
            prim, fab, stage = key
            mark = " TRIP" if key in tripped else ""
            lines.append(
                f"  {prim:>6s} f{fab} {stage:<9s} ewma {s.ewma:+9.3f} "
                f"last {s.last:+9.3f} worst {s.worst:+9.3f} n={s.n}{mark}")
        if len(rows) > top:
            lines.append(f"  ... {len(rows) - top} more cells")
        return lines

    def check(self) -> None:
        """Raise DriftError when any cell is out of envelope."""
        bad = self.tripped()
        if bad:
            cells = ", ".join(
                f"{k[0]}/f{k[1]}/{k[2]} ewma={s.ewma:+.3f} n={s.n}"
                for k, s in bad[:6])
            raise DriftError(
                f"{len(bad)} drift cell(s) exceed |ewma| > "
                f"{self.config.threshold:g}: {cells}")


class DriftError(AssertionError):
    """Model-vs-measured calibration left the configured envelope."""
