"""Structured tracer: Chrome trace-event / Perfetto JSON export
(ISSUE 9).

Three process groups (``pid``) per export:

* pid 0 — **engine**: wall-clock spans for plan / execute / account per
  step (real ``perf_counter`` time, so two runs differ here — the
  determinism tests compare pids 1-2 only).
* pid 1 — **planned timeline**: the analytic schedule, one track
  (``tid``) per timeline resource — ``link i<inst> f<fabric>`` for each
  (link, fabric) pair and ``sm i<inst>`` for each holder SM — plus a
  ``steps`` marker track. Event times are the SIMULATED seconds the
  scheduler assigned, so this group is deterministic by construction.
* pid 2 — **measured timeline**: the same flow structure with the
  shard_map backend's measured stage walls (only present when the
  backend produced MeasuredReports).

Steps share one origin across the planned and measured groups (a step's
planned schedule and its measured execution sit vertically aligned in
the viewer); consecutive steps are laid head-to-tail with a small gap.
Measured walls on forced host devices are orders of magnitude longer
than the analytic model — that scale difference is the point of the
side-by-side rendering, zoom handles it.

Load the exported file at https://ui.perfetto.dev (or
``chrome://tracing``): it is a plain ``{"traceEvents": [...]}`` JSON.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

PID_ENGINE = 0
PID_PLANNED = 1
PID_MEASURED = 2

_PROCESS_NAMES = {
    PID_ENGINE: "engine (wall clock)",
    PID_PLANNED: "planned timeline (analytic)",
    PID_MEASURED: "measured timeline (shard_map walls)",
}

# tid 0 of every timeline pid is the per-step marker track
_STEP_TID = 0


def _track_label(resource) -> str:
    """Timeline Resource tuple -> human track name."""
    if resource is None:
        return "unbound"
    kind = resource[0]
    if kind == "link":
        return f"link i{resource[1]} f{resource[2]}"
    if kind == "sm":
        return f"sm i{resource[1]}"
    return "/".join(str(p) for p in resource)


class Tracer:
    """Collects trace events in memory; ``export()`` emits the JSON.

    The engine never calls into a Tracer from the planner hot path —
    all rendering happens at account time behind ``Obs.enabled`` — so a
    run without a tracer pays literally nothing for this module.
    """

    def __init__(self) -> None:
        self.events: List[dict] = []
        # per-pid {track label: tid}; tids allocated first-seen, stable
        # across identical runs (the determinism contract)
        self._tids: Dict[int, Dict[str, int]] = {}
        self._procs_emitted: set = set()
        self._cursor_us = 0.0
        self._wall0: Optional[float] = None
        self.n_steps = 0

    # -- bookkeeping ---------------------------------------------------------

    def _ensure_process(self, pid: int) -> None:
        if pid in self._procs_emitted:
            return
        self._procs_emitted.add(pid)
        self.events.append({
            "ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": _PROCESS_NAMES.get(pid, f"pid {pid}")},
        })
        # render planned above measured regardless of first-touch order
        self.events.append({
            "ph": "M", "pid": pid, "tid": 0, "name": "process_sort_index",
            "args": {"sort_index": pid},
        })

    def _tid(self, pid: int, label: str) -> int:
        self._ensure_process(pid)
        tids = self._tids.setdefault(pid, {})
        tid = tids.get(label)
        if tid is None:
            tid = tids[label] = len(tids) + 1   # 0 is the step track
            self.events.append({
                "ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                "args": {"name": label},
            })
        return tid

    # -- engine wall spans ---------------------------------------------------

    def wall_span(self, name: str, t0: float, t1: float,
                  track: str = "engine", **args: object) -> None:
        """A real perf_counter span (plan/execute/account), on pid 0.
        ``track`` names the pid-0 thread lane — the pipelined engine
        (ISSUE 10) rotates in-flight steps across lanes so overlapping
        walls render side by side instead of on one impossible track."""
        if self._wall0 is None:
            self._wall0 = t0
        self.events.append({
            "ph": "X", "pid": PID_ENGINE,
            "tid": self._tid(PID_ENGINE, track),
            "ts": (t0 - self._wall0) * 1e6,
            "dur": max(t1 - t0, 0.0) * 1e6,
            "name": name, "cat": "engine",
            "args": dict(args),
        })

    # -- timeline rendering --------------------------------------------------

    def add_step(self, step: int, planned, measured=None) -> None:
        """Render one step: the planned timeline, and (when the backend
        measured real walls) the measured timeline, at a shared origin."""
        origin = self._cursor_us
        span_p = self._emit_timeline(PID_PLANNED, step, planned, origin)
        span_m = 0.0
        if measured is not None:
            span_m = self._emit_timeline(PID_MEASURED, step, measured,
                                         origin)
        width = max(span_p, span_m, 1.0)
        self._cursor_us = origin + width * 1.05 + 1.0
        self.n_steps += 1

    def _emit_timeline(self, pid: int, step: int, timeline,
                       origin_us: float) -> float:
        """One 'X' event per scheduled stage, tracks = resources. Returns
        the group's width in us."""
        self._ensure_process(pid)
        makespan_us = timeline.makespan_s * 1e6
        self.events.append({
            "ph": "X", "pid": pid, "tid": _STEP_TID,
            "ts": origin_us, "dur": makespan_us,
            "name": f"step {step}", "cat": "step",
            "args": {"step": step, "makespan_us": makespan_us},
        })
        for s in timeline.scheduled:
            prim = s.flow_key.split(":", 1)[0]
            self.events.append({
                "ph": "X", "pid": pid,
                "tid": self._tid(pid, _track_label(s.resource)),
                "ts": origin_us + s.start_s * 1e6,
                "dur": (s.end_s - s.start_s) * 1e6,
                "name": s.stage, "cat": prim or "flow",
                "args": {"flow": s.flow_key, "step": step},
            })
        return makespan_us

    # -- export --------------------------------------------------------------

    def export(self, path: Optional[str] = None) -> dict:
        doc = {
            "traceEvents": self.events,
            "displayTimeUnit": "ns",
            "otherData": {"steps": self.n_steps,
                          "format": "repro.obs flight recorder"},
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=None, separators=(",", ":"))
                f.write("\n")
        return doc


def validate_trace(doc: dict) -> List[str]:
    """Schema check for an exported trace document. Returns a list of
    problems (empty = valid). Used by tests and the CI trace smoke."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    named_threads = set()
    named_procs = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"event {i}: unexpected ph {ph!r}")
            continue
        if "pid" not in ev or "tid" not in ev or "name" not in ev:
            problems.append(f"event {i}: missing pid/tid/name")
            continue
        if ph == "M":
            if ev["name"] == "thread_name":
                named_threads.add((ev["pid"], ev["tid"]))
            elif ev["name"] == "process_name":
                named_procs.add(ev["pid"])
            continue
        ts, dur = ev.get("ts"), ev.get("dur")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
        if not isinstance(dur, (int, float)) or dur < 0:
            problems.append(f"event {i}: bad dur {dur!r}")
    for ev in events:
        if ev.get("ph") != "X":
            continue
        if ev["pid"] not in named_procs:
            problems.append(f"pid {ev['pid']} has no process_name")
            break
    for ev in events:
        if ev.get("ph") != "X" or ev.get("tid") == _STEP_TID:
            continue
        if (ev["pid"], ev["tid"]) not in named_threads:
            problems.append(
                f"track ({ev['pid']},{ev['tid']}) has no thread_name")
            break
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as e:  # pragma: no cover - defensive
        problems.append(f"not JSON-serializable: {e}")
    return problems
