"""repro.obs — the flight recorder (ISSUE 9).

One bundle, three organs:

* :class:`~repro.obs.trace.Tracer` — Chrome trace-event / Perfetto JSON
  spans: engine wall phases + planned/measured timeline track groups.
* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  streaming-percentile histograms for everything that decides behavior
  (decisions by verdict, bytes by fabric, planner cache hit rates, pool
  occupancy, eviction/promotion churn, indexer roundtrips, ...).
* :class:`~repro.obs.drift.DriftMonitor` — per-(primitive, fabric,
  stage) EWMA of measured-vs-analytic residuals; the §7 "~7% tracking"
  claim as a loud invariant.

Hot-path contract: the planner NEVER calls into this package. The engine
keeps plain-int cache counters (free either way) and hands everything to
``Obs.on_step`` once per step, from ``_account``, behind a single
``obs is not NULL_OBS`` check in ``schedule_step``. A run constructed
without an Obs pays one identity comparison per step — that is the
"disabled tracer costs near-zero" guarantee the planner bench guards.
"""

from __future__ import annotations

from typing import Optional

from repro.obs.drift import (DriftConfig, DriftError,  # noqa: F401
                             DriftMonitor)
from repro.obs.metrics import MetricsRegistry  # noqa: F401
from repro.obs.trace import Tracer, validate_trace  # noqa: F401


class _NullObs:
    """The disabled singleton: identity-compared on the step path, never
    called. ``enabled`` is False so library code can branch cheaply."""

    __slots__ = ()
    enabled = False
    tracer = None
    metrics = None
    drift = None

    def bind_engine(self, engine) -> None:  # pragma: no cover - trivial
        pass

    def on_step(self, engine, plan, execution, stats, walls=None,
                overlap_s=0.0, replans=0) -> None:  # pragma: no cover
        pass


NULL_OBS = _NullObs()


class Obs:
    """Live observability bundle. Construct with the organs you want:

    >>> obs = Obs()                       # metrics only
    >>> obs = Obs(tracer=Tracer(), drift=DriftMonitor())

    and pass it to ``ServingEngine(..., obs=obs)`` (or let
    ``repro.launch.serve`` build it from ``--trace-out`` /
    ``--metrics-out`` / ``--drift-threshold``).
    """

    enabled = True

    def __init__(self, tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 drift: Optional[DriftMonitor] = None) -> None:
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.drift = drift
        self._bound_stores: set = set()

    # -- wiring ---------------------------------------------------------------

    def bind_engine(self, engine) -> None:
        """Attach the store-churn listeners. Called by ServingEngine's
        constructor; idempotent per store."""
        store = engine.store
        if id(store) in self._bound_stores:
            return
        self._bound_stores.add(id(store))
        m = self.metrics

        def _on_copy_retired(chunk_id: str, instance: int) -> None:
            m.counter("store.copy_retirements", instance=instance).inc()

        store.add_evict_listener(_on_copy_retired)

    # -- the one per-step hook ------------------------------------------------

    def on_step(self, engine, plan, execution, stats, walls=None,
                overlap_s=0.0, replans=0) -> None:
        """Fold one accounted step into every organ. Runs AFTER the step's
        sched_wall_s was measured, so even heavy exports here never show
        up in planner-throughput numbers. ``overlap_s`` is the planner
        wall this step demonstrably hid under the device barrier and
        ``replans`` the engine's cumulative misspeculation count — both
        zero outside pipelined mode (ISSUE 10)."""
        from repro.serving import timeline as TL

        m = self.metrics
        report = getattr(execution, "measured", None)
        timeline = execution.timeline

        # -- engine: decisions, latency, selection fallbacks ------------------
        m.counter("engine.steps").inc()
        m.counter("engine.pairs").inc(stats.n_pairs)
        m.counter("engine.pairs_priced").inc(stats.n_priced)
        m.counter("engine.pairs_resident").inc(stats.n_resident)
        for prim, n in stats.primitives.items():
            m.counter("engine.dispatches", primitive=prim).inc(n)
        m.counter("engine.replicas_spawned").inc(stats.replicas_spawned)
        m.counter("engine.evictions").inc(stats.evictions)
        if stats.selection_fallbacks:
            # satellite (ISSUE 9): the priced-vs-executed divergence is a
            # per-run counter now, not a once-per-process warning
            m.counter("engine.selection_fallbacks").inc(
                stats.selection_fallbacks)
        m.histogram("engine.step_latency_s").observe(stats.latency_s)
        m.histogram("engine.sched_wall_s").observe(stats.sched_wall_s)

        # -- pipeline (ISSUE 10) ----------------------------------------------
        depth = max(1, getattr(engine.cfg, "pipeline_depth", 1))
        m.gauge("engine.pipeline_depth").set(depth)
        if depth > 1:
            m.histogram("engine.planner_overlap_s").observe(overlap_s)
            m.counter("engine.planner_overlap_s_total").inc(overlap_s)
            m.gauge("engine.misspeculation_replans").set(replans)

        # -- engine: bytes by fabric/link + §8 congestion ---------------------
        # model-implied wire bytes: duration x fabric bandwidth for every
        # scheduled wire stage except the pure-latency probe (the index
        # stage keeps its probe floor — documented in README's glossary)
        bw = engine._fa.bw_Bps
        fabric_names = engine._fa.names
        for s in timeline.scheduled:
            res = s.resource
            if res is None or res[0] != "link" or s.stage == "probe":
                continue
            fi = res[2]
            nbytes = (s.end_s - s.start_s) * float(bw[fi])
            m.counter("engine.wire_bytes", fabric=fabric_names[fi]).inc(
                nbytes)
            m.counter("engine.link_wire_bytes", instance=res[1],
                      fabric=fabric_names[fi]).inc(nbytes)
        link_counts = timeline.link_flow_counts()
        for k in link_counts.values():
            m.histogram("engine.link_flows").observe(float(k))
        congested = sum(1 for k in link_counts.values() if k >= 3)
        if congested:
            m.counter("engine.congested_links").inc(congested)

        # -- planner caches (cumulative -> gauges) ----------------------------
        for name, v in engine.planner_cache_stats().items():
            m.gauge(f"planner.cache.{name}").set(v)
        for name, v in TL.sim_memo_stats().items():
            m.gauge(f"planner.sim_memo.{name}").set(v)

        # -- chunk store occupancy --------------------------------------------
        store = engine.store
        for i in range(store.n_instances):
            used = store.used(i)
            side = store.sidecar_tokens_used(i)
            m.gauge("store.pool_used_tokens", instance=i).set(used)
            m.gauge("store.sidecar_tokens", instance=i).set(side)
        m.gauge("store.pool_tokens").set(store.pool_tokens)
        m.gauge("store.promotions").set(store.promotions)

        # -- backend telemetry ------------------------------------------------
        backend = engine.backend
        qh = getattr(backend, "qmemo_hits", None)
        if qh is not None:
            m.gauge("exec.query_memo.hit").set(qh)
            m.gauge("exec.query_memo.miss").set(
                getattr(backend, "qmemo_misses", 0))
        phase_total = getattr(backend, "phase_wall_total", None)
        if phase_total:
            for phase, secs in phase_total.items():
                m.gauge("exec.phase_wall_s", phase=phase).set(secs)
        if report is not None:
            if report.stage_fills:
                # satellite (ISSUE 9): stage-measurement gaps per-run, not
                # warn-once
                m.counter("exec.stage_fills").inc(report.stage_fills)
            m.gauge("exec.pool_entries").set(report.pool_entries)
            m.gauge("exec.pool_bytes").set(report.pool_bytes)
            m.histogram("exec.wall_s").observe(report.wall_s)
            ratio = report.makespan_ratio
            if ratio == ratio and ratio not in (float("inf"),):
                m.histogram("exec.measured_ratio").observe(ratio)

        # -- indexer service --------------------------------------------------
        sel = engine.selector
        counts = getattr(sel, "obs_counts", None)
        if counts:
            for name, v in counts.items():
                m.gauge(f"selector.{name}").set(v)
        sizes = getattr(sel, "drain_merge_sizes", None)
        if sizes is not None:
            for n in sizes():
                m.histogram("selector.merge_candidates").observe(float(n))

        # -- drift ------------------------------------------------------------
        if self.drift is not None and report is not None:
            self.drift.observe_report(report)

        # -- tracer -----------------------------------------------------------
        if self.tracer is not None:
            if walls is not None:
                t0, t1, t2, t3 = walls
                # in-flight steps overlap in wall time; give each a lane
                # (round-robin over depth) so Perfetto renders them as
                # parallel pid-0 tracks instead of one impossible track.
                # Depth 1 keeps the historical single "engine" track.
                track = "engine" if depth <= 1 \
                    else f"engine lane {(stats.step - 1) % depth}"
                self.tracer.wall_span("plan", t0, t1, track=track,
                                      step=stats.step)
                self.tracer.wall_span("execute", t1, t2, track=track,
                                      step=stats.step,
                                      backend=type(backend).__name__)
                self.tracer.wall_span("account", t2, t3, track=track,
                                      step=stats.step)
            self.tracer.add_step(
                stats.step, timeline,
                report.measured if report is not None else None)
