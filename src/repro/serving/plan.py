"""The PLAN layer of the serving engine (plan / execute / account).

A decode step flows through three layers since ISSUE 3:

  plan    — residency resolution, one vectorized decide_batch() over every
            non-resident (request, chunk) pair, per-(holder, chunk, fabric)
            dispatch batching, fan-in capping, fetch persistence. Output: a
            StepPlan — the full transport schedule for the step, expressed
            as DispatchRecords plus the residency telemetry.
  execute — an ExecutionBackend (repro.serving.backends) consumes the plan:
            the AnalyticBackend schedules it on the PR-2 overlap timeline
            (pure simulation, today's numbers); the JaxExecBackend ALSO
            runs the planned attention on real c^KV arrays and returns the
            decode outputs.
  account — StepStats built from the plan + the executed timeline.

This module holds the data types the three layers share (and the timeline
construction both backends use), so engine and backends can import it
without importing each other.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import cost_model as cm
from repro.serving import timeline as TL


@dataclasses.dataclass
class Request:
    req_id: int
    home: int                      # requester instance
    chunk_ids: List[str]
    m_q: int = 1                   # query rows per chunk this step
    expected_reuse_steps: int = 1
    k_selected: Optional[int] = None
    # deterministic seed for this request's query tensor (exec backend);
    # None lets the backend derive one from req_id. The ANALYTIC path never
    # reads it, so traces stay backend-agnostic.
    query_seed: Optional[int] = None


@dataclasses.dataclass
class DispatchRecord:
    step: int
    holder: int
    primitive: str
    chunk_id: str
    n_requesters: int
    m_q_total: int
    est_cost_s: float
    backup: bool = False
    # timeline inputs: which wire the dispatch occupies (link_instance < 0
    # means no wire — LOCAL), the requester-side instance for merge/splice,
    # and the §4 per-stage breakdown the est_cost_s sums over
    fabric_idx: int = -1
    link_instance: int = -1
    home: int = -1
    stages: cm.StageList = ()
    # the requests batched into this dispatch (plan -> execute handoff: the
    # exec backend stacks their query tensors into one holder-side partial)
    req_ids: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class ResidentPair:
    """A (request, chunk) access served by local attention — no transport,
    so no DispatchRecord; the exec backend still computes its partial."""
    req_id: int
    chunk_id: str
    instance: int


@dataclasses.dataclass
class StepPlan:
    """One planned decode step: every transport as a DispatchRecord, every
    free local access as a ResidentPair, plus the planning telemetry the
    account layer folds into StepStats. Planning COMMITS residency (fetch
    persistence, replica spawns, LRU evictions) — execution replays the
    already-decided schedule, it never re-plans."""
    step: int
    requests: List[Request]
    records: List[DispatchRecord]
    resident_pairs: List[ResidentPair]
    n_pairs: int                   # (request, chunk) accesses resolved
    n_priced: int                  # pairs that reached decide_batch
    n_resident: int                # served by local attention, no transport
    replicas_spawned: int = 0
    evictions: int = 0
    # selection regime (ISSUE 4): the indexer's per-request verdicts
    # (req_id -> RequestSelection, repro.serving.selection.types) — the
    # plan->execute handoff of the §5.4 masks; empty when no selector ran
    selections: Dict[int, object] = dataclasses.field(default_factory=dict)
    # requests that carried k_selected but had NO selector to run: priced
    # as selection, executed dense — counted so the regimes cannot diverge
    # silently (the engine also warns once)
    selection_fallbacks: int = 0


@dataclasses.dataclass
class StepStats:
    """Per-step scheduler telemetry (the benchmark's raw material)."""
    step: int
    n_requests: int
    n_pairs: int                   # (request, chunk) accesses resolved
    n_priced: int                  # pairs that reached decide_batch
    n_resident: int                # served by local attention, no transport
    n_dispatches: int              # primary dispatches issued
    primitives: Dict[str, int]
    latency_s: float               # makespan of the step's transport timeline
    sched_wall_s: float            # scheduler wall-clock for this step
    replicas_spawned: int = 0
    evictions: int = 0
    # timeline telemetry: the old independent max-reduce price (what PR 1
    # reported as latency), the serial sum of every stage, and the summed
    # duration per stage name
    max_dispatch_s: float = 0.0
    serial_stage_s: float = 0.0
    stage_totals: Dict[str, float] = dataclasses.field(default_factory=dict)
    # selection regime (ISSUE 4): pairs served under an ACTIVE indexer
    # selection this step, and requests that were priced as selection but
    # executed dense because no selector was configured (warn-once +
    # recorded here, so the divergence is always visible in telemetry)
    n_selected: int = 0
    selection_fallbacks: int = 0

    @property
    def decisions_per_sec(self) -> float:
        """Predicate evaluations per wall-clock second (resident pairs skip
        the predicate and are excluded)."""
        return self.n_priced / self.sched_wall_s if self.sched_wall_s else 0.0

    @property
    def has_transport(self) -> bool:
        """False for a fully-resident step: nothing was scheduled, so the
        0.0 makespan is not a latency any request experienced."""
        return self.n_dispatches > 0

    @property
    def overlap_efficiency(self) -> float:
        """makespan / sum-of-stages (1.0 = fully serial, 1/n = n flows
        perfectly overlapped; 1.0 for an empty step)."""
        return (self.latency_s / self.serial_stage_s
                if self.serial_stage_s > 0 else 1.0)


def transport_latencies(stats: Iterable[StepStats]) -> np.ndarray:
    """Latencies of the steps that actually dispatched work. Fully-resident
    steps have an empty schedule (latency 0.0); including them would deflate
    p50/p99 with zeros nobody waited for — aggregation must skip them."""
    return np.array([s.latency_s for s in stats if s.has_transport],
                    np.float64)


def _backup_of(records: List["DispatchRecord"],
               i: int) -> Optional["DispatchRecord"]:
    """The straggler backup shadowing records[i], if any. The planner
    emits a backup IMMEDIATELY after its primary, so adjacency — not
    chunk_id alone — is the association: two fabric groups of one chunk
    each carry their own backup and must not cap each other."""
    nxt = i + 1
    if nxt < len(records) and records[nxt].backup \
            and records[nxt].chunk_id == records[i].chunk_id:
        return records[nxt]
    return None


def _critical_path(records: List["DispatchRecord"]) -> float:
    """Independent max-reduce price of one step's records: max over primary
    dispatches, where a backup caps its own primary's contribution. Through
    PR 1 this WAS the step latency; it is kept as StepStats.max_dispatch_s —
    the no-contention floor the timeline makespan is compared against."""
    worst = 0.0
    for i, r in enumerate(records):
        if r.backup:
            continue
        cost = r.est_cost_s
        b = _backup_of(records, i)
        if b is not None:
            cost = min(cost, b.est_cost_s)
        worst = max(worst, cost)
    return worst


def build_timeline(records: List["DispatchRecord"]) -> TL.Timeline:
    """One step's dispatch records as an overlap-aware schedule.

    A straggler backup replaces its own primary (adjacent record) when it
    is the cheaper path (the engine cancels the primary at the p99
    deadline — modeled as the faster of the two serving the chunk),
    mirroring _critical_path's min. Wire stages bind to the dispatch's
    (link_instance, fabric) resource, compute to the holder's SM,
    merge/splice/prefill to the requester's."""
    flows: List[TL.Flow] = []
    for i, r in enumerate(records):
        if r.backup:
            continue
        b = _backup_of(records, i)
        eff = b if b is not None and b.est_cost_s < r.est_cost_s else r
        if not eff.stages:
            continue
        link_res = (TL.link(eff.link_instance, eff.fabric_idx)
                    if eff.link_instance >= 0 else None)
        requester = eff.home if eff.home >= 0 else eff.holder
        flows.append(TL.transport_flow(
            f"{eff.primitive}:{eff.chunk_id}@{eff.holder}#{i}",
            eff.stages, link_res=link_res,
            holder_sm=TL.sm(eff.holder), requester_sm=TL.sm(requester),
            primitive=eff.primitive, chunk_id=eff.chunk_id))
    return TL.simulate(flows)
