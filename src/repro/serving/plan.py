"""The PLAN layer of the serving engine (plan / execute / account).

A decode step flows through three layers since ISSUE 3:

  plan    — residency resolution, one vectorized decide_batch() over every
            non-resident (request, chunk) pair, per-(holder, chunk, fabric)
            dispatch batching, fan-in capping, fetch persistence. Output: a
            StepPlan — the full transport schedule for the step, expressed
            as DispatchRecords plus the residency telemetry.
  execute — an ExecutionBackend (repro.serving.backends) consumes the plan:
            the AnalyticBackend schedules it on the PR-2 overlap timeline
            (pure simulation, today's numbers); the JaxExecBackend ALSO
            runs the planned attention on real c^KV arrays and returns the
            decode outputs.
  account — StepStats built from the plan + the executed timeline.

This module holds the data types the three layers share (and the timeline
construction both backends use), so engine and backends can import it
without importing each other.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import cost_model as cm
from repro.serving import timeline as TL


@dataclasses.dataclass
class Request:
    req_id: int
    home: int                      # requester instance
    chunk_ids: List[str]
    m_q: int = 1                   # query rows per chunk this step
    expected_reuse_steps: int = 1
    k_selected: Optional[int] = None
    # deterministic seed for this request's query tensor (exec backend);
    # None lets the backend derive one from req_id. The ANALYTIC path never
    # reads it, so traces stay backend-agnostic.
    query_seed: Optional[int] = None


@dataclasses.dataclass
class DispatchRecord:
    step: int
    holder: int
    primitive: str
    chunk_id: str
    n_requesters: int
    m_q_total: int
    est_cost_s: float
    backup: bool = False
    # timeline inputs: which wire the dispatch occupies (link_instance < 0
    # means no wire — LOCAL), the requester-side instance for merge/splice,
    # and the §4 per-stage breakdown the est_cost_s sums over
    fabric_idx: int = -1
    link_instance: int = -1
    home: int = -1
    stages: cm.StageList = ()
    # the requests batched into this dispatch (plan -> execute handoff: the
    # exec backend stacks their query tensors into one holder-side partial)
    req_ids: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class ResidentPair:
    """A (request, chunk) access served by local attention — no transport,
    so no DispatchRecord; the exec backend still computes its partial."""
    req_id: int
    chunk_id: str
    instance: int


@dataclasses.dataclass
class StepPlan:
    """One planned decode step: every transport as a DispatchRecord, every
    free local access as a ResidentPair, plus the planning telemetry the
    account layer folds into StepStats. Planning COMMITS residency (fetch
    persistence, replica spawns, LRU evictions) — execution replays the
    already-decided schedule, it never re-plans.

    `records` is LAZY when the plan carries its columnar form: the array
    planner passes records=None and the DispatchRecord objects are
    materialized from `arrays` on first access (telemetry / logging), off
    the scheduler's timed critical path. The object planner still passes
    them eagerly — for it the records ARE the plan."""
    step: int
    requests: List[Request]
    records: dataclasses.InitVar[Optional[List[DispatchRecord]]]
    resident_pairs: List[ResidentPair]
    n_pairs: int                   # (request, chunk) accesses resolved
    n_priced: int                  # pairs that reached decide_batch
    n_resident: int                # served by local attention, no transport
    replicas_spawned: int = 0
    evictions: int = 0
    # selection regime (ISSUE 4): the indexer's per-request verdicts
    # (req_id -> RequestSelection, repro.serving.selection.types) — the
    # plan->execute handoff of the §5.4 masks; empty when no selector ran
    selections: Dict[int, object] = dataclasses.field(default_factory=dict)
    # requests that carried k_selected but had NO selector to run: priced
    # as selection, executed dense — counted so the regimes cannot diverge
    # silently (the engine also warns once)
    selection_fallbacks: int = 0
    # ISSUE 6: the columnar form of `records`, set by the array planner.
    # When present it is authoritative for the hot path (the analytic
    # backend schedules straight from it); `records` is materialized from
    # it and stays the cross-backend / telemetry contract.
    arrays: Optional["StepPlanArrays"] = None

    def __post_init__(self, records: Optional[List[DispatchRecord]]):
        self._records = records


def _steplan_records(self: "StepPlan") -> List["DispatchRecord"]:
    if self._records is None:
        self._records = self.arrays.to_records()
    return self._records


# attached after class creation: a plain `records` property in the class
# body would be mistaken for the InitVar's default by @dataclass
StepPlan.records = property(_steplan_records)


@dataclasses.dataclass
class StepStats:
    """Per-step scheduler telemetry (the benchmark's raw material)."""
    step: int
    n_requests: int
    n_pairs: int                   # (request, chunk) accesses resolved
    n_priced: int                  # pairs that reached decide_batch
    n_resident: int                # served by local attention, no transport
    n_dispatches: int              # primary dispatches issued
    primitives: Dict[str, int]
    latency_s: float               # makespan of the step's transport timeline
    sched_wall_s: float            # scheduler wall-clock for this step
    replicas_spawned: int = 0
    evictions: int = 0
    # timeline telemetry: the old independent max-reduce price (what PR 1
    # reported as latency), the serial sum of every stage, and the summed
    # duration per stage name
    max_dispatch_s: float = 0.0
    serial_stage_s: float = 0.0
    stage_totals: Dict[str, float] = dataclasses.field(default_factory=dict)
    # selection regime (ISSUE 4): pairs served under an ACTIVE indexer
    # selection this step, and requests that were priced as selection but
    # executed dense because no selector was configured (warn-once +
    # recorded here, so the divergence is always visible in telemetry)
    n_selected: int = 0
    selection_fallbacks: int = 0

    def comparable(self) -> Dict[str, object]:
        """Everything deterministic about the step: the full dataclass
        minus sched_wall_s (host wall clock — the one field that may
        legitimately differ between two runs of the same plan). A/B
        identity checks (pipelined vs lockstep, obs on vs off) compare
        this dict."""
        d = dataclasses.asdict(self)
        d.pop("sched_wall_s")
        return d

    @property
    def decisions_per_sec(self) -> float:
        """Predicate evaluations per wall-clock second (resident pairs skip
        the predicate and are excluded)."""
        return self.n_priced / self.sched_wall_s if self.sched_wall_s else 0.0

    @property
    def has_transport(self) -> bool:
        """False for a fully-resident step: nothing was scheduled, so the
        0.0 makespan is not a latency any request experienced."""
        return self.n_dispatches > 0

    @property
    def overlap_efficiency(self) -> float:
        """makespan / sum-of-stages (1.0 = fully serial, 1/n = n flows
        perfectly overlapped; 1.0 for an empty step)."""
        return (self.latency_s / self.serial_stage_s
                if self.serial_stage_s > 0 else 1.0)


def transport_latencies(stats: Iterable[StepStats]) -> np.ndarray:
    """Latencies of the steps that actually dispatched work. Fully-resident
    steps have an empty schedule (latency 0.0); including them would deflate
    p50/p99 with zeros nobody waited for — aggregation must skip them."""
    return np.array([s.latency_s for s in stats if s.has_transport],
                    np.float64)


def _backup_of(records: List["DispatchRecord"],
               i: int) -> Optional["DispatchRecord"]:
    """The straggler backup shadowing records[i], if any. The planner
    emits a backup IMMEDIATELY after its primary, so adjacency — not
    chunk_id alone — is the association: two fabric groups of one chunk
    each carry their own backup and must not cap each other."""
    nxt = i + 1
    if nxt < len(records) and records[nxt].backup \
            and records[nxt].chunk_id == records[i].chunk_id:
        return records[nxt]
    return None


def _critical_path(records: List["DispatchRecord"]) -> float:
    """Independent max-reduce price of one step's records: max over primary
    dispatches, where a backup caps its own primary's contribution. Through
    PR 1 this WAS the step latency; it is kept as StepStats.max_dispatch_s —
    the no-contention floor the timeline makespan is compared against."""
    worst = 0.0
    for i, r in enumerate(records):
        if r.backup:
            continue
        cost = r.est_cost_s
        b = _backup_of(records, i)
        if b is not None:
            cost = min(cost, b.est_cost_s)
        worst = max(worst, cost)
    return worst


# ---------------------------------------------------------------------------
# Columnar plan (ISSUE 6): the step's records as flat numpy columns.
# ---------------------------------------------------------------------------

PRIM_NAMES: Tuple[str, ...] = ("route", "fetch", "local", "fetch_replica")
PRIM_CODE: Dict[str, int] = {n: i for i, n in enumerate(PRIM_NAMES)}

# flow resource ids, packed per instance: slot 0 = the instance's SM,
# slots 2 + fabric_idx = its (link, fabric) wires (fabric_idx in {0, 1})
_RES_SLOTS = 4


_RES_MEMO: dict = {}


def _decode_res(code: int) -> TL.Resource:
    r = _RES_MEMO.get(code)
    if r is None:
        inst, slot = divmod(code, _RES_SLOTS)
        r = TL.sm(inst) if slot == 0 else TL.link(inst, slot - 2)
        _RES_MEMO[code] = r
    return r


@dataclasses.dataclass
class StepPlanArrays:
    """One step's DispatchRecords as struct-of-arrays: fixed-width record
    columns plus two ragged columns (per-record stage chains and batched
    req_ids). chunk ids are interned in `chunk_ids`; stage names in
    timeline.STAGE_NAMES. to_records() round-trips to the object form
    exactly (tests/test_plan_arrays.py pins it on the golden traces)."""
    step: int
    chunk_ids: Tuple[str, ...]           # intern table for `chunk`
    prim: np.ndarray                     # (R,) int64 PRIM_NAMES code
    holder: np.ndarray                   # (R,) int64
    chunk: np.ndarray                    # (R,) int64 -> chunk_ids
    n_requesters: np.ndarray             # (R,) int64
    m_q_total: np.ndarray                # (R,) int64
    est_cost_s: np.ndarray               # (R,) float64
    backup: np.ndarray                   # (R,) bool
    fabric_idx: np.ndarray               # (R,) int64, -1 = no wire
    link_instance: np.ndarray            # (R,) int64, -1 = no wire
    home: np.ndarray                     # (R,) int64, -1 = unset
    stage_off: np.ndarray                # (R+1,) int64 ragged bounds
    stage_code: np.ndarray               # (S,) int64 timeline.STAGE_NAMES
    stage_dur: np.ndarray                # (S,) float64
    req_off: np.ndarray                  # (R+1,) int64 ragged bounds
    req_ids: np.ndarray                  # (Q,) int64 batched request ids

    @property
    def n_records(self) -> int:
        return int(self.prim.shape[0])

    @classmethod
    def from_records(cls, step: int,
                     records: List["DispatchRecord"]) -> "StepPlanArrays":
        """Columnarize object records (conversion path — tests and the
        round-trip contract; the array planner builds columns directly)."""
        cid_index: Dict[str, int] = {}
        stage_off, stage_code, stage_dur = [0], [], []
        req_off, req_ids = [0], []
        cols = ([], [], [], [], [], [], [], [], [], [])
        for r in records:
            cols[0].append(PRIM_CODE[r.primitive])
            cols[1].append(r.holder)
            cols[2].append(cid_index.setdefault(r.chunk_id, len(cid_index)))
            cols[3].append(r.n_requesters)
            cols[4].append(r.m_q_total)
            cols[5].append(r.est_cost_s)
            cols[6].append(r.backup)
            cols[7].append(r.fabric_idx)
            cols[8].append(r.link_instance)
            cols[9].append(r.home)
            for name, dur in r.stages:
                stage_code.append(TL.STAGE_CODE[name])
                stage_dur.append(dur)
            stage_off.append(len(stage_code))
            req_ids.extend(r.req_ids)
            req_off.append(len(req_ids))
        return cls(
            step=step, chunk_ids=tuple(cid_index),
            prim=np.asarray(cols[0], np.int64),
            holder=np.asarray(cols[1], np.int64),
            chunk=np.asarray(cols[2], np.int64),
            n_requesters=np.asarray(cols[3], np.int64),
            m_q_total=np.asarray(cols[4], np.int64),
            est_cost_s=np.asarray(cols[5], np.float64),
            backup=np.asarray(cols[6], bool),
            fabric_idx=np.asarray(cols[7], np.int64),
            link_instance=np.asarray(cols[8], np.int64),
            home=np.asarray(cols[9], np.int64),
            stage_off=np.asarray(stage_off, np.int64),
            stage_code=np.asarray(stage_code, np.int64),
            stage_dur=np.asarray(stage_dur, np.float64),
            req_off=np.asarray(req_off, np.int64),
            req_ids=np.asarray(req_ids, np.int64))

    def to_records(self) -> List[DispatchRecord]:
        """Materialize object DispatchRecords (the telemetry / exec-backend
        contract). Values round-trip bitwise: columns never re-derive.
        Every column is pulled down with .tolist() once (native Python
        scalars, same bits as item-wise int()/float()) so the per-record
        work is pure slicing."""
        so = self.stage_off.tolist()
        pairs = list(zip((TL.STAGE_NAMES[c] for c in self.stage_code.tolist()),
                         self.stage_dur.tolist()))
        ro = self.req_off.tolist()
        rid = self.req_ids.tolist()
        prim = [PRIM_NAMES[c] for c in self.prim.tolist()]
        cid = [self.chunk_ids[c] for c in self.chunk.tolist()]
        holder, nreq = self.holder.tolist(), self.n_requesters.tolist()
        mqt, est = self.m_q_total.tolist(), self.est_cost_s.tolist()
        backup, fi = self.backup.tolist(), self.fabric_idx.tolist()
        link, home = self.link_instance.tolist(), self.home.tolist()
        step = self.step
        return [
            DispatchRecord(
                step, holder[i], prim[i], cid[i], nreq[i], mqt[i], est[i],
                backup=backup[i], fabric_idx=fi[i], link_instance=link[i],
                home=home[i], stages=tuple(pairs[so[i]:so[i + 1]]),
                req_ids=tuple(rid[ro[i]:ro[i + 1]]))
            for i in range(self.n_records)]

    def _effective(self):
        """Primary record ids + the effective record serving each (its
        adjacent backup when that is cheaper — build_timeline's rule)."""
        R = self.n_records
        primary = np.nonzero(~self.backup)[0]
        if primary.size == 0:
            return primary, primary, np.zeros(0, bool)
        nxt = np.minimum(primary + 1, R - 1)
        shadowed = ((primary + 1 < R) & self.backup[nxt]
                    & (self.chunk[nxt] == self.chunk[primary]))
        eff = np.where(shadowed & (self.est_cost_s[nxt]
                                   < self.est_cost_s[primary]), nxt, primary)
        return primary, eff, shadowed

    def critical_path_s(self) -> float:
        """_critical_path over the columns: max over primaries, a backup
        capping its own primary."""
        primary, _, shadowed = self._effective()
        if primary.size == 0:
            return 0.0
        R = self.n_records
        nxt = np.minimum(primary + 1, R - 1)
        cost = np.where(shadowed,
                        np.minimum(self.est_cost_s[primary],
                                   self.est_cost_s[nxt]),
                        self.est_cost_s[primary])
        return max(0.0, float(cost.max()))

    def flow_arrays(self) -> TL.FlowArrays:
        """The step's flow set for timeline.simulate_arrays — the columnar
        image of build_timeline(): one flow per primary record (its backup
        substituted when cheaper), wire stages bound to the record's
        (link_instance, fabric) resource, compute to the holder's SM, the
        rest to the requester's. Memoized per instance (columns are never
        mutated); the planner's step-replay cache forwards the memo so a
        repeated step skips the rebuild too."""
        fa = getattr(self, "_fa_memo", None)
        if fa is not None:
            return fa
        counts = np.diff(self.stage_off)
        if self.n_records and not self.backup.any() and counts.all():
            # fast path (the steady state: no straggler backups, every
            # record carries stages): flows ARE the records in order, so
            # the stage table is reused as-is — no gather, no compaction
            primary = eff = None
            F = self.n_records
            offsets = self.stage_off
            code = self.stage_code
            dur = self.stage_dur
            link_inst, fab = self.link_instance, self.fabric_idx
            hold, home = self.holder, self.home
        else:
            primary, eff, _ = self._effective()
            counts = self.stage_off[eff + 1] - self.stage_off[eff]
            keep = counts > 0
            primary, eff, counts = primary[keep], eff[keep], counts[keep]
            F = eff.shape[0]
            offsets = np.zeros(F + 1, np.int64)
            np.cumsum(counts, out=offsets[1:])
            # ragged gather of the effective records' stage rows
            flat = np.repeat(self.stage_off[eff] - offsets[:-1], counts) \
                + np.arange(offsets[-1])
            code = self.stage_code[flat]
            dur = self.stage_dur[flat]
            link_inst, fab = self.link_instance[eff], self.fabric_idx[eff]
            hold, home = self.holder[eff], self.home[eff]
        # per-flow resource codes (packed ints), then per-stage by class
        link_code = np.where(link_inst >= 0,
                             link_inst * _RES_SLOTS + 2 + fab, -1)
        holder_code = hold * _RES_SLOTS
        req_code = np.where(home >= 0, home, hold) * _RES_SLOTS
        fl = np.repeat(np.arange(F), counts)
        wire = TL.WIRE_CODE_MASK[code]
        holdm = TL.HOLDER_CODE_MASK[code]
        res_packed = np.where(wire, link_code[fl],
                              np.where(holdm, holder_code[fl], req_code[fl]))
        bound = res_packed >= 0
        uniq = np.unique(res_packed[bound])
        res = np.where(bound, np.searchsorted(uniq, res_packed), -1)

        def _meta() -> tuple:
            # reporting-only strings, built on first access (FlowArrays
            # materializes them lazily — the scheduler never reads them)
            e = np.arange(self.n_records) if eff is None else eff
            p = e if primary is None else primary
            prim_s = [PRIM_NAMES[c] for c in self.prim[e]]
            cid_s = [self.chunk_ids[c] for c in self.chunk[e]]
            keys = tuple(
                f"{pp}:{c}@{h}#{i}" for pp, c, h, i in
                zip(prim_s, cid_s, self.holder[e].tolist(), p.tolist()))
            return keys, tuple(prim_s), tuple(cid_s)

        fa = TL.FlowArrays(
            offsets=offsets, code=code, dur=dur, res=res,
            resources=tuple(_decode_res(int(c)) for c in uniq),
            meta_builder=_meta)
        self._fa_memo = fa
        return fa


def build_timeline(records: List["DispatchRecord"]) -> TL.Timeline:
    """One step's dispatch records as an overlap-aware schedule.

    A straggler backup replaces its own primary (adjacent record) when it
    is the cheaper path (the engine cancels the primary at the p99
    deadline — modeled as the faster of the two serving the chunk),
    mirroring _critical_path's min. Wire stages bind to the dispatch's
    (link_instance, fabric) resource, compute to the holder's SM,
    merge/splice/prefill to the requester's."""
    flows: List[TL.Flow] = []
    for i, r in enumerate(records):
        if r.backup:
            continue
        b = _backup_of(records, i)
        eff = b if b is not None and b.est_cost_s < r.est_cost_s else r
        if not eff.stages:
            continue
        link_res = (TL.link(eff.link_instance, eff.fabric_idx)
                    if eff.link_instance >= 0 else None)
        requester = eff.home if eff.home >= 0 else eff.holder
        flows.append(TL.transport_flow(
            f"{eff.primitive}:{eff.chunk_id}@{eff.holder}#{i}",
            eff.stages, link_res=link_res,
            holder_sm=TL.sm(eff.holder), requester_sm=TL.sm(requester),
            primitive=eff.primitive, chunk_id=eff.chunk_id))
    return TL.simulate(flows)
