"""Overlap-aware transport timeline for one decode step.

The cost model prices a dispatch as probe / transfer / compute / return /
merge stages (§4); a real NIC overlaps those stages ACROSS concurrent
flows while serializing the wire itself. The max-reduce the engine used
through PR 1 prices each dispatch independently and takes the max, which
makes fabric sharing invisible: four flows on one link cost the same as
one. This module is the event simulator that replaces it:

  * every dispatch becomes a Flow — an ordered list of Stages;
  * a wire stage (probe / transfer / return / pull / gather / index) occupies the
    flow's ("link", instance, fabric) resource EXCLUSIVELY: two flows never
    overlap on the same link — queueing is simulated, not priced (§8);
  * a compute stage occupies the holder's ("sm", instance) resource, so
    holder-side compute is charged per-instance occupancy (the §6.3 elbow's
    other half: a busy holder serializes its chunk groups);
  * requester-side stages (merge / splice / prefill / host) occupy the
    requester's SM;
  * stages of DIFFERENT flows on DIFFERENT resources overlap freely — the
    probe of flow B rides under the transfer of flow A.

simulate() runs greedy earliest-start list scheduling (deterministic:
ties break toward the earlier flow), which is work-conserving, so the
makespan is bracketed by

    max(flow serial time)  <=  makespan  <=  sum(all stage durations)

and a single flow's makespan IS the scalar cost-model price (the stage
durations come from cost_model.route_stages/fetch_stages/local_stages,
which sum to the closed forms exactly). tests/test_timeline.py and
tests/test_timeline_props.py pin these invariants down.

overlap_efficiency = makespan / sum-of-stages: 1.0 means the schedule is
fully serial (no overlap harvested); 1/n means n flows overlapped
perfectly. Lower is better.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

# ("link", instance, fabric_idx) — the shared wire anchored at an instance
# ("sm", instance, 0)            — an instance's compute occupancy
Resource = Tuple[str, int, int]

WIRE_STAGES = frozenset({"probe", "transfer", "return", "pull", "gather",
                         "index"})
HOLDER_STAGES = frozenset({"compute"})
# merge / splice / prefill / host (and anything unknown) land requester-side


def link(instance: int, fabric_idx: int) -> Resource:
    """The (link, fabric) wire resource anchored at `instance` (§8: the
    holder's NIC is what concurrent flows subscribe)."""
    return ("link", instance, fabric_idx)


def sm(instance: int) -> Resource:
    """An instance's compute-occupancy resource."""
    return ("sm", instance, 0)


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    duration_s: float
    resource: Optional[Resource] = None   # None: no shared resource


@dataclasses.dataclass(frozen=True)
class Flow:
    """One dispatch as an ordered stage chain (stages run sequentially
    within a flow; overlap happens only across flows)."""
    key: str
    stages: Tuple[Stage, ...]
    primitive: str = ""
    chunk_id: str = ""

    @property
    def serial_s(self) -> float:
        """The flow's independent (no-contention) price: what the old
        max-reduce charged it."""
        return sum(s.duration_s for s in self.stages)


def transport_flow(key: str, stages: Sequence[Tuple[str, float]], *,
                   link_res: Optional[Resource] = None,
                   holder_sm: Optional[Resource] = None,
                   requester_sm: Optional[Resource] = None,
                   primitive: str = "", chunk_id: str = "") -> Flow:
    """Build a Flow from a cost_model stage breakdown ((name, seconds)
    pairs), binding each stage to the wire / holder-SM / requester-SM
    resource by stage-name class."""
    bound: List[Stage] = []
    for name, dur in stages:
        if name in WIRE_STAGES:
            res = link_res
        elif name in HOLDER_STAGES:
            res = holder_sm
        else:
            res = requester_sm
        bound.append(Stage(name, float(dur), res))
    return Flow(key, tuple(bound), primitive, chunk_id)


@dataclasses.dataclass(frozen=True)
class ScheduledStage:
    flow_key: str
    stage: str
    resource: Optional[Resource]
    start_s: float
    end_s: float

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclasses.dataclass
class Timeline:
    """One step's schedule: where every stage landed, and the makespan."""
    flows: Tuple[Flow, ...]
    scheduled: List[ScheduledStage]
    makespan_s: float
    serial_s: float                    # sum of every stage duration

    @property
    def overlap_efficiency(self) -> float:
        """makespan / sum-of-stages; 1.0 = fully serial, 1/n = n flows
        perfectly overlapped. 1.0 for an empty timeline."""
        return self.makespan_s / self.serial_s if self.serial_s > 0 else 1.0

    @property
    def max_flow_serial_s(self) -> float:
        """The old max-reduce price of this flow set."""
        return max((f.serial_s for f in self.flows), default=0.0)

    def busy_s(self) -> Dict[Resource, float]:
        """Total occupied seconds per shared resource."""
        busy: Dict[Resource, float] = defaultdict(float)
        for s in self.scheduled:
            if s.resource is not None:
                busy[s.resource] += s.duration_s
        return dict(busy)

    def link_flow_counts(self) -> Dict[Resource, int]:
        """Distinct flows that touched each (link, fabric) resource — the
        OBSERVED per-link subscription the §8 k_flows premium models."""
        seen: Dict[Resource, set] = defaultdict(set)
        for s in self.scheduled:
            if s.resource is not None and s.resource[0] == "link":
                seen[s.resource].add(s.flow_key)
        return {r: len(ks) for r, ks in seen.items()}

    def utilization(self, resource: Resource) -> float:
        """Busy fraction of one resource over the makespan."""
        if self.makespan_s <= 0:
            return 0.0
        return self.busy_s().get(resource, 0.0) / self.makespan_s

    def stage_totals(self) -> Dict[str, float]:
        """Summed duration per stage name (the step's cost anatomy)."""
        tot: Dict[str, float] = defaultdict(float)
        for s in self.scheduled:
            tot[s.stage] += s.duration_s
        return dict(tot)

    def flow_end_s(self, key: str) -> float:
        return max((s.end_s for s in self.scheduled if s.flow_key == key),
                   default=0.0)

    def gantt(self, max_flows: int = 12) -> str:
        """Per-flow stage spans in microseconds, earliest flow first."""
        by_flow: Dict[str, List[ScheduledStage]] = defaultdict(list)
        for s in self.scheduled:
            by_flow[s.flow_key].append(s)
        rows = sorted(by_flow.items(),
                      key=lambda kv: min(s.start_s for s in kv[1]))
        lines = []
        for key, stages in rows[:max_flows]:
            spans = " ".join(
                f"{s.stage}[{s.start_s * 1e6:.0f}-{s.end_s * 1e6:.0f}us]"
                for s in sorted(stages, key=lambda s: s.start_s))
            lines.append(f"  {key:<32} {spans}")
        if len(rows) > max_flows:
            lines.append(f"  ... {len(rows) - max_flows} more flows")
        return "\n".join(lines)


def simulate(flows: Sequence[Flow]) -> Timeline:
    """Greedy earliest-start list scheduling over capacity-1 resources.

    Repeatedly schedules the ready stage (its flow's predecessors done)
    with the earliest feasible start = max(flow ready, resource free);
    ties break toward the earlier flow in input order, so the schedule is
    deterministic. Work-conserving: the machine is never idle while a
    stage could run, which gives makespan <= sum of all durations."""
    flows = tuple(flows)
    nxt = [0] * len(flows)                 # next stage index per flow
    ready = [0.0] * len(flows)             # flow's predecessor finish time
    free: Dict[Resource, float] = defaultdict(float)
    scheduled: List[ScheduledStage] = []
    remaining = sum(len(f.stages) for f in flows)
    serial = sum(f.serial_s for f in flows)
    makespan = 0.0
    while remaining:
        best_i, best_start = -1, None
        for i, f in enumerate(flows):
            if nxt[i] >= len(f.stages):
                continue
            st = f.stages[nxt[i]]
            start = (ready[i] if st.resource is None
                     else max(ready[i], free[st.resource]))
            if best_start is None or start < best_start:
                best_i, best_start = i, start
        f = flows[best_i]
        st = f.stages[nxt[best_i]]
        end = best_start + st.duration_s
        scheduled.append(ScheduledStage(f.key, st.name, st.resource,
                                        best_start, end))
        ready[best_i] = end
        if st.resource is not None:
            free[st.resource] = end
        nxt[best_i] += 1
        remaining -= 1
        makespan = max(makespan, end)
    return Timeline(flows, scheduled, makespan, serial)
