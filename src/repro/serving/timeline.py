"""Overlap-aware transport timeline for one decode step.

The cost model prices a dispatch as probe / transfer / compute / return /
merge stages (§4); a real NIC overlaps those stages ACROSS concurrent
flows while serializing the wire itself. The max-reduce the engine used
through PR 1 prices each dispatch independently and takes the max, which
makes fabric sharing invisible: four flows on one link cost the same as
one. This module is the event simulator that replaces it:

  * every dispatch becomes a Flow — an ordered list of Stages;
  * a wire stage (probe / transfer / return / pull / gather / index) occupies the
    flow's ("link", instance, fabric) resource EXCLUSIVELY: two flows never
    overlap on the same link — queueing is simulated, not priced (§8);
  * a compute stage occupies the holder's ("sm", instance) resource, so
    holder-side compute is charged per-instance occupancy (the §6.3 elbow's
    other half: a busy holder serializes its chunk groups);
  * requester-side stages (merge / splice / prefill / host) occupy the
    requester's SM;
  * stages of DIFFERENT flows on DIFFERENT resources overlap freely — the
    probe of flow B rides under the transfer of flow A.

simulate() runs greedy earliest-start list scheduling (deterministic:
ties break toward the earlier flow), which is work-conserving, so the
makespan is bracketed by

    max(flow serial time)  <=  makespan  <=  sum(all stage durations)

and a single flow's makespan IS the scalar cost-model price (the stage
durations come from cost_model.route_stages/fetch_stages/local_stages,
which sum to the closed forms exactly). tests/test_timeline.py and
tests/test_timeline_props.py pin these invariants down.

overlap_efficiency = makespan / sum-of-stages: 1.0 means the schedule is
fully serial (no overlap harvested); 1/n means n flows overlapped
perfectly. Lower is better.

Since ISSUE 6 the hot path is ARRAY-based: FlowArrays is the columnar
flow set (flat stage columns + ragged per-flow offsets), and
simulate_arrays() runs the SAME greedy earliest-start policy as an
event loop over a lazy-reevaluation heap — one candidate per flow keyed
by a lower-bound start estimate, refreshed on pop when stale. Ties pop
the smaller flow index first, exactly the object scheduler's scan
order, so the two schedules are identical stage-for-stage for all
non-negative durations (tests/test_plan_arrays.py asserts bit-equality
on randomized flow sets, zero durations included); negative durations —
never emitted by the cost model — fall back to the object oracle.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

# ("link", instance, fabric_idx) — the shared wire anchored at an instance
# ("sm", instance, 0)            — an instance's compute occupancy
Resource = Tuple[str, int, int]

WIRE_STAGES = frozenset({"probe", "transfer", "return", "pull", "gather",
                         "index"})
HOLDER_STAGES = frozenset({"compute"})
# merge / splice / prefill / host (and anything unknown) land requester-side

# Stage-name interning for the array scheduler (ISSUE 6): every stage the
# cost model emits, by a stable small-int code. FlowArrays carries codes,
# not strings; names reappear only at the reporting boundary
# (stage_totals / gantt).
STAGE_NAMES: Tuple[str, ...] = (
    "probe", "transfer", "compute", "return", "merge", "host",
    "pull", "splice", "gather", "index", "prefill")
STAGE_CODE: Dict[str, int] = {n: i for i, n in enumerate(STAGE_NAMES)}
# per-code resource class, aligned with the frozensets above
WIRE_CODE_MASK = np.array([n in WIRE_STAGES for n in STAGE_NAMES])
HOLDER_CODE_MASK = np.array([n in HOLDER_STAGES for n in STAGE_NAMES])


def link(instance: int, fabric_idx: int) -> Resource:
    """The (link, fabric) wire resource anchored at `instance` (§8: the
    holder's NIC is what concurrent flows subscribe)."""
    return ("link", instance, fabric_idx)


def sm(instance: int) -> Resource:
    """An instance's compute-occupancy resource."""
    return ("sm", instance, 0)


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    duration_s: float
    resource: Optional[Resource] = None   # None: no shared resource


@dataclasses.dataclass(frozen=True)
class Flow:
    """One dispatch as an ordered stage chain (stages run sequentially
    within a flow; overlap happens only across flows)."""
    key: str
    stages: Tuple[Stage, ...]
    primitive: str = ""
    chunk_id: str = ""

    @property
    def serial_s(self) -> float:
        """The flow's independent (no-contention) price: what the old
        max-reduce charged it."""
        return sum(s.duration_s for s in self.stages)


def transport_flow(key: str, stages: Sequence[Tuple[str, float]], *,
                   link_res: Optional[Resource] = None,
                   holder_sm: Optional[Resource] = None,
                   requester_sm: Optional[Resource] = None,
                   primitive: str = "", chunk_id: str = "") -> Flow:
    """Build a Flow from a cost_model stage breakdown ((name, seconds)
    pairs), binding each stage to the wire / holder-SM / requester-SM
    resource by stage-name class."""
    bound: List[Stage] = []
    for name, dur in stages:
        if name in WIRE_STAGES:
            res = link_res
        elif name in HOLDER_STAGES:
            res = holder_sm
        else:
            res = requester_sm
        bound.append(Stage(name, float(dur), res))
    return Flow(key, tuple(bound), primitive, chunk_id)


@dataclasses.dataclass(frozen=True)
class ScheduledStage:
    flow_key: str
    stage: str
    resource: Optional[Resource]
    start_s: float
    end_s: float

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclasses.dataclass
class Timeline:
    """One step's schedule: where every stage landed, and the makespan.

    The schedule is immutable once simulate() returns it; the per-flow and
    per-resource aggregates below are computed in ONE pass over `scheduled`
    on first use and memoized (ISSUE 6 satellite: flow_end_s /
    link_flow_counts used to rescan every stage per call — O(n^2) across a
    step report)."""
    flows: Tuple[Flow, ...]
    scheduled: List[ScheduledStage]
    makespan_s: float
    serial_s: float                    # sum of every stage duration
    _agg: Optional[dict] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def _aggregates(self) -> dict:
        if self._agg is None:
            busy: Dict[Resource, float] = defaultdict(float)
            seen: Dict[Resource, set] = defaultdict(set)
            totals: Dict[str, float] = defaultdict(float)
            ends: Dict[str, float] = {}
            for s in self.scheduled:
                totals[s.stage] += s.duration_s
                if s.end_s > ends.get(s.flow_key, 0.0):
                    ends[s.flow_key] = s.end_s
                if s.resource is not None:
                    busy[s.resource] += s.duration_s
                    if s.resource[0] == "link":
                        seen[s.resource].add(s.flow_key)
            self._agg = {
                "busy": dict(busy),
                "link_counts": {r: len(ks) for r, ks in seen.items()},
                "stage_totals": dict(totals),
                "flow_ends": ends,
            }
        return self._agg

    @property
    def overlap_efficiency(self) -> float:
        """makespan / sum-of-stages; 1.0 = fully serial, 1/n = n flows
        perfectly overlapped. 1.0 for an empty timeline."""
        return self.makespan_s / self.serial_s if self.serial_s > 0 else 1.0

    @property
    def max_flow_serial_s(self) -> float:
        """The old max-reduce price of this flow set."""
        return max((f.serial_s for f in self.flows), default=0.0)

    def busy_s(self) -> Dict[Resource, float]:
        """Total occupied seconds per shared resource."""
        return dict(self._aggregates()["busy"])

    def link_flow_counts(self) -> Dict[Resource, int]:
        """Distinct flows that touched each (link, fabric) resource — the
        OBSERVED per-link subscription the §8 k_flows premium models."""
        return dict(self._aggregates()["link_counts"])

    def utilization(self, resource: Resource) -> float:
        """Busy fraction of one resource over the makespan."""
        if self.makespan_s <= 0:
            return 0.0
        return self._aggregates()["busy"].get(resource, 0.0) / self.makespan_s

    def stage_totals(self) -> Dict[str, float]:
        """Summed duration per stage name (the step's cost anatomy)."""
        return dict(self._aggregates()["stage_totals"])

    def flow_end_s(self, key: str) -> float:
        return self._aggregates()["flow_ends"].get(key, 0.0)

    def gantt(self, max_flows: int = 12) -> str:
        """Per-flow stage spans in microseconds, earliest flow first."""
        by_flow: Dict[str, List[ScheduledStage]] = defaultdict(list)
        for s in self.scheduled:
            by_flow[s.flow_key].append(s)
        rows = sorted(by_flow.items(),
                      key=lambda kv: min(s.start_s for s in kv[1]))
        lines = []
        for key, stages in rows[:max_flows]:
            spans = " ".join(
                f"{s.stage}[{s.start_s * 1e6:.0f}-{s.end_s * 1e6:.0f}us]"
                for s in sorted(stages, key=lambda s: s.start_s))
            lines.append(f"  {key:<32} {spans}")
        if len(rows) > max_flows:
            lines.append(f"  ... {len(rows) - max_flows} more flows")
        return "\n".join(lines)


def simulate(flows: Sequence[Flow]) -> Timeline:
    """Greedy earliest-start list scheduling over capacity-1 resources.

    Repeatedly schedules the ready stage (its flow's predecessors done)
    with the earliest feasible start = max(flow ready, resource free);
    ties break toward the earlier flow in input order, so the schedule is
    deterministic. Work-conserving: the machine is never idle while a
    stage could run, which gives makespan <= sum of all durations."""
    flows = tuple(flows)
    nxt = [0] * len(flows)                 # next stage index per flow
    ready = [0.0] * len(flows)             # flow's predecessor finish time
    free: Dict[Resource, float] = defaultdict(float)
    scheduled: List[ScheduledStage] = []
    remaining = sum(len(f.stages) for f in flows)
    serial = sum(f.serial_s for f in flows)
    makespan = 0.0
    while remaining:
        best_i, best_start = -1, None
        for i, f in enumerate(flows):
            if nxt[i] >= len(f.stages):
                continue
            st = f.stages[nxt[i]]
            start = (ready[i] if st.resource is None
                     else max(ready[i], free[st.resource]))
            if best_start is None or start < best_start:
                best_i, best_start = i, start
        f = flows[best_i]
        st = f.stages[nxt[best_i]]
        end = best_start + st.duration_s
        scheduled.append(ScheduledStage(f.key, st.name, st.resource,
                                        best_start, end))
        ready[best_i] = end
        if st.resource is not None:
            free[st.resource] = end
        nxt[best_i] += 1
        remaining -= 1
        makespan = max(makespan, end)
    return Timeline(flows, scheduled, makespan, serial)


# ---------------------------------------------------------------------------
# Array scheduler (ISSUE 6): the same greedy policy, vectorized.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FlowArrays:
    """Columnar flow set: one flat stage table (code / duration / resource
    id) plus ragged per-flow offsets. Resource ids index `resources`
    (-1 = no shared resource); flow order is schedule-tie order, exactly as
    a Flow sequence's input order is for simulate().

    keys / primitives / chunk_ids are reporting-only strings the scheduler
    never reads; a builder may defer them via `meta_builder` — a zero-arg
    callable returning the (keys, primitives, chunk_ids) triple — so the
    hot path skips string construction entirely (they materialize on
    first access)."""
    offsets: np.ndarray                  # (F+1,) int64 stage ranges
    code: np.ndarray                     # (S,) int64 STAGE_NAMES index
    dur: np.ndarray                      # (S,) float64 stage durations
    res: np.ndarray                      # (S,) int64 -> resources, -1 none
    resources: Tuple[Resource, ...]
    keys: dataclasses.InitVar[Optional[Tuple[str, ...]]] = None
    primitives: dataclasses.InitVar[Tuple[str, ...]] = ()
    chunk_ids: dataclasses.InitVar[Tuple[str, ...]] = ()
    meta_builder: Optional[Callable[[], tuple]] = None

    def __post_init__(self, keys, primitives, chunk_ids):
        self._keys = keys
        self._primitives = primitives
        self._chunk_ids = chunk_ids

    def _meta(self) -> None:
        self._keys, self._primitives, self._chunk_ids = self.meta_builder()

    @property
    def n_flows(self) -> int:
        return len(self.offsets) - 1

    def flow_of_stage(self) -> np.ndarray:
        """(S,) flow index per flat stage."""
        return np.repeat(np.arange(self.n_flows), np.diff(self.offsets))

    @classmethod
    def from_flows(cls, flows: Sequence[Flow]) -> "FlowArrays":
        flows = tuple(flows)
        res_index: Dict[Resource, int] = {}
        offsets = [0]
        code: List[int] = []
        dur: List[float] = []
        res: List[int] = []
        for f in flows:
            for s in f.stages:
                code.append(STAGE_CODE[s.name])
                dur.append(s.duration_s)
                if s.resource is None:
                    res.append(-1)
                else:
                    res.append(res_index.setdefault(s.resource,
                                                    len(res_index)))
            offsets.append(len(code))
        return cls(
            offsets=np.asarray(offsets, np.int64),
            code=np.asarray(code, np.int64),
            dur=np.asarray(dur, np.float64),
            res=np.asarray(res, np.int64),
            resources=tuple(res_index),
            keys=tuple(f.key for f in flows),
            primitives=tuple(f.primitive for f in flows),
            chunk_ids=tuple(f.chunk_id for f in flows))

    def to_flows(self) -> Tuple[Flow, ...]:
        """Object flows (the oracle scheduler's input form)."""
        prims = self.primitives or ("",) * self.n_flows
        cids = self.chunk_ids or ("",) * self.n_flows
        flows = []
        for i in range(self.n_flows):
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            stages = tuple(
                Stage(STAGE_NAMES[int(self.code[j])], float(self.dur[j]),
                      None if self.res[j] < 0
                      else self.resources[int(self.res[j])])
                for j in range(lo, hi))
            flows.append(Flow(self.keys[i], stages, prims[i], cids[i]))
        return tuple(flows)


def _fa_keys(self: "FlowArrays") -> Tuple[str, ...]:
    if self._keys is None and self.meta_builder is not None:
        self._meta()
    return self._keys


def _fa_primitives(self: "FlowArrays") -> Tuple[str, ...]:
    if self._keys is None and self.meta_builder is not None:
        self._meta()
    return self._primitives


def _fa_chunk_ids(self: "FlowArrays") -> Tuple[str, ...]:
    if self._keys is None and self.meta_builder is not None:
        self._meta()
    return self._chunk_ids


# attached after class creation: plain properties in the class body would
# be mistaken for the InitVar defaults by @dataclass (same pattern as
# StepPlan.records in serving/plan.py)
FlowArrays.keys = property(_fa_keys)
FlowArrays.primitives = property(_fa_primitives)
FlowArrays.chunk_ids = property(_fa_chunk_ids)


@dataclasses.dataclass
class ArrayTimeline:
    """simulate_arrays()' result: the same schedule simulate() produces,
    kept columnar. Duck-types Timeline's reporting surface (makespan_s,
    serial_s, stage_totals, busy_s, link_flow_counts, flow_end_s,
    utilization, overlap_efficiency, max_flow_serial_s, gantt); aggregates
    are computed once from the arrays at construction."""
    arrays: FlowArrays
    start_s: np.ndarray                  # (S,) per flat stage
    end_s: np.ndarray
    order: np.ndarray                    # flat stage ids, schedule order
    makespan_s: float
    serial_s: float
    _stage_totals: Dict[str, float]
    _busy: Dict[Resource, float]
    _link_counts: Dict[Resource, int]
    _flow_serial: np.ndarray             # (F,) per-flow serial price
    _flow_end: np.ndarray                # (F,) per-flow finish time
    _key_index: Optional[Dict[str, int]] = dataclasses.field(
        default=None, repr=False, compare=False)
    _flows: Optional[Tuple[Flow, ...]] = dataclasses.field(
        default=None, repr=False, compare=False)
    _scheduled: Optional[List[ScheduledStage]] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def flows(self) -> Tuple[Flow, ...]:
        """Object-form flows, materialized on demand (inspection surface —
        the hot path never touches this)."""
        if self._flows is None:
            self._flows = tuple(self.arrays.to_flows())
        return self._flows

    @property
    def scheduled(self) -> List[ScheduledStage]:
        """Object-form schedule in scheduled order, materialized on demand
        (matches Timeline.scheduled entry-for-entry)."""
        if self._scheduled is None:
            fa = self.arrays
            flow_of = fa.flow_of_stage()
            res = fa.res
            self._scheduled = [
                ScheduledStage(
                    fa.keys[int(flow_of[j])], STAGE_NAMES[int(fa.code[j])],
                    fa.resources[res[j]] if res[j] >= 0 else None,
                    float(self.start_s[j]), float(self.end_s[j]))
                for j in self.order.tolist()]
        return self._scheduled

    @property
    def overlap_efficiency(self) -> float:
        return self.makespan_s / self.serial_s if self.serial_s > 0 else 1.0

    @property
    def max_flow_serial_s(self) -> float:
        return float(self._flow_serial.max()) if self._flow_serial.size \
            else 0.0

    def busy_s(self) -> Dict[Resource, float]:
        return dict(self._busy)

    def link_flow_counts(self) -> Dict[Resource, int]:
        return dict(self._link_counts)

    def utilization(self, resource: Resource) -> float:
        if self.makespan_s <= 0:
            return 0.0
        return self._busy.get(resource, 0.0) / self.makespan_s

    def stage_totals(self) -> Dict[str, float]:
        return dict(self._stage_totals)

    def flow_end_s(self, key: str) -> float:
        if self._key_index is None:
            self._key_index = {k: i for i, k in enumerate(self.arrays.keys)}
        i = self._key_index.get(key)
        return float(self._flow_end[i]) if i is not None else 0.0

    def gantt(self, max_flows: int = 12) -> str:
        """Per-flow stage spans in microseconds, earliest flow first
        (matches Timeline.gantt — stages within a flow are sequential, so
        flat order is start order)."""
        fa = self.arrays
        rows = sorted(
            (i for i in range(fa.n_flows)
             if fa.offsets[i] < fa.offsets[i + 1]),
            key=lambda i: float(self.start_s[fa.offsets[i]]))
        lines = []
        for i in rows[:max_flows]:
            spans = " ".join(
                f"{STAGE_NAMES[int(fa.code[j])]}"
                f"[{self.start_s[j] * 1e6:.0f}-{self.end_s[j] * 1e6:.0f}us]"
                for j in range(int(fa.offsets[i]), int(fa.offsets[i + 1])))
            lines.append(f"  {fa.keys[i]:<32} {spans}")
        if len(rows) > max_flows:
            lines.append(f"  ... {len(rows) - max_flows} more flows")
        return "\n".join(lines)


def _seq_sum(values: np.ndarray) -> float:
    """Left-to-right float64 sum — the accumulation order Python's sum()
    uses. np.sum pairwise-reduces, which rounds DIFFERENTLY; bit-parity
    with the object oracle needs the sequential order."""
    acc = np.zeros(1, np.float64)
    np.add.at(acc, np.zeros(len(values), np.intp), values)
    return float(acc[0])


# schedule memo: simulate_arrays is a pure function of the flow STRUCTURE
# (offsets / durations / resource binding / stage codes) — requester
# identity lives only in the lazy metadata, so steady-state steps whose
# transports repeat bit-for-bit (same groups, same durations) reuse the
# computed schedule outright. The fingerprint covers every input the
# scheduler reads, so a hit is exact by construction.
_SIM_MEMO: Dict[tuple, tuple] = {}
_SIM_MEMO_CAP = 512

# schedule-memo effectiveness counters (ISSUE 9): module-global like the
# memo itself. inst_hit = the planner handed back the SAME FlowArrays
# object (step replay), memo_hit = structure-fingerprint hit, miss = the
# heap scheduler actually ran. Published via the obs metrics registry.
_SIM_STATS = {"inst_hit": 0, "memo_hit": 0, "miss": 0}


def sim_memo_stats() -> Dict[str, int]:
    """Snapshot of the _SIM_MEMO hit/miss counters."""
    return dict(_SIM_STATS)


# ---------------------------------------------------------------------------
# Measured-vs-analytic report (ISSUE 7): the shard_map exec backend records
# WALL-CLOCK per-stage durations for every dispatch it executes on the real
# device mesh; re-scheduling those measured flows through the same greedy
# simulator yields a measured timeline directly comparable to the analytic
# one — the paper's §7 model-validation loop, in-repo and continuous.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MeasuredReport:
    """One step's measured-vs-analytic comparison.

    analytic — the schedule the cost model priced (fabric constants of the
        PLANNED hardware: probe floors, link bandwidths, HBM sweeps);
    measured — the SAME flow structure (keys, resource binding, stage
        order) re-simulated with per-stage wall-clock durations recorded
        around the real collectives. Absolute ratios are only meaningful
        when the fabric table was calibrated for the executing hardware
        (benchmarks/calibrate_fabric.py); on forced host devices the value
        of the report is the SHAPE agreement — which stages dominate,
        how much overlap the schedule harvests — and the machinery itself.
    """
    step: int
    analytic: Union[Timeline, "ArrayTimeline"]
    measured: Timeline
    wall_s: float = 0.0                 # end-to-end execute() wall clock
    # execution-path telemetry (ISSUE 8): how the measured side ran
    mode: str = "serial"                # "serial" per-stage | "fused" overlap
    pool_entries: int = 0               # committed-copy cache population
    pool_bytes: int = 0                 # ... and its device-buffer bytes
    stage_fills: int = 0                # stage measurements apportioning had
    #                                     to invent (planned durations all 0
    #                                     or a serial stage went unmeasured)

    def stage_rows(self) -> List[Tuple[str, float, float, float]]:
        """(stage, analytic_s, measured_s, measured/analytic) per stage
        name, in STAGE_NAMES order; ratio is inf when analytic is 0."""
        a, m = self.analytic.stage_totals(), self.measured.stage_totals()
        rows = []
        for name in STAGE_NAMES:
            if name not in a and name not in m:
                continue
            av, mv = a.get(name, 0.0), m.get(name, 0.0)
            rows.append((name, av, mv, mv / av if av > 0 else float("inf")))
        return rows

    @property
    def makespan_ratio(self) -> float:
        a = self.analytic.makespan_s
        return self.measured.makespan_s / a if a > 0 else float("inf")

    @property
    def overlap_efficiency(self) -> float:
        """Measured makespan / sum of measured group walls: < 1.0 means
        the executor actually ran independent groups concurrently."""
        total = sum(self.measured.stage_totals().values())
        return self.measured.makespan_s / total if total > 0 else 1.0

    def summary(self) -> str:
        lines = [
            f"step {self.step}: makespan analytic "
            f"{self.analytic.makespan_s * 1e6:9.1f}us  measured "
            f"{self.measured.makespan_s * 1e6:9.1f}us  "
            f"(x{self.makespan_ratio:.2f}, exec wall "
            f"{self.wall_s * 1e3:.1f}ms, {self.mode}, "
            f"pool {self.pool_entries}/{self.pool_bytes}B"
            + (f", {self.stage_fills} stage fills" if self.stage_fills
               else "") + ")"]
        for name, av, mv, ratio in self.stage_rows():
            lines.append(f"  {name:<9} analytic {av * 1e6:9.1f}us  "
                         f"measured {mv * 1e6:9.1f}us  (x{ratio:.2f})")
        return "\n".join(lines)


def measured_vs_analytic(step: int,
                         analytic: Union[Timeline, "ArrayTimeline"],
                         measured_flows: Sequence[Flow],
                         wall_s: float = 0.0, *, mode: str = "serial",
                         pool_entries: int = 0, pool_bytes: int = 0,
                         stage_fills: int = 0) -> MeasuredReport:
    """Schedule the measured flows (same greedy policy as the analytic
    side) and pair the two timelines into a MeasuredReport."""
    return MeasuredReport(step, analytic, simulate(measured_flows), wall_s,
                          mode=mode, pool_entries=pool_entries,
                          pool_bytes=pool_bytes, stage_fills=stage_fills)


def simulate_arrays(fa: FlowArrays) -> Union["ArrayTimeline", Timeline]:
    """Greedy earliest-start list scheduling via a lazy-reevaluation heap.

    One candidate per flow lives in the heap, keyed (start_estimate,
    flow_index). An estimate is computed from resource-free times at push
    time; free times only move forward, so every key is a LOWER bound on
    the candidate's true start. Popping the heap minimum and recomputing:
    if the true start equals the key, every other candidate's true start
    is >= its key >= ours, and equal-key ties pop the smaller flow index
    first — exactly the object scheduler's scan order — so scheduling it
    IS the greedy choice. If the key went stale, re-push with the fresh
    start and continue. Stage-for-stage identical to simulate() for any
    non-negative durations (zero-duration stages included — the selection
    regime emits them when sel_frac is 0); negative durations would break
    free-time monotonicity, so that never-emitted corner is delegated to
    the object oracle.

    The loop is plain Python over pre-extracted lists: per stage it costs
    a heappop, two list reads and at most one heappush — ~10x fewer
    interpreter-level operations than one numpy round of the previous
    round-based scheduler, and the bench's per-step flow sets (tens of
    flows, hundreds of stages) are far below numpy's vectorization
    break-even."""
    # instance memo first: the planner's step-replay cache hands the SAME
    # FlowArrays object back for a repeated step, so not even the byte
    # fingerprint needs recomputing
    inst_cached = getattr(fa, "_sim_memo", None)
    if inst_cached is not None:
        _SIM_STATS["inst_hit"] += 1
        return ArrayTimeline(fa, *inst_cached)
    S = int(fa.dur.shape[0])
    F = fa.n_flows
    if S and float(fa.dur.min()) < 0.0:
        return simulate(fa.to_flows())
    memo_key = (F, fa.offsets.tobytes(), fa.dur.tobytes(), fa.res.tobytes(),
                fa.code.tobytes(), fa.resources)
    cached = _SIM_MEMO.get(memo_key)
    if cached is not None:
        _SIM_STATS["memo_hit"] += 1
        fa._sim_memo = cached
        return ArrayTimeline(fa, *cached)
    _SIM_STATS["miss"] += 1
    off_l = fa.offsets.tolist()
    dur_l = fa.dur.tolist()
    res_l = fa.res.tolist()
    code_l = fa.code.tolist()
    is_link_l = [rsc[0] == "link" for rsc in fa.resources]
    free = [0.0] * max(1, len(fa.resources))
    start_l = [0.0] * S
    end_l = [0.0] * S
    order_l = [0] * S
    n_done = 0
    makespan = 0.0
    # aggregates, accumulated inline in the oracle's order: stage totals
    # and resource busy as left-to-right float adds in SCHEDULE order
    # (exactly np.add.at over the order array), flow end as an
    # order-independent max
    tot = [0.0] * len(STAGE_NAMES)
    code_seen = [False] * len(STAGE_NAMES)
    busy_l = [0.0] * max(1, len(fa.resources))
    flow_end_l = [0.0] * F
    link_seen: set = set()               # distinct (link res, flow) pairs
    flow_serial_l = [0.0] * F
    # heap entries are (start_estimate, flow); flow is unique per entry
    # (exactly one candidate per unfinished flow), so the 2-tuple orders
    # identically to any longer key — the candidate's flat stage and
    # flow-ready time live in the ptr / rdyf side lists instead
    ptr = off_l[:F]                      # next flat stage per flow
    rdyf = [0.0] * F                     # flow-ready (prev stage end)
    heap = [(0.0, f) for f in range(F) if off_l[f] < off_l[f + 1]]
    push, pop = heapq.heappush, heapq.heappop
    while heap:
        s, f = pop(heap)
        j = ptr[f]
        r = res_l[j]
        if r >= 0:
            fr = free[r]
            rdy = rdyf[f]
            true_s = fr if fr > rdy else rdy
            if true_s > s:                   # stale estimate — refresh
                push(heap, (true_s, f))
                continue
        dj = dur_l[j]
        e = s + dj
        start_l[j] = s
        end_l[j] = e
        order_l[n_done] = j
        n_done += 1
        d = e - s                        # == ScheduledStage.duration_s
        c = code_l[j]
        tot[c] += d
        code_seen[c] = True
        # per-flow serial accumulates raw durations in stage order (a
        # flow's stages schedule in order, so this IS left-to-right)
        flow_serial_l[f] += dj
        if r >= 0:
            free[r] = e
            busy_l[r] += d
            if is_link_l[r]:
                link_seen.add((r, f))
        # a flow's stage ends are monotone (non-negative durations), so the
        # last write wins and the makespan is recovered post-loop as the
        # max over flow ends — both exact float maxes, no arithmetic
        flow_end_l[f] = e
        nj = j + 1
        if nj < off_l[f + 1]:
            ptr[f] = nj
            rdyf[f] = e
            nr = res_l[nj]
            if nr >= 0:
                fr = free[nr]
                push(heap, (fr if fr > e else e, f))
            else:
                push(heap, (e, f))
    if flow_end_l:
        makespan = max(flow_end_l)
    start_s = np.array(start_l, np.float64)
    end_s = np.array(end_l, np.float64)
    order = np.array(order_l, np.int64)

    # cross-flow serial sum in flow order — the oracle's accumulation
    serial = 0.0
    for fs in flow_serial_l:
        serial += fs
    stage_totals = {STAGE_NAMES[c]: tot[c]
                    for c in range(len(STAGE_NAMES)) if code_seen[c]}
    busy = {rsc: busy_l[i] for i, rsc in enumerate(fa.resources)}
    # distinct flows per link: unique (resource, flow) pairs, counted
    link_counts: Dict[Resource, int] = {}
    if any(is_link_l):
        lcnt = [0] * len(fa.resources)
        for r, _ in link_seen:
            lcnt[r] += 1
        link_counts = {rsc: lcnt[i] for i, rsc in enumerate(fa.resources)
                       if is_link_l[i]}
    out = (start_s, end_s, order, makespan, serial, stage_totals, busy,
           link_counts, np.array(flow_serial_l, np.float64),
           np.array(flow_end_l, np.float64))
    if len(_SIM_MEMO) >= _SIM_MEMO_CAP:
        _SIM_MEMO.clear()
    _SIM_MEMO[memo_key] = out
    fa._sim_memo = out
    return ArrayTimeline(fa, *out)
