"""AnalyticBackend: the PR-2 timeline as an ExecutionBackend.

Executing a plan analytically = scheduling its DispatchRecords on the
overlap-aware transport timeline (wire stages serialize per (link, fabric),
holder compute per-instance). No arrays move; StepStats derived from this
backend are bit-identical to the pre-split engine — the golden JSON
fixtures of tests/test_engine_golden.py enforce that.

Since ISSUE 6 a plan carrying its columnar form (StepPlan.arrays, the
array planner's output) is scheduled by timeline.simulate_arrays — the
lazy-heap event scheduler — instead of the per-stage O(stages x flows)
rescan loop. The two produce the same schedule stage-for-stage, so the
golden fixtures hold bit-identically on either path.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from repro.serving import timeline as TL
from repro.serving.backends.base import StepExecution, StepTicket
from repro.serving.plan import StepPlan, build_timeline

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine


class AnalyticBackend:
    name = "analytic"

    def execute(self, engine: "ServingEngine",
                plan: StepPlan) -> StepExecution:
        if plan.arrays is not None:
            timeline = TL.simulate_arrays(plan.arrays.flow_arrays())
        else:
            timeline = build_timeline(plan.records)
        return StepExecution(timeline=timeline, backend=self.name)

    # simulation has no device work to defer: submit IS execute (ISSUE 10)

    def submit(self, engine: "ServingEngine", plan: StepPlan) -> StepTicket:
        return StepTicket(plan=plan, execution=self.execute(engine, plan))

    def await_result(self, engine: "ServingEngine",
                     ticket: StepTicket) -> StepExecution:
        return ticket.execution
