"""AnalyticBackend: the PR-2 timeline as an ExecutionBackend.

Executing a plan analytically = scheduling its DispatchRecords on the
overlap-aware transport timeline (wire stages serialize per (link, fabric),
holder compute per-instance). No arrays move; StepStats derived from this
backend are bit-identical to the pre-split engine — the golden JSON
fixtures of tests/test_engine_golden.py enforce that.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from repro.serving.backends.base import StepExecution
from repro.serving.plan import StepPlan, build_timeline

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine


class AnalyticBackend:
    name = "analytic"

    def execute(self, engine: "ServingEngine",
                plan: StepPlan) -> StepExecution:
        return StepExecution(timeline=build_timeline(plan.records),
                             backend=self.name)
