"""Pluggable EXECUTE layer for the serving engine (plan / execute / account).

The planner (repro.serving.engine) emits a StepPlan; a backend runs it:

* AnalyticBackend — schedules the plan on the PR-2 overlap-aware transport
  timeline. Pure simulation: StepStats are bit-identical to the pre-split
  engine (the golden fixtures of tests/test_engine_golden.py pin this).
* JaxExecBackend  — ALSO executes the planned attention on real c^KV
  arrays (materialized in the chunk store): ROUTE via core.routing,
  FETCH via the core.splice replication path followed by local attention,
  LOCAL via absorbed_partial + merge. Returns actual decode outputs next
  to the analytic stage costs, so the §3.3 exactness claim is testable
  end-to-end THROUGH the scheduler, not just at the kernel layer.
* ShardMapExecBackend — the multi-host form (ISSUE 7): the chunk store's
  canonical arrays partition across a device-mesh "instance" axis and
  every planned transport runs as a REAL collective inside shard_map
  (route_pairwise / route_fanout for ROUTE, core.splice.fetch_chunk /
  fetch_scattered_gather for FETCH), with per-stage wall timings fed back
  through timeline.measured_vs_analytic — the paper's §7 loop.
"""

from repro.serving.backends.base import ExecutionBackend, StepExecution
from repro.serving.backends.analytic import AnalyticBackend

__all__ = ["ExecutionBackend", "StepExecution", "AnalyticBackend",
           "JaxExecBackend", "ShardMapExecBackend", "TINY_MLA"]

_LAZY = ("JaxExecBackend", "TINY_MLA")
_LAZY_SHARD = ("ShardMapExecBackend",)


def __getattr__(name: str):
    # jax_exec / shard_map pull in jax; the planner + analytic backend are
    # numpy-only and must stay importable without it (chunk_store's
    # documented contract), so the exec backends load on first use.
    if name in _LAZY:
        from repro.serving.backends import jax_exec
        return getattr(jax_exec, name)
    if name in _LAZY_SHARD:
        from repro.serving.backends import shard_map
        return getattr(shard_map, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
