"""Pluggable EXECUTE layer for the serving engine (plan / execute / account).

The planner (repro.serving.engine) emits a StepPlan; a backend runs it:

* AnalyticBackend — schedules the plan on the PR-2 overlap-aware transport
  timeline. Pure simulation: StepStats are bit-identical to the pre-split
  engine (the golden fixtures of tests/test_engine_golden.py pin this).
* JaxExecBackend  — ALSO executes the planned attention on real c^KV
  arrays (materialized in the chunk store): ROUTE via core.routing,
  FETCH via the core.splice replication path followed by local attention,
  LOCAL via absorbed_partial + merge. Returns actual decode outputs next
  to the analytic stage costs, so the §3.3 exactness claim is testable
  end-to-end THROUGH the scheduler, not just at the kernel layer.

Later PRs swap in further backends (multi-host shard_map execution,
overlapped real transfers) without touching the planner.
"""

from repro.serving.backends.base import ExecutionBackend, StepExecution
from repro.serving.backends.analytic import AnalyticBackend

__all__ = ["ExecutionBackend", "StepExecution", "AnalyticBackend",
           "JaxExecBackend", "TINY_MLA"]

_LAZY = ("JaxExecBackend", "TINY_MLA")


def __getattr__(name: str):
    # jax_exec pulls in jax; the planner + analytic backend are numpy-only
    # and must stay importable without it (chunk_store's documented
    # contract), so the exec backend loads on first use.
    if name in _LAZY:
        from repro.serving.backends import jax_exec
        return getattr(jax_exec, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
