"""JaxExecBackend: run dispatch plans on real jax arrays.

The planner decides ROUTE / FETCH / LOCAL per (holder, chunk, fabric)
group; this backend EXECUTES those decisions:

* chunks materialize as real c^KV arrays (S, d_qk) in the chunk store —
  deterministic per chunk_id, so a re-run (or the exactness oracle) sees
  the same cache bytes;
* ROUTE — the grouped requesters' query tensors are stacked into one
  holder-side batched partial (core.routing.route_batched: the §6.3
  "batched partial is ~free" holder kernel), sliced back per request,
  merged requester-side. The query moved, the cache did not.
* FETCH — the chunk replicates through the core.splice path (delta-0
  re-home: the rotation is the identity, §6.3 true-prefix case), the copy
  is stored as the replica's array, and the requesters attend it LOCALLY —
  the cache moved, exactly as priced.
* LOCAL — re-prefill: the canonical entries are recomputed at the
  requester (same deterministic materialization) and attended locally.
* resident pairs (no transport planned) attend their local copy.

Every request's per-chunk partials merge through the online-softmax merge
(core.merge) — associative + commutative with identity — so the final
output per request equals single-instance attention over the concatenated
chunks to float round-off REGARDLESS of which primitive the predicate
picked (§3.3, now end-to-end through the scheduler).

The analytic stage costs ride along unchanged: the returned timeline is
the same schedule the AnalyticBackend produces, so planner parity and
StepStats parity hold by construction.
"""

from __future__ import annotations

import zlib
from collections import defaultdict
from typing import Dict, List, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core.chunk_store import ChunkStore
from repro.core.merge import Partial, merge_tree
from repro.core.routing import route_batched
from repro.core.splice import splice_delta_rotate
from repro.models.mla import MLAConfig, absorbed_partial
from repro.serving.backends.base import StepExecution
from repro.serving.plan import Request, StepPlan, build_timeline

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine


# Execution geometry for CPU-scale tests and the serve CLI: d_qk = 24.
# The PLANNER's costs always use the paper payload (cfg.payload on the
# engine) — primitive decisions are invariant to the execution geometry,
# which is what makes analytic-vs-exec planner parity exact.
TINY_MLA = MLAConfig(d_model=64, n_heads=2, kv_lora_rank=16,
                     qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=8)


def _stable_seed(*parts) -> int:
    """Deterministic 32-bit seed from stringable parts (NOT Python hash(),
    which is salted per process)."""
    return zlib.crc32(":".join(str(p) for p in parts).encode())


def chunk_array(cfg: MLAConfig, chunk_id: str, length: int,
                dtype=jnp.float32) -> jax.Array:
    """The canonical c^KV array of a chunk: (length, d_qk), deterministic
    in chunk_id — re-prefill (LOCAL) regenerates exactly these entries."""
    key = jax.random.PRNGKey(_stable_seed("ckv", chunk_id))
    return jax.random.normal(key, (length, cfg.d_qk), dtype)


def query_for(cfg: MLAConfig, rq: Request, step: int,
              dtype=jnp.float32) -> jax.Array:
    """The request's absorbed decode queries this step: (m_q, H, d_qk),
    deterministic in (query_seed, step). The oracle in tests regenerates
    the identical tensor."""
    seed = rq.req_id if rq.query_seed is None else rq.query_seed
    key = jax.random.fold_in(jax.random.PRNGKey(_stable_seed("q", seed)),
                             step)
    return jax.random.normal(key, (rq.m_q, cfg.n_heads, cfg.d_qk), dtype)


def oracle_partial(cfg: MLAConfig, store: ChunkStore, rq: Request,
                   step: int, dtype=jnp.float32) -> Partial:
    """The §3.3 exactness reference: single-instance attention over the
    request's CONCATENATED chunks (canonical arrays, same query tensor the
    backend materialized). Every exec-backend consumer (tests, benchmarks,
    the serve CLI's --verify, examples) checks against THIS — one oracle,
    so query/chunk materialization can never silently diverge from it."""
    q = query_for(cfg, rq, step, dtype)
    cat = jnp.concatenate([store.lookup(c).data for c in rq.chunk_ids],
                          axis=0)
    return absorbed_partial(cfg, q, cat)


def max_oracle_err(engine: "ServingEngine", reqs: List[Request],
                   step: int) -> float:
    """Worst |exec output - oracle| over a step's requests. The engine
    must be running a JaxExecBackend (its cfg/dtype define the oracle)."""
    backend = engine.backend
    outs = engine.outputs_of(step)
    worst = 0.0
    for rq in reqs:
        want = oracle_partial(backend.cfg, engine.store, rq, step,
                              backend.dtype)
        worst = max(worst, float(jnp.max(
            jnp.abs(outs[rq.req_id].o - want.o))))
    return worst


class JaxExecBackend:
    """Execute StepPlans on real arrays. cfg sets the EXECUTION geometry
    (array shapes); it is independent of the planner's cost payload."""

    name = "exec"

    def __init__(self, cfg: MLAConfig = TINY_MLA, dtype=jnp.float32):
        self.cfg = cfg
        self.dtype = dtype

    # -- materialization ----------------------------------------------------

    def ensure_chunk_data(self, store: ChunkStore,
                          chunk_id: str) -> jax.Array:
        """Canonical array of chunk_id, materializing it on first touch."""
        chunk = store.lookup(chunk_id)
        if chunk.data is None:
            store.attach_data(
                chunk_id, chunk_array(self.cfg, chunk_id, chunk.length,
                                      self.dtype))
        return chunk.data

    def _array_on(self, store: ChunkStore, chunk_id: str,
                  instance: int) -> jax.Array:
        """The copy instance would attend: its replica array if the exec
        path produced one, else the canonical array (replicas created
        outside the exec path — e.g. hand-seeded in examples — fall back
        to canonical bytes, which is what a real pull would deliver)."""
        arr = store.array_on(chunk_id, instance)
        return arr if arr is not None else self.ensure_chunk_data(store,
                                                                  chunk_id)

    # -- execution ----------------------------------------------------------

    def execute(self, engine: "ServingEngine",
                plan: StepPlan) -> StepExecution:
        store = engine.store
        reqs: Dict[int, Request] = {rq.req_id: rq for rq in plan.requests}
        queries: Dict[int, jax.Array] = {}

        def q_of(rid: int) -> jax.Array:
            if rid not in queries:
                queries[rid] = query_for(self.cfg, reqs[rid], plan.step,
                                         self.dtype)
            return queries[rid]

        parts: Dict[int, List[Partial]] = defaultdict(list)

        # resident accesses: local attention on the instance's copy
        for rp in plan.resident_pairs:
            arr = self._array_on(store, rp.chunk_id, rp.instance)
            parts[rp.req_id].append(
                absorbed_partial(self.cfg, q_of(rp.req_id), arr))

        for rec in plan.records:
            if rec.backup or not rec.req_ids:
                continue
            if rec.primitive == "route":
                self._exec_route(store, rec, q_of, parts)
            elif rec.primitive in ("fetch", "fetch_replica"):
                self._exec_fetch(store, rec, q_of, parts)
            else:                                     # local re-prefill
                arr = self.ensure_chunk_data(store, rec.chunk_id)
                for rid in rec.req_ids:
                    parts[rid].append(
                        absorbed_partial(self.cfg, q_of(rid), arr))

        outputs = {rid: merge_tree(ps) for rid, ps in parts.items()}
        return StepExecution(timeline=build_timeline(plan.records),
                             outputs=outputs, backend=self.name)

    def _exec_route(self, store: ChunkStore, rec, q_of, parts) -> None:
        """One batched dispatch: stack the group's queries, one holder-side
        partial over the holder's resident copy, slice back per request."""
        holder_arr = self._array_on(store, rec.chunk_id, rec.holder)
        qs = [q_of(rid) for rid in rec.req_ids]
        stacked = jnp.concatenate(qs, axis=0) if len(qs) > 1 else qs[0]
        merged = route_batched(self.cfg, [stacked], [[holder_arr]])[0]
        off = 0
        for rid, q in zip(rec.req_ids, qs):
            n = q.shape[0]
            parts[rid].append(Partial(o=merged.o[off:off + n],
                                      m=merged.m[off:off + n],
                                      l=merged.l[off:off + n]))
            off += n

    def _exec_fetch(self, store: ChunkStore, rec, q_of, parts) -> None:
        """Move the cache: pull the source copy, delta-0 splice (identity
        rotation — the §6.3 true-prefix re-home our store models), persist
        the replica array where the planner made it resident, then serve
        the group with LOCAL attention on the moved copy."""
        src = (rec.link_instance if rec.primitive == "fetch_replica"
               else rec.holder)
        src_arr = self._array_on(store, rec.chunk_id, src)
        moved = splice_delta_rotate(src_arr, 0, self.cfg)
        dest = rec.home
        if dest >= 0 and store.resident_on(rec.chunk_id, dest):
            store.set_replica_data(rec.chunk_id, dest, moved)
        for rid in rec.req_ids:
            parts[rid].append(absorbed_partial(self.cfg, q_of(rid), moved))
