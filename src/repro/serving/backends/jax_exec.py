"""JaxExecBackend: run dispatch plans on real jax arrays.

The planner decides ROUTE / FETCH / LOCAL per (holder, chunk, fabric)
group; this backend EXECUTES those decisions:

* chunks materialize as real c^KV arrays (S, d_qk) in the chunk store —
  deterministic per chunk_id, so a re-run (or the exactness oracle) sees
  the same cache bytes;
* ROUTE — the grouped requesters' query tensors are stacked into one
  holder-side batched partial (core.routing.route_batched: the §6.3
  "batched partial is ~free" holder kernel), sliced back per request,
  merged requester-side. The query moved, the cache did not.
* FETCH — the chunk replicates through the core.splice path (delta-0
  re-home: the rotation is the identity, §6.3 true-prefix case), the copy
  is stored as the replica's array, and the requesters attend it LOCALLY —
  the cache moved, exactly as priced.
* LOCAL — re-prefill: the canonical entries are recomputed at the
  requester (same deterministic materialization) and attended locally.
* resident pairs (no transport planned) attend their local copy.

Under an ACTIVE selection (ISSUE 4 — the plan carries the indexer's masks
in StepPlan.selections), every primitive narrows to the chosen set:
ROUTE executes as a MASKED partial on the holder (selected & resident in
place — "the indexer's choice made distributed", §5.4; semantically the
block-sparse attend kernels/sparse_select computes), FETCH becomes the
scattered gather core.splice models (pull ONLY the selected entries at
canonical positions — no splice, nothing persisted), LOCAL and resident
accesses attend through the mask. The merged outputs then reproduce
single-instance selection_k decode (the DSA path of models/model.py) to
float round-off — selection_oracle_partial is that reference.

Every request's per-chunk partials merge through the online-softmax merge
(core.merge) — associative + commutative with identity — so the final
output per request equals single-instance attention over the concatenated
chunks to float round-off REGARDLESS of which primitive the predicate
picked (§3.3, now end-to-end through the scheduler).

The analytic stage costs ride along unchanged: the returned timeline is
the same schedule the AnalyticBackend produces, so planner parity and
StepStats parity hold by construction.
"""

from __future__ import annotations

import zlib
from collections import defaultdict
from typing import Dict, List, Optional, Tuple, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunk_store import ChunkStore
from repro.core.merge import Partial, merge_tree
from repro.core.routing import route_batched
from repro.core.splice import splice_delta_rotate
from repro.models.mla import MLAConfig, absorbed_partial
from repro.serving.backends.base import StepExecution, StepTicket
from repro.serving.plan import Request, StepPlan, build_timeline

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine


# Execution geometry for CPU-scale tests and the serve CLI: d_qk = 24.
# The PLANNER's costs always use the paper payload (cfg.payload on the
# engine) — primitive decisions are invariant to the execution geometry,
# which is what makes analytic-vs-exec planner parity exact.
TINY_MLA = MLAConfig(d_model=64, n_heads=2, kv_lora_rank=16,
                     qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=8)


def _stable_seed(*parts) -> int:
    """Deterministic 32-bit seed from stringable parts (NOT Python hash(),
    which is salted per process)."""
    return zlib.crc32(":".join(str(p) for p in parts).encode())


def fetch_source(rec) -> int:
    """The instance a fetch-kind dispatch pulls its bytes FROM — the wire's
    source end. For every fetch-kind record the planner sets link_instance
    to that source: plain "fetch" records carry link_instance == holder,
    and "fetch_replica" spawns carry the canonical holder (their `holder`
    field is the TARGET instance). One shared resolver (ISSUE 7 satellite):
    _exec_fetch and _exec_fetch_selected used to resolve independently —
    link_instance-for-fetch_replica vs always-rec.holder — a divergence
    that delta-0 replication kept silent (every copy holds canonical
    bytes) but that a delta-splice world would surface as wrong bytes."""
    return rec.link_instance if rec.link_instance >= 0 else rec.holder


def chunk_array(cfg: MLAConfig, chunk_id: str, length: int,
                dtype=jnp.float32) -> jax.Array:
    """The canonical c^KV array of a chunk: (length, d_qk), deterministic
    in chunk_id — re-prefill (LOCAL) regenerates exactly these entries."""
    key = jax.random.PRNGKey(_stable_seed("ckv", chunk_id))
    return jax.random.normal(key, (length, cfg.d_qk), dtype)


def query_for(cfg: MLAConfig, rq: Request, step: int,
              dtype=jnp.float32) -> jax.Array:
    """The request's absorbed decode queries this step: (m_q, H, d_qk),
    deterministic in (query_seed, step). The oracle in tests regenerates
    the identical tensor."""
    seed = rq.req_id if rq.query_seed is None else rq.query_seed
    key = jax.random.fold_in(jax.random.PRNGKey(_stable_seed("q", seed)),
                             step)
    return jax.random.normal(key, (rq.m_q, cfg.n_heads, cfg.d_qk), dtype)


def oracle_partial(cfg: MLAConfig, store: ChunkStore, rq: Request,
                   step: int, dtype=jnp.float32) -> Partial:
    """The §3.3 exactness reference: single-instance attention over the
    request's CONCATENATED chunks (canonical arrays, same query tensor the
    backend materialized). Every exec-backend consumer (tests, benchmarks,
    the serve CLI's --verify, examples) checks against THIS — one oracle,
    so query/chunk materialization can never silently diverge from it."""
    q = query_for(cfg, rq, step, dtype)
    cat = jnp.concatenate([store.lookup(c).data for c in rq.chunk_ids],
                          axis=0)
    return absorbed_partial(cfg, q, cat)


def selection_oracle_partial(cfg: MLAConfig, store: ChunkStore, rq: Request,
                             sel, step: int, dtype=jnp.float32) -> Partial:
    """The selection-regime exactness reference: single-instance
    selection_k decode — the DSA path of models/model.py lifted to the
    serving cache. One instance holds the request's CONCATENATED chunks,
    applies the GLOBAL selection mask (sel: a RequestSelection), and
    attends the chosen entries in place (canonical positions — no
    re-rotation, §3.3). The scheduler-driven scatter-attend must reproduce
    this to float round-off regardless of how the selection was split
    across holders or which primitives served the shards."""
    q = query_for(cfg, rq, step, dtype)
    cat = jnp.concatenate([store.lookup(c).data for c in rq.chunk_ids],
                          axis=0)
    gmask = np.concatenate([np.asarray(sel.masks[c]) for c in rq.chunk_ids])
    return absorbed_partial(cfg, q, cat, jnp.asarray(gmask))


def max_oracle_err(engine: "ServingEngine", reqs: List[Request],
                   step: int) -> float:
    """Worst |exec output - oracle| over a step's requests. The engine
    must be running a JaxExecBackend (its cfg/dtype define the oracle).
    Requests under an active selection verify against the selection
    oracle; everything else against dense single-instance attention."""
    backend = engine.backend
    outs = engine.outputs_of(step)
    sels = (engine.plans[step - 1].selections
            if 1 <= step <= len(engine.plans) else {})
    worst = 0.0
    for rq in reqs:
        sel = sels.get(rq.req_id)
        want = (selection_oracle_partial(backend.cfg, engine.store, rq, sel,
                                         step, backend.dtype)
                if sel is not None else
                oracle_partial(backend.cfg, engine.store, rq, step,
                               backend.dtype))
        worst = max(worst, float(jnp.max(
            jnp.abs(outs[rq.req_id].o - want.o))))
    return worst


class JaxExecBackend:
    """Execute StepPlans on real arrays. cfg sets the EXECUTION geometry
    (array shapes); it is independent of the planner's cost payload."""

    name = "exec"

    def __init__(self, cfg: MLAConfig = TINY_MLA, dtype=jnp.float32):
        self.cfg = cfg
        self.dtype = dtype
        # query memo (ISSUE 8 satellite): query_for is deterministic in
        # (seed, step, m_q), so the tensor is materialized ONCE per step
        # at the backend level instead of per-execute()-closure — shared
        # by every subclass (shard_map inherits). Entries older than the
        # previous step are pruned when a new step arrives.
        self._qmemo: Dict[Tuple[int, int, int], jax.Array] = {}
        self._qmemo_step = -1
        # query-memo effectiveness (ISSUE 9), read by the obs registry
        self.qmemo_hits = 0
        self.qmemo_misses = 0

    def query_of(self, rq: Request, step: int) -> jax.Array:
        """Memoized query_for: the request's decode queries this step."""
        if step != self._qmemo_step:
            if step > self._qmemo_step:
                self._qmemo = {k: v for k, v in self._qmemo.items()
                               if k[1] >= step - 1}
            else:                        # a fresh engine restarted the clock
                self._qmemo.clear()
            self._qmemo_step = step
        seed = rq.req_id if rq.query_seed is None else rq.query_seed
        key = (seed, step, rq.m_q)
        q = self._qmemo.get(key)
        if q is None:
            self.qmemo_misses += 1
            q = self._qmemo[key] = query_for(self.cfg, rq, step, self.dtype)
        else:
            self.qmemo_hits += 1
        return q

    # -- materialization ----------------------------------------------------

    def ensure_chunk_data(self, store: ChunkStore,
                          chunk_id: str) -> jax.Array:
        """Canonical array of chunk_id, materializing it on first touch."""
        chunk = store.lookup(chunk_id)
        if chunk.data is None:
            store.attach_data(
                chunk_id, chunk_array(self.cfg, chunk_id, chunk.length,
                                      self.dtype))
        return chunk.data

    def _array_on(self, store: ChunkStore, chunk_id: str,
                  instance: int) -> jax.Array:
        """The copy instance would attend: its replica array if the exec
        path produced one, else the canonical array (replicas created
        outside the exec path — e.g. hand-seeded in examples — fall back
        to canonical bytes, which is what a real pull would deliver)."""
        arr = store.array_on(chunk_id, instance)
        return arr if arr is not None else self.ensure_chunk_data(store,
                                                                  chunk_id)

    # -- execution ----------------------------------------------------------

    def execute(self, engine: "ServingEngine",
                plan: StepPlan) -> StepExecution:
        store = engine.store
        reqs: Dict[int, Request] = {rq.req_id: rq for rq in plan.requests}
        sels = plan.selections

        def q_of(rid: int) -> jax.Array:
            return self.query_of(reqs[rid], plan.step)

        def mask_of(rid: int, chunk_id: str) -> Optional[jax.Array]:
            """The indexer's (c_t,) token mask for this access, or None in
            the dense regime (plan.selections is the §5.4 handoff)."""
            sel = sels.get(rid)
            if sel is None:
                return None
            return jnp.asarray(np.asarray(sel.masks[chunk_id]))

        parts: Dict[int, List[Partial]] = defaultdict(list)

        # resident accesses: local attention on the instance's copy,
        # through the selection mask when the indexer chose for this request
        for rp in plan.resident_pairs:
            arr = self._array_on(store, rp.chunk_id, rp.instance)
            parts[rp.req_id].append(
                absorbed_partial(self.cfg, q_of(rp.req_id), arr,
                                 mask_of(rp.req_id, rp.chunk_id)))

        for rec in plan.records:
            if rec.backup or not rec.req_ids:
                continue
            if rec.primitive == "route":
                self._exec_route(store, rec, q_of, parts, mask_of)
            elif rec.primitive in ("fetch", "fetch_replica"):
                if rec.req_ids[0] in sels:
                    self._exec_fetch_selected(store, rec, q_of, parts,
                                              sels[rec.req_ids[0]])
                else:
                    self._exec_fetch(store, rec, q_of, parts)
            else:                                     # local re-prefill
                arr = self.ensure_chunk_data(store, rec.chunk_id)
                for rid in rec.req_ids:
                    parts[rid].append(
                        absorbed_partial(self.cfg, q_of(rid), arr,
                                         mask_of(rid, rec.chunk_id)))

        outputs = {rid: merge_tree(ps) for rid, ps in parts.items()}
        return StepExecution(timeline=build_timeline(plan.records),
                             outputs=outputs, backend=self.name)

    # single-process execution blocks as it goes — there is no deferred
    # device barrier to move, so submit runs the step eagerly (ISSUE 10;
    # the shard_map subclass overrides both halves with a real split)

    def submit(self, engine: "ServingEngine", plan: StepPlan) -> StepTicket:
        return StepTicket(plan=plan, execution=self.execute(engine, plan))

    def await_result(self, engine: "ServingEngine",
                     ticket: StepTicket) -> StepExecution:
        return ticket.execution

    def _exec_route(self, store: ChunkStore, rec, q_of, parts,
                    mask_of) -> None:
        """One batched dispatch: stack the group's queries, one holder-side
        partial over the holder's resident copy, slice back per request.
        A selection-regime dispatch (single-request by construction)
        routes as a MASKED partial — the holder attends selected &
        resident in place (§5.4), the block-sparse shape
        kernels/sparse_select computes."""
        holder_arr = self._array_on(store, rec.chunk_id, rec.holder)
        qs = [q_of(rid) for rid in rec.req_ids]
        mask = mask_of(rec.req_ids[0], rec.chunk_id)
        if mask is not None:
            merged = route_batched(self.cfg, [qs[0]], [[holder_arr]],
                                   masks=[[mask]])[0]
        else:
            stacked = jnp.concatenate(qs, axis=0) if len(qs) > 1 else qs[0]
            merged = route_batched(self.cfg, [stacked], [[holder_arr]])[0]
        off = 0
        for rid, q in zip(rec.req_ids, qs):
            n = q.shape[0]
            parts[rid].append(Partial(o=merged.o[off:off + n],
                                      m=merged.m[off:off + n],
                                      l=merged.l[off:off + n]))
            off += n

    def _exec_fetch(self, store: ChunkStore, rec, q_of, parts) -> None:
        """Move the cache: pull the source copy, delta-0 splice (identity
        rotation — the §6.3 true-prefix re-home our store models), persist
        the replica array where the planner made it resident, then serve
        the group with LOCAL attention on the moved copy."""
        src_arr = self._array_on(store, rec.chunk_id, fetch_source(rec))
        moved = splice_delta_rotate(src_arr, 0, self.cfg)
        dest = rec.home
        if dest >= 0 and store.resident_on(rec.chunk_id, dest):
            store.set_replica_data(rec.chunk_id, dest, moved)
            # the index SIDECAR moves with the cache bytes: keys derive
            # from the latent band only (position-invariant — the splice
            # touches just the rope band), so the replica's keys are the
            # canonical ones when they have been materialized
            keys = store.lookup(rec.chunk_id).index_keys
            if keys is not None:
                store.set_replica_index_keys(rec.chunk_id, dest, keys)
        for rid in rec.req_ids:
            parts[rid].append(absorbed_partial(self.cfg, q_of(rid), moved))

    def _exec_fetch_selected(self, store: ChunkStore, rec, q_of, parts,
                             sel) -> None:
        """FETCH under selection: the scattered gather (§5.4) — pull ONLY
        the selected entries from the holder's copy, at their canonical
        positions (NO splice: re-rotating a selection diverges, see
        core/splice), attend them at the requester, persist nothing (the
        selection is re-chosen every step). Single-process form of
        core.splice.fetch_scattered_gather + local attend."""
        # fetch_replica-under-selection is unreachable by construction:
        # replica spawns batch only DENSE fan-in overflow (selection pairs
        # group per-request, srid >= 0, and never join a dense group), so a
        # selected request can never ride a fetch_replica record. Pinned
        # here so the source resolution below (fetch_source == rec.holder
        # for plain fetch records) cannot silently diverge again.
        assert rec.primitive == "fetch", (
            f"selection fetch arrived as {rec.primitive!r}: replica spawns "
            "must never batch selected requests")
        rid = rec.req_ids[0]
        idx = np.nonzero(np.asarray(sel.masks[rec.chunk_id]))[0]
        if idx.size == 0:
            # the indexer chose nothing on this holder: the gather is
            # empty and the request's partial is the merge identity
            q = q_of(rid)
            parts[rid].append(Partial.identity(
                q.shape[:-1], self.cfg.kv_lora_rank))
            return
        src_arr = self._array_on(store, rec.chunk_id, fetch_source(rec))
        gathered = jnp.take(src_arr, jnp.asarray(idx), axis=0)
        parts[rid].append(
            absorbed_partial(self.cfg, q_of(rid), gathered))
