"""The ExecutionBackend protocol: what the engine's EXECUTE layer plugs in.

A backend receives the engine (for topology + chunk store) and the step's
StepPlan, and returns a StepExecution. It must NOT re-plan: primitives,
batching, persistence and replica placement are already decided — the
backend's job is to realize (or simulate) the planned transports.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Protocol, TYPE_CHECKING, \
    runtime_checkable

from repro.serving import timeline as TL
from repro.serving.plan import StepPlan

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class StepExecution:
    """What executing one StepPlan produced.

    timeline — the overlap-aware schedule of the plan's records (both
        backends produce it; the account layer derives StepStats from it).
    outputs  — req_id -> merged attention Partial over every chunk the
        request attended this step. Empty for the analytic backend; the
        exec backend's outputs must reproduce single-instance attention to
        float round-off (§3.3), which tests/test_backends.py asserts.
    measured — a timeline.MeasuredReport when the backend recorded real
        per-stage wall timings for the step (the shard_map backend,
        ISSUE 7); None for analytic / in-process execution.
    """
    timeline: TL.Timeline
    outputs: Dict[int, Any] = dataclasses.field(default_factory=dict)
    backend: str = ""
    measured: Optional[TL.MeasuredReport] = None


@runtime_checkable
class ExecutionBackend(Protocol):
    name: str

    def execute(self, engine: "ServingEngine",
                plan: StepPlan) -> StepExecution:
        """Run (or simulate) one planned step."""
        ...                                          # pragma: no cover
