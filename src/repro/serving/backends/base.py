"""The ExecutionBackend protocol: what the engine's EXECUTE layer plugs in.

A backend receives the engine (for topology + chunk store) and the step's
StepPlan, and returns a StepExecution. It must NOT re-plan: primitives,
batching, persistence and replica placement are already decided — the
backend's job is to realize (or simulate) the planned transports.

Since ISSUE 10 execution is split into two halves so the engine can
pipeline plan(N+1) under execute(N):

* ``submit(engine, plan) -> StepTicket`` — issue the step's device work
  without blocking on it. A backend with nothing async to offer (the
  analytic timeline, the in-process jax path) executes eagerly and
  returns the finished StepExecution inside the ticket.
* ``await_result(engine, ticket) -> StepExecution`` — block until the
  submitted step completes and account its measured walls. Must be called
  exactly once per ticket, in submit order (the engine drains FIFO).

``execute`` remains the one-shot form (submit + await back to back) and
the only method a minimal backend must provide — the ``submit_step`` /
``await_step`` helpers below degrade to it, so third-party backends keep
working unchanged at any pipeline depth (they just overlap nothing).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Protocol, TYPE_CHECKING, \
    runtime_checkable

from repro.serving import timeline as TL
from repro.serving.plan import StepPlan

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class StepExecution:
    """What executing one StepPlan produced.

    timeline — the overlap-aware schedule of the plan's records (both
        backends produce it; the account layer derives StepStats from it).
    outputs  — req_id -> merged attention Partial over every chunk the
        request attended this step. Empty for the analytic backend; the
        exec backend's outputs must reproduce single-instance attention to
        float round-off (§3.3), which tests/test_backends.py asserts.
    measured — a timeline.MeasuredReport when the backend recorded real
        per-stage wall timings for the step (the shard_map backend,
        ISSUE 7); None for analytic / in-process execution.
    """
    timeline: TL.Timeline
    outputs: Dict[int, Any] = dataclasses.field(default_factory=dict)
    backend: str = ""
    measured: Optional[TL.MeasuredReport] = None


@dataclasses.dataclass
class StepTicket:
    """An in-flight step: what submit() issued and await_result() will
    finish. ``execution`` is pre-filled by eager backends (submit already
    ran everything); ``state`` is backend-private launch context for the
    genuinely async ones (the shard_map backend parks its dispatched
    device tasks here until the barrier)."""
    plan: StepPlan
    state: Any = None
    execution: Optional[StepExecution] = None


@runtime_checkable
class ExecutionBackend(Protocol):
    name: str

    def execute(self, engine: "ServingEngine",
                plan: StepPlan) -> StepExecution:
        """Run (or simulate) one planned step."""
        ...                                          # pragma: no cover


def submit_step(backend: ExecutionBackend, engine: "ServingEngine",
                plan: StepPlan) -> StepTicket:
    """Issue one planned step without blocking. Backends that predate the
    split (no submit attr) run eagerly — correct at any depth, they just
    leave nothing for the planner to hide under."""
    sub = getattr(backend, "submit", None)
    if sub is None:
        return StepTicket(plan=plan, execution=backend.execute(engine, plan))
    return sub(engine, plan)


def await_step(backend: ExecutionBackend, engine: "ServingEngine",
               ticket: StepTicket) -> StepExecution:
    """Block until a submitted step's StepExecution is complete."""
    if ticket.execution is not None:
        return ticket.execution
    return backend.await_result(engine, ticket)
