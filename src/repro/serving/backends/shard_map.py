"""ShardMapExecBackend: run the plan on a real device mesh (ISSUE 7).

The chunk store's canonical arrays partition across a mesh axis named
"instance" — one device per serving instance (forced host devices in CI:
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) — and every
transport the planner decided executes as a REAL collective inside
shard_map:

* ROUTE  — the staged core.routing decomposition: ``pairwise_ship`` /
  ``pairwise_return`` ppermutes when the dispatch group shares one home,
  ``fanout_gather`` / ``fanout_exchange`` all-collectives when requesters
  span homes. The query crosses the axis; the cache never does.
* FETCH  — ``core.splice.fetch_chunk`` (bulk ppermute + delta-0 splice;
  the copy persists as the replica array exactly where the planner made
  it resident) or ``fetch_scattered_gather`` under an active selection
  (canonical positions, nothing persisted — §5.4).
* LOCAL  — re-prefill on the requester's own device.

Outputs reproduce the single-instance oracles to float round-off — the
§3.3 exactness claim, now through the scheduler AND a real mesh.

Each wire / compute stage is timed around its collective (jit-compiled
once per shape, warmed before timing so compile never pollutes a sample)
and the measured durations are rebound to the SAME flow structure the
cost model priced; ``timeline.measured_vs_analytic`` re-schedules them
into a measured-vs-analytic MeasuredReport per step — the paper's §7
model-validation loop, continuously exercised in CI. The returned
*analytic* timeline is byte-identical to AnalyticBackend's, so planner
StepStats parity holds by construction (sched_wall_s excepted).

Two execution modes (ISSUE 8):

* ``fused=True`` (default) — each dispatch group's staged chain compiles
  into ONE jitted program per (primitive, shape-signature), every
  record's host->device stacking batches into a single ``device_put``
  per step, all groups launch WITHOUT intermediate ``block_until_ready``
  (JAX async dispatch pipelines them the way the overlap timeline
  models) and the step blocks once at a barrier. Each group's measured
  wall — net of queueing behind groups that share a (link, fabric) wire
  or an SM, per the plan's resource bindings — is apportioned over the
  record's planned stage ratios, so the per-stage measured breakdown
  survives fusion. Merges run on-device over committed shards (every
  partial of a request lands on its home device); nothing round-trips
  through the host until the store persists a replica.
* ``fused=False`` — the PR-7 per-stage path: one timed ``staged_call``
  per stage, host-side merges. The A/B kill switch (mirrors
  ``EngineConfig.vectorized_plan``) and the serial baseline
  ``bench_serving_steadystate --exec-bench`` compares against.
"""

from __future__ import annotations

import sys
import time
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.chunk_store import ChunkStore
from repro.core.merge import NEG_INF, Partial, merge_stacked, merge_tree
from repro.core.routing import (check_route_shards, fanout_exchange,
                                fanout_gather, pairwise_return, pairwise_ship)
from repro.core.splice import (fetch_chunk, fetch_scattered_gather,
                               splice_delta_rotate)
from repro.models.mla import MLAConfig, absorbed_partial
from repro.serving import timeline as TL
from repro.serving.backends.base import StepExecution, StepTicket
from repro.serving.backends.jax_exec import (JaxExecBackend, TINY_MLA,
                                             fetch_source)
from repro.serving.plan import StepPlan, build_timeline

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine

AXIS = "instance"

_MESH_CACHE: Dict[int, Tuple[Any, Tuple[Any, ...]]] = {}
_ASM_CACHE: Dict[int, "_ShardAssembler"] = {}


def mesh_for(n_instances: int):
    """A 1-D mesh over the first n_instances devices, axis named AXIS.
    Device order pins instance i to jax.devices()[i], so shard extraction
    by instance index is deterministic."""
    cached = _MESH_CACHE.get(n_instances)
    if cached is not None:
        return cached
    devs = jax.devices()
    if len(devs) < n_instances:
        raise RuntimeError(
            f"shard_map backend needs {n_instances} devices for the "
            f"{AXIS!r} mesh axis but jax sees {len(devs)}. On CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_instances} BEFORE importing jax.")
    devices = tuple(devs[:n_instances])
    mesh = jax.sharding.Mesh(np.asarray(devices), (AXIS,))
    _MESH_CACHE[n_instances] = (mesh, devices)
    return mesh, devices


def assembler_for(n_instances: int) -> "_ShardAssembler":
    asm = _ASM_CACHE.get(n_instances)
    if asm is None:
        asm = _ASM_CACHE[n_instances] = _ShardAssembler(*mesh_for(n_instances))
    return asm


def check_instance_shards(parts: Dict[int, Any], per_shape: Tuple[int, ...],
                          n_instances: Optional[int] = None,
                          axis: str = AXIS) -> None:
    """Up-front per-instance shard validation (ISSUE 7 satellite): every
    supplied shard must match the mesh-wide per-shard shape. A ragged
    shard used to surface only as an opaque XLA concatenation / layout
    error at assembly; shapes are host-side constants here, so the
    mismatch is rejected naming the axis, the offending shard and BOTH
    shapes."""
    per = tuple(per_shape)
    for inst, part in parts.items():
        if n_instances is not None and not 0 <= inst < n_instances:
            raise ValueError(
                f"instance shard on mesh axis {axis!r}: shard {inst} is "
                f"outside the mesh (axis size {n_instances})")
        got = tuple(part.shape)
        if got != per:
            raise ValueError(
                f"instance shards disagree on mesh axis {axis!r}: shard "
                f"{inst} has shape {got} but the mesh-wide per-shard "
                f"shape is {per}")


def staged_call(jits: Dict[Any, Any], key, build, args) -> Tuple[Any, float]:
    """Run a jitted stage and return (output, wall seconds). First call
    per (static, shapes) key builds + WARMS the function — compile time
    never lands in a measured sample; subsequent shapes re-key."""
    fn = jits.get(key)
    if fn is None:
        fn = build()
        jax.block_until_ready(fn(*args))
        jits[key] = fn
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args))
    return out, time.perf_counter() - t0


class _ShardAssembler:
    """Host-side <-> mesh-sharded array plumbing for one mesh size.

    stack() builds a global array sharded P(AXIS) from a {instance:
    per-shard array} dict (absent instances get cached committed zero
    buffers — a non-holder's view of a chunk it does not have); take()
    extracts instance i's committed shard of a global result."""

    def __init__(self, mesh, devices):
        self.mesh = mesh
        self.devices = devices
        self.n = len(devices)
        self._zeros: Dict[Tuple, Any] = {}

    def _zero(self, per_shape: Tuple[int, ...], dtype, inst: int):
        key = (per_shape, jnp.dtype(dtype).name, inst)
        buf = self._zeros.get(key)
        if buf is None:
            buf = jax.device_put(jnp.zeros(per_shape, dtype),
                                 self.devices[inst])
            self._zeros[key] = buf
        return buf

    def stack(self, parts: Dict[int, Any], per_shape: Tuple[int, ...],
              dtype=jnp.float32):
        per_shape = tuple(per_shape)
        check_instance_shards(parts, per_shape, self.n)
        bufs = []
        for inst in range(self.n):
            part = parts.get(inst)
            if part is None:
                bufs.append(self._zero(per_shape, dtype, inst))
            else:
                bufs.append(jax.device_put(jnp.asarray(part, dtype),
                                           self.devices[inst]))
        gshape = (self.n * per_shape[0],) + per_shape[1:]
        return jax.make_array_from_single_device_arrays(
            gshape, NamedSharding(self.mesh, P(AXIS)), bufs)

    def take(self, garr, inst: int):
        """Instance inst's per-shard slice of a P(AXIS)-sharded global
        array, as the committed single-device buffer."""
        per = garr.shape[0] // self.n
        for s in garr.addressable_shards:
            if (s.index[0].start or 0) == inst * per:
                return s.data
        raise RuntimeError(               # pragma: no cover - all host devs
            f"no addressable shard for instance {inst} on axis {AXIS!r}")

    def begin_batch(self) -> "_StackBatch":
        """A deferred-stacking batch: collect a whole step's placements,
        transfer them in ONE device_put (ISSUE 8)."""
        return _StackBatch(self)


class _StackBatch:
    """One step's host->device transfers, batched. add() defers a
    _ShardAssembler.stack(); put() defers a single-device commit; both
    return integer handles into the list commit() produces. commit()
    issues a SINGLE batched jax.device_put over every (array, device)
    pair — one dispatch instead of one per record input — then assembles
    the global sharded arrays from the committed buffers. Transfers can
    dedupe per step via put(key=...): the same query tensor feeding two
    records on one device ships once."""

    def __init__(self, asm: _ShardAssembler):
        self.asm = asm
        self._src: List[Any] = []
        self._dev: List[Any] = []
        self._dedupe: Dict[Any, int] = {}
        self._items: List[Tuple] = []

    def _tx(self, arr, inst: int, key=None) -> int:
        if key is not None:
            hit = self._dedupe.get(key)
            if hit is not None:
                return hit
        slot = len(self._src)
        self._src.append(arr)
        self._dev.append(self.asm.devices[inst])
        if key is not None:
            self._dedupe[key] = slot
        return slot

    def put(self, arr, inst: int, key=None) -> int:
        """Commit one array to instance inst's device."""
        self._items.append(("put", self._tx(jnp.asarray(arr), inst, key)))
        return len(self._items) - 1

    def add(self, parts: Dict[int, Any], per_shape: Tuple[int, ...],
            dtype=jnp.float32) -> int:
        """_ShardAssembler.stack, deferred: absent instances resolve to
        the assembler's cached committed zero buffers at commit()."""
        per_shape = tuple(per_shape)
        check_instance_shards(parts, per_shape, self.asm.n)
        slots: List[Optional[int]] = []
        for inst in range(self.asm.n):
            p = parts.get(inst)
            slots.append(None if p is None
                         else self._tx(jnp.asarray(p, dtype), inst))
        self._items.append(("stack", per_shape, jnp.dtype(dtype), slots))
        return len(self._items) - 1

    def commit(self) -> List[Any]:
        bufs = jax.device_put(self._src, self._dev) if self._src else []
        out: List[Any] = []
        for item in self._items:
            if item[0] == "put":
                out.append(bufs[item[1]])
                continue
            _, per_shape, dtype, slots = item
            shard_bufs = [bufs[s] if s is not None
                          else self.asm._zero(per_shape, dtype, inst)
                          for inst, s in enumerate(slots)]
            gshape = (self.asm.n * per_shape[0],) + per_shape[1:]
            out.append(jax.make_array_from_single_device_arrays(
                gshape, NamedSharding(self.asm.mesh, P(AXIS)), shard_bufs))
        return out


class ShardMapExecBackend(JaxExecBackend):
    """JaxExecBackend semantics on a real mesh, with measured stage
    timings. cfg is the execution geometry (TINY_MLA by default; the
    planner's cost payload is independent — analytic/exec planner parity
    is exact)."""

    name = "shard_map"
    _warned_fill = False               # process-wide warn-once (ISSUE 8)

    def __init__(self, cfg: MLAConfig = TINY_MLA, dtype=jnp.float32,
                 fused: bool = True):
        super().__init__(cfg, dtype)
        self.fused = fused
        self.mesh = None
        self.devices: Tuple[Any, ...] = ()
        self._asm: Optional[_ShardAssembler] = None
        self._jits: Dict[Any, Any] = {}
        self._pool: Dict[Tuple[str, int], Any] = {}
        self._tiny = None
        self._listening_store = None
        self._fill_count = 0
        # per-step / cumulative phase walls of the fused path (stack /
        # dispatch / barrier / merge) — benchmarks/profile_exec.py reads
        # these; four perf_counter probes per step, nothing on the
        # per-record path
        self.phase_wall: Dict[str, float] = {}
        self.phase_wall_total: Dict[str, float] = {}

    # -- mesh binding -------------------------------------------------------

    def _bind(self, engine: "ServingEngine") -> None:
        ni = len(engine.instances)
        if self.mesh is None or len(self.devices) != ni:
            self.mesh, self.devices = mesh_for(ni)
            self._asm = assembler_for(ni)
            self._jits.clear()
            self._pool.clear()
            self._tiny = self._asm.stack({}, (1,), jnp.float32)
        store = engine.store
        if self._listening_store is not store:
            # bound committed-copy cache (ISSUE 8 satellite): when the
            # engine's LRU path retires a replica (or a holder dies), the
            # device-side buffer retires with it
            store.add_evict_listener(self._retire_pooled)
            self._listening_store = store

    def _retire_pooled(self, chunk_id: str, instance: int) -> None:
        self._pool.pop((chunk_id, instance), None)

    def _pool_bytes(self) -> int:
        return sum(int(getattr(b, "nbytes", 0))
                   for b in self._pool.values())

    def _shmap(self, body, in_specs, out_specs):
        return jax.jit(compat.shard_map(body, mesh=self.mesh,
                                        in_specs=in_specs,
                                        out_specs=out_specs))

    def _staged(self, statics: Tuple, build, args) -> Tuple[Any, float]:
        key = statics + tuple(
            (tuple(x.shape), jnp.dtype(x.dtype).name)
            for x in jax.tree.leaves(args))
        return staged_call(self._jits, key, build, args)

    def _committed_copy(self, store: ChunkStore, chunk_id: str,
                        inst: int):
        """The copy instance `inst` attends, committed to ITS device.
        Cached per (chunk, instance): chunk bytes are canonical under
        delta-0 replication, so a cached copy can never go stale in
        content — only in shape, which re-keys."""
        arr = self._array_on(store, chunk_id, inst)
        key = (chunk_id, inst)
        buf = self._pool.get(key)
        if buf is None or buf.shape != arr.shape:
            buf = jax.device_put(arr, self.devices[inst])
            self._pool[key] = buf
        return buf

    @staticmethod
    def _uncommit(x):
        """Strip device commitment (via host) so downstream host-side
        merges can mix operands from different shards."""
        return jnp.asarray(np.asarray(x))

    # -- execution ----------------------------------------------------------

    def execute(self, engine: "ServingEngine",
                plan: StepPlan) -> StepExecution:
        return self.await_result(engine, self.submit(engine, plan))

    def submit(self, engine: "ServingEngine", plan: StepPlan) -> StepTicket:
        """Issue the step WITHOUT blocking (ISSUE 10): bind, STACK the
        batched device_put, DISPATCH every fused program — everything of
        _execute_overlapped up to (not including) the barrier. The engine
        plans the next step while the devices chew; await_result barriers
        and merges. The serial chain has no deferrable barrier (each
        staged_call blocks), so fused=False stays eager — the A/B oracle
        is a ticket whose execution is already complete."""
        t_wall0 = time.perf_counter()
        self._bind(engine)
        if not self.fused:
            self._fill_count = 0
            return StepTicket(plan=plan, execution=self._execute_serial(
                engine, plan, t_wall0))
        return StepTicket(plan=plan,
                          state=self._submit_overlapped(engine, plan,
                                                        t_wall0))

    def await_result(self, engine: "ServingEngine",
                     ticket: StepTicket) -> StepExecution:
        if ticket.execution is not None:
            return ticket.execution
        return self._await_overlapped(engine, ticket.plan, ticket.state)

    def _analytic_timeline(self, plan: StepPlan):
        """EXACTLY what AnalyticBackend produces, so StepStats derived
        from it are bit-identical (golden parity)."""
        if plan.arrays is not None:
            return TL.simulate_arrays(plan.arrays.flow_arrays())
        return build_timeline(plan.records)

    def _report(self, plan: StepPlan, analytic, measured_flows,
                t_wall0: float, mode: str) -> TL.MeasuredReport:
        return TL.measured_vs_analytic(
            plan.step, analytic, measured_flows,
            time.perf_counter() - t_wall0, mode=mode,
            pool_entries=len(self._pool), pool_bytes=self._pool_bytes(),
            stage_fills=self._fill_count)

    def _execute_serial(self, engine: "ServingEngine", plan: StepPlan,
                        t_wall0: float) -> StepExecution:
        store = engine.store
        reqs = {rq.req_id: rq for rq in plan.requests}
        sels = plan.selections

        def q_of(rid: int) -> jax.Array:
            return self.query_of(reqs[rid], plan.step)

        def mask_of(rid: int, chunk_id: str) -> Optional[np.ndarray]:
            sel = sels.get(rid)
            if sel is None:
                return None
            return np.asarray(sel.masks[chunk_id], bool)

        parts: Dict[int, List[Partial]] = defaultdict(list)

        # resident accesses attend host-side, exactly like the in-process
        # backend: the analytic flow set has no transport for them either,
        # so the measured flow set stays structurally identical.
        for rp in plan.resident_pairs:
            arr = self._array_on(store, rp.chunk_id, rp.instance)
            m = mask_of(rp.req_id, rp.chunk_id)
            parts[rp.req_id].append(
                absorbed_partial(self.cfg, q_of(rp.req_id), arr,
                                 None if m is None else jnp.asarray(m)))

        sel_times = getattr(engine.selector, "measured_index_s", None) or {}
        measured_flows: List[TL.Flow] = []
        for i, rec in enumerate(plan.records):
            if rec.backup or not rec.req_ids:
                continue
            if rec.primitive == "route":
                meas = self._exec_route_mesh(store, rec, q_of, parts,
                                             mask_of, reqs)
            elif rec.primitive in ("fetch", "fetch_replica"):
                if rec.req_ids[0] in sels:
                    meas = self._exec_fetch_selected_mesh(
                        store, rec, q_of, parts, sels[rec.req_ids[0]])
                else:
                    meas = self._exec_fetch_mesh(store, rec, q_of, parts)
            else:
                meas = self._exec_local_mesh(store, rec, q_of, parts,
                                             mask_of)
            if rec.stages and rec.stages[0][0] == "index":
                # the indexer round trip ran at PLAN time (the selector's
                # scoring collective); its measured wall lands here
                meas.setdefault("index", float(sel_times.get(
                    (plan.step, rec.req_ids[0], rec.chunk_id), 0.0)))
            if rec.stages:
                measured_flows.append(self._measured_flow(rec, i, meas))

        outputs = {rid: merge_tree(ps) for rid, ps in parts.items()}
        analytic = self._analytic_timeline(plan)
        report = self._report(plan, analytic, measured_flows, t_wall0,
                              "serial")
        return StepExecution(timeline=analytic, outputs=outputs,
                             backend=self.name, measured=report)

    def _count_fill(self, rec, n: int) -> None:
        """A stage duration had to be invented (a serial stage went
        unmeasured, or a fused wall apportioned over all-zero planned
        durations): count it on the step's MeasuredReport and warn ONCE
        per process — silent 0.0 fills used to deflate measured
        makespans (ISSUE 8 satellite)."""
        self._fill_count += n
        cls = type(self)
        if not cls._warned_fill:
            cls._warned_fill = True
            print(f"[shard_map] warning: filled {n} unmeasured stage "
                  f"duration(s) on {rec.primitive}:{rec.chunk_id}; "
                  f"counted on MeasuredReport.stage_fills (warn-once)",
                  file=sys.stderr)

    def _measured_flow(self, rec, i: int, meas: Dict[str, float]) -> TL.Flow:
        """Rebind the record's planned stage chain to measured durations:
        same key, same stage names/order, same resource binding as
        plan.build_timeline — so the measured schedule is comparable
        stage-for-stage with the analytic one."""
        missing = [name for name, _dur in rec.stages if name not in meas]
        if missing:
            self._count_fill(rec, len(missing))
        stages = [(name, float(meas.get(name, 0.0)))
                  for name, _dur in rec.stages]
        link_res = (TL.link(rec.link_instance, rec.fabric_idx)
                    if rec.link_instance >= 0 else None)
        requester = rec.home if rec.home >= 0 else rec.holder
        return TL.transport_flow(
            f"{rec.primitive}:{rec.chunk_id}@{rec.holder}#{i}", stages,
            link_res=link_res, holder_sm=TL.sm(rec.holder),
            requester_sm=TL.sm(requester), primitive=rec.primitive,
            chunk_id=rec.chunk_id)

    # -- ROUTE --------------------------------------------------------------

    def _exec_route_mesh(self, store, rec, q_of, parts, mask_of,
                         reqs) -> Dict[str, float]:
        holder = rec.holder
        ckv = self._committed_copy(store, rec.chunk_id, holder)
        mask = mask_of(rec.req_ids[0], rec.chunk_id)
        valid = (np.ones(ckv.shape[0], bool) if mask is None else mask)
        qs = [q_of(rid) for rid in rec.req_ids]
        homes = [reqs[rid].home for rid in rec.req_ids]
        for q, home in zip(qs, homes):
            check_route_shards(AXIS, q, ckv, valid, shard=home)
        if len(set(homes)) == 1:
            stacked = jnp.concatenate(qs, axis=0) if len(qs) > 1 else qs[0]
            meas, merged = self._route_pairwise_staged(ckv, valid, stacked,
                                                       holder, homes[0])
            off = 0
            for rid, q in zip(rec.req_ids, qs):
                n = q.shape[0]
                parts[rid].append(Partial(o=merged.o[off:off + n],
                                          m=merged.m[off:off + n],
                                          l=merged.l[off:off + n]))
                off += n
            return meas
        # requesters span homes: the fanout schedule — every home ships
        # its block of rows in ONE all_gather round, padded to the widest
        by_home: Dict[int, List[jax.Array]] = {}
        slices: Dict[int, Tuple[int, int, int]] = {}
        for rid, q, home in zip(rec.req_ids, qs, homes):
            blk = by_home.setdefault(home, [])
            start = sum(x.shape[0] for x in blk)
            blk.append(q)
            slices[rid] = (home, start, q.shape[0])
        b_pad = max(sum(x.shape[0] for x in blk) for blk in by_home.values())
        blocks: Dict[int, jax.Array] = {}
        for home, blk in by_home.items():
            block = jnp.concatenate(blk, axis=0) if len(blk) > 1 else blk[0]
            if block.shape[0] < b_pad:
                pad = jnp.zeros((b_pad - block.shape[0],) + block.shape[1:],
                                block.dtype)
                block = jnp.concatenate([block, pad], axis=0)
            blocks[home] = block
        meas, merged_by_home = self._route_fanout_staged(
            ckv, valid, blocks, b_pad, holder)
        for rid in rec.req_ids:
            home, start, n = slices[rid]
            mp = merged_by_home[home]
            parts[rid].append(Partial(o=mp.o[start:start + n],
                                      m=mp.m[start:start + n],
                                      l=mp.l[start:start + n]))
        return meas

    def _route_pairwise_staged(self, ckv, valid, q_stacked, holder: int,
                               requester: int):
        """ROUTE, one home: probe / transfer / compute / return around the
        staged core.routing ppermute decomposition, merge host-side. Non-
        participant shards see zero queries against all-False masks — the
        merge identity (core.merge NaN-guards pin this)."""
        meas: Dict[str, float] = {}
        PS = P(AXIS)
        PART = Partial(o=PS, m=PS, l=PS)
        _, meas["probe"] = self._staged(
            ("probe-pair", holder, requester),
            lambda: self._shmap(
                lambda t: lax.ppermute(t, AXIS, [(requester, holder)]),
                (PS,), PS),
            (self._tiny,))
        qg = self._asm.stack({requester: q_stacked},
                             tuple(q_stacked.shape), self.dtype)
        shipped, meas["transfer"] = self._staged(
            ("pair-ship", holder, requester),
            lambda: self._shmap(
                lambda q: pairwise_ship(q, holder, requester, AXIS),
                (PS,), PS),
            (qg,))
        cg = self._asm.stack({holder: ckv}, tuple(ckv.shape), self.dtype)
        vg = self._asm.stack({holder: valid}, (valid.shape[0],), jnp.bool_)
        part, meas["compute"] = self._staged(
            ("route-compute", holder),
            lambda: self._shmap(
                lambda q, c, v: absorbed_partial(self.cfg, q, c, v),
                (PS, PS, PS), PART),
            (shipped, cg, vg))
        back, meas["return"] = self._staged(
            ("pair-return", holder, requester),
            lambda: self._shmap(
                lambda p: pairwise_return(p, holder, requester, AXIS),
                (PART,), PART),
            (part,))
        t0 = time.perf_counter()
        merged = Partial(*(self._uncommit(self._asm.take(x, requester))
                           for x in back))
        meas["merge"] = time.perf_counter() - t0
        return meas, merged

    def _route_fanout_staged(self, ckv, valid, blocks: Dict[int, jax.Array],
                             b_pad: int, holder: int):
        """ROUTE, many homes: all_gather the padded query blocks, one
        holder-side batched partial over every visitor, all_to_all the
        partials home, merge_stacked on-shard."""
        meas: Dict[str, float] = {}
        PS = P(AXIS)
        PART = Partial(o=PS, m=PS, l=PS)
        _, meas["probe"] = self._staged(
            ("probe-fan",),
            lambda: self._shmap(lambda t: lax.all_gather(t, AXIS),
                                (PS,), PS),
            (self._tiny,))
        sample = next(iter(blocks.values()))
        qg = self._asm.stack(blocks, (b_pad,) + tuple(sample.shape[1:]),
                             self.dtype)
        gathered, meas["transfer"] = self._staged(
            ("fan-gather",),
            lambda: self._shmap(lambda q: fanout_gather(q, AXIS), (PS,), PS),
            (qg,))
        cg = self._asm.stack({holder: ckv}, tuple(ckv.shape), self.dtype)
        vg = self._asm.stack({holder: valid}, (valid.shape[0],), jnp.bool_)
        part, meas["compute"] = self._staged(
            ("route-compute", holder),
            lambda: self._shmap(
                lambda q, c, v: absorbed_partial(self.cfg, q, c, v),
                (PS, PS, PS), PART),
            (gathered, cg, vg))
        ex, meas["return"] = self._staged(
            ("fan-exchange",),
            lambda: self._shmap(lambda p: fanout_exchange(p, AXIS),
                                (PART,), PART),
            (part,))
        t0 = time.perf_counter()
        merged_g, _dt = self._staged(
            ("fan-merge",),
            lambda: self._shmap(lambda p: merge_stacked(p.o, p.m, p.l),
                                (PART,), PART),
            (ex,))
        merged = {home: Partial(*(self._uncommit(self._asm.take(x, home))
                                  for x in merged_g))
                  for home in blocks}
        meas["merge"] = time.perf_counter() - t0
        return meas, merged

    # -- FETCH --------------------------------------------------------------

    def _exec_fetch_mesh(self, store, rec, q_of, parts) -> Dict[str, float]:
        """Move the cache across the mesh: bulk ppermute pull into the
        destination's pool (core.splice.fetch_chunk, delta elided), delta-0
        splice on the destination shard, persist the replica where the
        planner made it resident, then the group attends locally."""
        meas: Dict[str, float] = {}
        src = fetch_source(rec)
        dst = rec.home if rec.home >= 0 else rec.holder
        ckv = self._committed_copy(store, rec.chunk_id, src)
        PS = P(AXIS)
        cg = self._asm.stack({src: ckv}, tuple(ckv.shape), self.dtype)
        pool_g = self._asm.stack({}, tuple(ckv.shape), self.dtype)
        pulled, meas["pull"] = self._staged(
            ("fetch-pull", src, dst),
            lambda: self._shmap(
                lambda pool, c: fetch_chunk(pool, c, None, 0, self.cfg,
                                            src, dst, AXIS),
                (PS, PS), PS),
            (pool_g, cg))
        moved_dev = self._asm.take(pulled, dst)
        moved_dev, meas["splice"] = self._staged(
            ("splice",),
            lambda: jax.jit(lambda x: splice_delta_rotate(x, 0, self.cfg)),
            (moved_dev,))
        moved = self._uncommit(moved_dev)
        if rec.home >= 0 and store.resident_on(rec.chunk_id, rec.home):
            self._pool[(rec.chunk_id, rec.home)] = moved_dev
            store.set_replica_data(rec.chunk_id, rec.home, moved)
            keys = store.lookup(rec.chunk_id).index_keys
            if keys is not None:
                store.set_replica_index_keys(rec.chunk_id, rec.home, keys)
        for rid in rec.req_ids:
            parts[rid].append(absorbed_partial(self.cfg, q_of(rid), moved))
        return meas

    def _exec_fetch_selected_mesh(self, store, rec, q_of, parts,
                                  sel) -> Dict[str, float]:
        """FETCH under selection: core.splice.fetch_scattered_gather —
        pull ONLY the chosen entries at canonical positions (no splice),
        attend at the requester, persist nothing."""
        assert rec.primitive == "fetch", (
            f"selection fetch arrived as {rec.primitive!r}: replica spawns "
            "must never batch selected requests")
        rid = rec.req_ids[0]
        idx = np.nonzero(np.asarray(sel.masks[rec.chunk_id]))[0]
        if idx.size == 0:
            q = q_of(rid)
            parts[rid].append(Partial.identity(
                q.shape[:-1], self.cfg.kv_lora_rank))
            return {"gather": 0.0}
        src = fetch_source(rec)
        dst = rec.home if rec.home >= 0 else rec.holder
        ckv = self._committed_copy(store, rec.chunk_id, src)
        PS = P(AXIS)
        cg = self._asm.stack({src: ckv}, tuple(ckv.shape), self.dtype)
        pool_g = self._asm.stack({}, (int(idx.size), ckv.shape[1]),
                                 self.dtype)
        pulled, dt = self._staged(
            ("fetch-gather", src, dst),
            lambda: self._shmap(
                lambda pool, c, ix: fetch_scattered_gather(
                    pool, c, ix, 0, self.cfg, src, dst, AXIS),
                (PS, PS, P()), PS),
            (pool_g, cg, jnp.asarray(idx)))
        gathered = self._uncommit(self._asm.take(pulled, dst))
        parts[rid].append(absorbed_partial(self.cfg, q_of(rid), gathered))
        return {"gather": dt}

    # -- LOCAL --------------------------------------------------------------

    def _exec_local_mesh(self, store, rec, q_of, parts,
                         mask_of) -> Dict[str, float]:
        """Re-prefill on the requester's own device (no wire)."""
        arr = self.ensure_chunk_data(store, rec.chunk_id)
        inst = rec.home if rec.home >= 0 else rec.holder
        carr = jax.device_put(arr, self.devices[inst])
        total = 0.0
        for rid in rec.req_ids:
            q = jax.device_put(q_of(rid), self.devices[inst])
            mask = mask_of(rid, rec.chunk_id)
            if mask is None:
                out, dt = self._staged(
                    ("prefill", inst),
                    lambda: jax.jit(
                        lambda q, c: absorbed_partial(self.cfg, q, c)),
                    (q, carr))
            else:
                cm = jax.device_put(jnp.asarray(mask), self.devices[inst])
                out, dt = self._staged(
                    ("prefill-mask", inst),
                    lambda: jax.jit(
                        lambda q, c, v: absorbed_partial(self.cfg, q, c, v)),
                    (q, carr, cm))
            total += dt
            parts[rid].append(jax.tree.map(self._uncommit, out))
        return {"prefill": total}

    # =======================================================================
    # Fused + overlapped execution (ISSUE 8 tentpole). One jitted program
    # per dispatch group, one batched stack per step, async launches, one
    # barrier. Numerically the same staged core.routing / core.splice
    # compositions as the serial path — XLA just sees them in one trace.
    # =======================================================================

    def _fused_fn(self, statics: Tuple, build, args):
        """The cached jitted program for (statics, arg shapes/dtypes).
        First build WARMS it (a blocking call on the real args) so
        compile never pollutes a measured sample; later calls return the
        cached wrapper without touching the device."""
        key = ("fused",) + tuple(statics) + tuple(
            (tuple(x.shape), jnp.dtype(x.dtype).name)
            for x in jax.tree.leaves(args))
        fn = self._jits.get(key)
        if fn is None:
            fn = build()
            jax.block_until_ready(fn(*args))
            self._jits[key] = fn
        return fn

    def _gated_partial(self, holder: int, q, c, v) -> Partial:
        """absorbed_partial on the HOLDER shard only. Every shard of the
        SPMD program traces the compute, but the lax.cond branches at
        runtime on axis_index, so non-holder shards skip the einsum
        entirely. On a real fabric the skip is free (the shards run in
        parallel anyway); on forced host devices — where all shards
        time-share one CPU — it removes an NI-fold redundancy that is
        pure harness artifact: the analytic schedule prices the holder's
        compute once. The skipped value is bitwise what the masked
        compute produces on a zero shard (all-False valid -> -inf
        logits): the merge identity, so fanout merge_stacked semantics
        are unchanged."""
        aval = jax.eval_shape(
            lambda a, b, d: absorbed_partial(self.cfg, a, b, d), q, c, v)
        ident = Partial(o=jnp.zeros(aval.o.shape, aval.o.dtype),
                        m=jnp.full(aval.m.shape, NEG_INF, aval.m.dtype),
                        l=jnp.zeros(aval.l.shape, aval.l.dtype))
        return lax.cond(lax.axis_index(AXIS) == holder,
                        lambda: absorbed_partial(self.cfg, q, c, v),
                        lambda: ident)

    @staticmethod
    def _record_resources(rec) -> List:
        """The plan's resource bindings for one dispatch group — the same
        (link, fabric) wire and SM keys build_timeline binds. Two groups
        sharing any of these are ORDERED on the device; groups sharing
        none are independent and their queue wait must not be billed as
        execution (ISSUE 8 wall attribution)."""
        res: List = []
        if rec.link_instance >= 0:
            res.append(TL.link(rec.link_instance, rec.fabric_idx))
        requester = rec.home if rec.home >= 0 else rec.holder
        res.append(TL.sm(rec.holder))
        if requester != rec.holder:
            res.append(TL.sm(requester))
        return res

    def _apportion(self, rec, wall: float, sel_times,
                   step: int) -> Dict[str, float]:
        """Spread one group's fused measured wall over the record's
        planned stage ratios, so the per-stage measured breakdown
        survives fusion. The "index" stage is excluded from the base —
        its wall was measured at PLAN time by the selector's scoring
        collective. Full coverage of the planned stage list is asserted;
        an all-zero planned base falls back to an even split, counted as
        a fill (ISSUE 8 satellite)."""
        names = [n for n, _ in rec.stages]
        meas: Dict[str, float] = {}
        if "index" in names:
            meas["index"] = float(sel_times.get(
                (step, rec.req_ids[0], rec.chunk_id), 0.0))
        rest = [(n, d) for n, d in rec.stages if n != "index"]
        total = sum(d for _, d in rest)
        if rest:
            if total > 0:
                for n, d in rest:
                    meas[n] = wall * (d / total)
            else:
                self._count_fill(rec, len(rest))
                for n, _ in rest:
                    meas[n] = wall / len(rest)
        assert set(meas) == set(names), \
            (rec.primitive, rec.chunk_id, set(names) ^ set(meas))
        return meas

    def _submit_overlapped(self, engine: "ServingEngine", plan: StepPlan,
                           t_wall0: float) -> dict:
        """STACK + DISPATCH of the fused path (ISSUE 8), detached from the
        barrier (ISSUE 10): returns the launch context _await_overlapped
        finishes. Everything here reads only plan-time state — residency
        was committed by plan_step, replica BYTES a prior in-flight step
        has not persisted yet resolve to canonical bytes via _array_on
        (identical content under delta-0 replication), so a submit issued
        before the previous step's merge is value-equivalent."""
        store = engine.store
        reqs = {rq.req_id: rq for rq in plan.requests}
        sels = plan.selections

        def q_of(rid: int) -> jax.Array:
            return self.query_of(reqs[rid], plan.step)

        def mask_of(rid: int, chunk_id: str) -> Optional[np.ndarray]:
            sel = sels.get(rid)
            if sel is None:
                return None
            return np.asarray(sel.masks[chunk_id], bool)

        parts: Dict[int, List[Partial]] = defaultdict(list)
        for rp in plan.resident_pairs:
            arr = self._array_on(store, rp.chunk_id, rp.instance)
            m = mask_of(rp.req_id, rp.chunk_id)
            parts[rp.req_id].append(
                absorbed_partial(self.cfg, q_of(rp.req_id), arr,
                                 None if m is None else jnp.asarray(m)))

        # -- STACK: collect every record's device inputs, ship them in
        # ONE batched transfer ---------------------------------------------
        t0 = time.perf_counter()
        batch = self._asm.begin_batch()
        preps = []
        for i, rec in enumerate(plan.records):
            if rec.backup or not rec.req_ids:
                continue
            if rec.primitive == "route":
                prep = self._prep_route(store, rec, q_of, reqs, mask_of,
                                        batch)
            elif rec.primitive in ("fetch", "fetch_replica"):
                if rec.req_ids[0] in sels:
                    prep = self._prep_fetch_selected(
                        store, rec, q_of, batch, sels[rec.req_ids[0]])
                else:
                    prep = self._prep_fetch(store, rec, q_of, reqs, batch)
            else:
                prep = self._prep_local(store, rec, q_of, reqs, mask_of,
                                        batch)
            preps.append((i, rec, prep))
        bufs = batch.commit()
        t_stack = time.perf_counter() - t0

        # -- DISPATCH: launch every group's fused program in record order
        # with NO intermediate block — JAX's async dispatch pipelines the
        # launches exactly the way the overlap timeline models ---------------
        t0 = time.perf_counter()
        tasks = []
        for i, rec, (launch, post) in preps:
            t_launch, out = launch(bufs)
            tasks.append([i, rec, out, post, t_launch, 0.0])
        t_dispatch = time.perf_counter() - t0
        return {"parts": parts, "tasks": tasks, "t_wall0": t_wall0,
                "t_stack": t_stack, "t_dispatch": t_dispatch}

    def _await_overlapped(self, engine: "ServingEngine", plan: StepPlan,
                          state: dict) -> StepExecution:
        parts, tasks = state["parts"], state["tasks"]
        t_wall0 = state["t_wall0"]
        # per-step fill counter: fills only ever happen in the merge phase
        # (_apportion/_measured_flow), and the engine drains tickets FIFO
        # in a single thread, so resetting here keeps _report per-step
        # accurate even with several submits in flight
        self._fill_count = 0

        # -- BARRIER: block once per step, in launch order -------------------
        t0 = time.perf_counter()
        for task in tasks:
            jax.block_until_ready(task[2])
            task[5] = time.perf_counter()
        t_barrier = time.perf_counter() - t0

        # -- MERGE/account: attribute walls net of same-resource queueing,
        # apportion over planned stage ratios, splice partials per request,
        # persist replicas (the only host round-trip left) -------------------
        t0 = time.perf_counter()
        sel_times = getattr(engine.selector, "measured_index_s",
                            None) or {}
        measured_flows: List[TL.Flow] = []
        last_done: Dict[Any, float] = {}
        for i, rec, out, post, t_launch, t_done in tasks:
            resources = self._record_resources(rec)
            t_ready = max([t_launch]
                          + [last_done.get(r, 0.0) for r in resources])
            wall = max(t_done - t_ready, 1e-9)
            for r in resources:
                last_done[r] = max(last_done.get(r, 0.0), t_done)
            if rec.stages:
                meas = self._apportion(rec, wall, sel_times, plan.step)
                measured_flows.append(self._measured_flow(rec, i, meas))
            post(out, parts)
        outputs = {rid: merge_tree(ps) for rid, ps in parts.items()}
        analytic = self._analytic_timeline(plan)
        report = self._report(plan, analytic, measured_flows, t_wall0,
                              "fused")
        self.phase_wall = {"stack": state["t_stack"],
                           "dispatch": state["t_dispatch"],
                           "barrier": t_barrier,
                           "merge": time.perf_counter() - t0}
        for k, v in self.phase_wall.items():
            self.phase_wall_total[k] = self.phase_wall_total.get(k, 0.0) + v
        return StepExecution(timeline=analytic, outputs=outputs,
                             backend=self.name, measured=report)

    # -- fused per-primitive preps ------------------------------------------
    # Each returns (launch, post): launch(bufs) -> (t_launch, out) issues
    # the group's device work asynchronously (t_launch taken AFTER any
    # cold compile+warm, so compile stays out of the samples); post(out,
    # parts) runs after the step barrier and only slices/merges/persists.

    def _prep_route(self, store, rec, q_of, reqs, mask_of, batch):
        holder = rec.holder
        ckv = self._committed_copy(store, rec.chunk_id, holder)
        mask = mask_of(rec.req_ids[0], rec.chunk_id)
        valid = (np.ones(ckv.shape[0], bool) if mask is None else mask)
        qs = [q_of(rid) for rid in rec.req_ids]
        homes = [reqs[rid].home for rid in rec.req_ids]
        for q, home in zip(qs, homes):
            check_route_shards(AXIS, q, ckv, valid, shard=home)
        cg = batch.add({holder: ckv}, tuple(ckv.shape), self.dtype)
        vg = batch.add({holder: valid}, (valid.shape[0],), jnp.bool_)
        PS = P(AXIS)
        PART = Partial(o=PS, m=PS, l=PS)

        if len(set(homes)) == 1:
            # one home: ship -> compute -> return in ONE program (the
            # probe ppermute existed only to time the wire floor; the
            # apportioning keeps its share of the fused wall)
            requester = homes[0]
            stacked = (jnp.concatenate(qs, axis=0) if len(qs) > 1
                       else qs[0])
            qg = batch.add({requester: stacked}, tuple(stacked.shape),
                           self.dtype)

            def launch(bufs):
                def build():
                    def body(q, c, v):
                        qh = pairwise_ship(q, holder, requester, AXIS)
                        p = self._gated_partial(holder, qh, c, v)
                        return pairwise_return(p, holder, requester, AXIS)
                    return self._shmap(body, (PS, PS, PS), PART)
                args = (bufs[qg], bufs[cg], bufs[vg])
                fn = self._fused_fn(("route-pair", holder, requester),
                                    build, args)
                t_launch = time.perf_counter()
                return t_launch, fn(*args)

            def post(back, parts):
                merged = Partial(*(self._asm.take(x, requester)
                                   for x in back))
                off = 0
                for rid, q in zip(rec.req_ids, qs):
                    n = q.shape[0]
                    parts[rid].append(Partial(o=merged.o[off:off + n],
                                              m=merged.m[off:off + n],
                                              l=merged.l[off:off + n]))
                    off += n
            return launch, post

        # requesters span homes: gather -> compute -> exchange -> merge
        # fused into one program (same padded fanout schedule as serial)
        by_home: Dict[int, List[jax.Array]] = {}
        slices: Dict[int, Tuple[int, int, int]] = {}
        for rid, q, home in zip(rec.req_ids, qs, homes):
            blk = by_home.setdefault(home, [])
            start = sum(x.shape[0] for x in blk)
            blk.append(q)
            slices[rid] = (home, start, q.shape[0])
        b_pad = max(sum(x.shape[0] for x in blk)
                    for blk in by_home.values())
        blocks: Dict[int, jax.Array] = {}
        for home, blk in by_home.items():
            block = jnp.concatenate(blk, axis=0) if len(blk) > 1 else blk[0]
            if block.shape[0] < b_pad:
                pad = jnp.zeros(
                    (b_pad - block.shape[0],) + block.shape[1:],
                    block.dtype)
                block = jnp.concatenate([block, pad], axis=0)
            blocks[home] = block
        sample = next(iter(blocks.values()))
        qg = batch.add(blocks, (b_pad,) + tuple(sample.shape[1:]),
                       self.dtype)

        def launch(bufs):
            def build():
                def body(q, c, v):
                    g = fanout_gather(q, AXIS)
                    p = self._gated_partial(holder, g, c, v)
                    ex = fanout_exchange(p, AXIS)
                    return merge_stacked(ex.o, ex.m, ex.l)
                return self._shmap(body, (PS, PS, PS), PART)
            args = (bufs[qg], bufs[cg], bufs[vg])
            fn = self._fused_fn(("route-fan", holder), build, args)
            t_launch = time.perf_counter()
            return t_launch, fn(*args)

        def post(merged_g, parts):
            merged = {home: Partial(*(self._asm.take(x, home)
                                      for x in merged_g))
                      for home in blocks}
            for rid in rec.req_ids:
                home, start, n = slices[rid]
                mp = merged[home]
                parts[rid].append(Partial(o=mp.o[start:start + n],
                                          m=mp.m[start:start + n],
                                          l=mp.l[start:start + n]))
        return launch, post

    def _prep_fetch(self, store, rec, q_of, reqs, batch):
        src = fetch_source(rec)
        dst = rec.home if rec.home >= 0 else rec.holder
        ckv = self._committed_copy(store, rec.chunk_id, src)
        cg = batch.add({src: ckv}, tuple(ckv.shape), self.dtype)
        pg = batch.add({}, tuple(ckv.shape), self.dtype)
        qh = {rid: batch.put(q_of(rid), dst, key=("q", rid, dst))
              for rid in rec.req_ids}
        PS = P(AXIS)

        def launch(bufs):
            def build():
                def body(pool, c):
                    pulled = fetch_chunk(pool, c, None, 0, self.cfg,
                                         src, dst, AXIS)
                    # splice is elementwise over the last dim, so the
                    # per-shard application equals splicing the taken
                    # shard (what the serial path does)
                    return splice_delta_rotate(pulled, 0, self.cfg)
                return self._shmap(body, (PS, PS), PS)
            args = (bufs[pg], bufs[cg])
            fn = self._fused_fn(("fetch-fused", src, dst), build, args)
            t_launch = time.perf_counter()
            moved_g = fn(*args)
            moved_dev = self._asm.take(moved_g, dst)
            attends = []
            for rid in rec.req_ids:
                q = bufs[qh[rid]]
                afn = self._fused_fn(
                    ("attend", dst),
                    lambda: jax.jit(
                        lambda q, c: absorbed_partial(self.cfg, q, c)),
                    (q, moved_dev))
                p = afn(q, moved_dev)
                home = reqs[rid].home
                if home >= 0 and home != dst:
                    # the partial (not the cache) rides home so every
                    # partial of a request merges on ONE device
                    p = jax.device_put(p, self.devices[home])
                attends.append((rid, p))
            return t_launch, (moved_dev, attends)

        def post(out, parts):
            moved_dev, attends = out
            if rec.home >= 0 and store.resident_on(rec.chunk_id, rec.home):
                self._pool[(rec.chunk_id, rec.home)] = moved_dev
                store.set_replica_data(rec.chunk_id, rec.home,
                                       self._uncommit(moved_dev))
                keys = store.lookup(rec.chunk_id).index_keys
                if keys is not None:
                    store.set_replica_index_keys(rec.chunk_id, rec.home,
                                                 keys)
            for rid, p in attends:
                parts[rid].append(p)
        return launch, post

    def _prep_fetch_selected(self, store, rec, q_of, batch, sel):
        assert rec.primitive == "fetch", (
            f"selection fetch arrived as {rec.primitive!r}: replica spawns "
            "must never batch selected requests")
        rid = rec.req_ids[0]
        idx = np.nonzero(np.asarray(sel.masks[rec.chunk_id]))[0]
        if idx.size == 0:
            q = q_of(rid)
            ident = Partial.identity(q.shape[:-1], self.cfg.kv_lora_rank)
            return ((lambda bufs: (time.perf_counter(), ident)),
                    (lambda out, parts: parts[rid].append(out)))
        src = fetch_source(rec)
        dst = rec.home if rec.home >= 0 else rec.holder
        ckv = self._committed_copy(store, rec.chunk_id, src)
        cg = batch.add({src: ckv}, tuple(ckv.shape), self.dtype)
        pg = batch.add({}, (int(idx.size), ckv.shape[1]), self.dtype)
        qh = batch.put(q_of(rid), dst, key=("q", rid, dst))
        ix = jnp.asarray(idx)
        PS = P(AXIS)

        def launch(bufs):
            def build():
                def body(pool, c, ixa):
                    return fetch_scattered_gather(pool, c, ixa, 0,
                                                  self.cfg, src, dst, AXIS)
                return self._shmap(body, (PS, PS, P()), PS)
            args = (bufs[pg], bufs[cg], ix)
            fn = self._fused_fn(("fetch-gather-fused", src, dst), build,
                                args)
            t_launch = time.perf_counter()
            pulled = fn(*args)
            gathered = self._asm.take(pulled, dst)
            q = bufs[qh]
            afn = self._fused_fn(
                ("attend", dst),
                lambda: jax.jit(
                    lambda q, c: absorbed_partial(self.cfg, q, c)),
                (q, gathered))
            return t_launch, afn(q, gathered)

        def post(p, parts):
            parts[rid].append(p)
        return launch, post

    def _prep_local(self, store, rec, q_of, reqs, mask_of, batch):
        arr = self.ensure_chunk_data(store, rec.chunk_id)
        items = []
        for rid in rec.req_ids:
            inst = (reqs[rid].home if reqs[rid].home >= 0 else rec.holder)
            q_h = batch.put(q_of(rid), inst, key=("q", rid, inst))
            c_h = batch.put(arr, inst, key=("ckv", rec.chunk_id, inst))
            mask = mask_of(rid, rec.chunk_id)
            m_h = (None if mask is None else
                   batch.put(jnp.asarray(mask), inst,
                             key=("mask", rid, rec.chunk_id, inst)))
            items.append((rid, inst, q_h, c_h, m_h))

        def launch(bufs):
            calls = []
            for rid, inst, q_h, c_h, m_h in items:
                if m_h is None:
                    args = (bufs[q_h], bufs[c_h])
                    fn = self._fused_fn(
                        ("prefill", inst),
                        lambda: jax.jit(lambda q, c: absorbed_partial(
                            self.cfg, q, c)), args)
                else:
                    args = (bufs[q_h], bufs[c_h], bufs[m_h])
                    fn = self._fused_fn(
                        ("prefill-mask", inst),
                        lambda: jax.jit(lambda q, c, v: absorbed_partial(
                            self.cfg, q, c, v)), args)
                calls.append((rid, fn, args))
            t_launch = time.perf_counter()
            return t_launch, [(rid, fn(*args)) for rid, fn, args in calls]

        def post(outs, parts):
            for rid, p in outs:
                parts[rid].append(p)
        return launch, post
