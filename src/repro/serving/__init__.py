from repro.serving.engine import (DispatchRecord, EngineConfig, Instance,
                                  Request, ResidentPair, ServingEngine,
                                  StepPlan, StepStats, build_timeline,
                                  transport_latencies)
from repro.serving.backends import (AnalyticBackend, ExecutionBackend,
                                    StepExecution)
from repro.serving.timeline import (Flow, ScheduledStage, Stage, Timeline,
                                    simulate, transport_flow)
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    load_trace, materialize_trace,
                                    register_corpus, save_trace, trace_meta)


def __getattr__(name: str):
    # lazy: JaxExecBackend / IndexerService need jax; everything above is
    # numpy-only and must stay importable without it (see
    # repro.serving.backends and repro.serving.selection).
    if name in ("JaxExecBackend", "TINY_MLA"):
        from repro.serving import backends
        return getattr(backends, name)
    if name in ("IndexerService", "SelectionConfig", "ReplaySelector",
                "RequestSelection"):
        from repro.serving import selection
        return getattr(selection, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
