from repro.serving.engine import (DispatchRecord, EngineConfig, Instance,
                                  Request, ServingEngine, StepStats,
                                  build_timeline, transport_latencies)
from repro.serving.timeline import (Flow, ScheduledStage, Stage, Timeline,
                                    simulate, transport_flow)
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    register_corpus)
