from repro.serving.engine import (DispatchRecord, EngineConfig, Instance,
                                  Request, ServingEngine, StepStats)
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    register_corpus)
