from repro.serving.engine import (EngineConfig, ServingEngine, Instance,
                                  Request)
