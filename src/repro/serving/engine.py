"""Predicate-driven serving engine: the layer that CONSUMES the paper's
cost model (§5: "the serving system that consumes the rule").

Responsibilities per decode step:
  * residency lookup (chunk_store) per (request, chunk);
  * transport choice per the closed-form predicate (core.predicate) with
    the fabric picked from the instance topology (intra-pod ICI vs
    cross-pod DCN — probe latency, not peak bandwidth, §5.5);
  * cross-request dispatcher batching: all queries routed to one holder in
    a step ship as ONE batched dispatch (the §5.3 reduction);
  * per-holder fan-in cap at the N~8 compute elbow (§6.3): beyond it,
    schedule a replica (amortised FETCH) and rebalance;
  * straggler mitigation: a backup dispatch fires to a replica holder when
    a holder's simulated latency exceeds the p99 deadline;
  * fault handling: drop_holder re-homes chunks (replica promotion) and
    orphaned chunks re-enter via LOCAL (re-prefill).

The transport itself can run in two modes: 'sim' (latency bookkeeping from
the cost model — used by benchmarks) and 'exec' (actual JAX math via
core.routing on a single host — used by correctness tests/examples).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core import predicate as P
from repro.core.chunk_store import ChunkStore
from repro.core.constants import Fabric


@dataclasses.dataclass
class Instance:
    idx: int
    pod: int = 0
    # simulated holder-side service-time scale (stragglers: > 1)
    slowdown: float = 1.0
    alive: bool = True


@dataclasses.dataclass
class Request:
    req_id: int
    home: int                      # requester instance
    chunk_ids: List[str]
    m_q: int = 1                   # query rows per chunk this step
    expected_reuse_steps: int = 1
    k_selected: Optional[int] = None


@dataclasses.dataclass
class EngineConfig:
    fanin_cap: int = C.HOLDER_COMPUTE_ELBOW_N      # §6.3 elbow
    staging_streams: int = C.STAGING_STREAMS_ELBOW_K  # §6.2 policy constant
    straggler_p99_factor: float = 3.0              # backup fire threshold
    intra_pod_fabric: str = "tpu_ici"
    cross_pod_fabric: str = "tpu_dcn"
    payload: cm.Payload = cm.MLA_PAYLOAD


@dataclasses.dataclass
class DispatchRecord:
    step: int
    holder: int
    primitive: str
    chunk_id: str
    n_requesters: int
    m_q_total: int
    est_cost_s: float
    backup: bool = False


class ServingEngine:
    def __init__(self, n_instances: int, pool_tokens: int,
                 cfg: EngineConfig = EngineConfig(),
                 instances_per_pod: int = 0):
        self.cfg = cfg
        self.store = ChunkStore(n_instances, pool_tokens)
        ipp = instances_per_pod or n_instances
        self.instances = [Instance(i, pod=i // ipp)
                          for i in range(n_instances)]
        self.log: List[DispatchRecord] = []
        self.step_idx = 0

    # -- topology -------------------------------------------------------------

    def fabric_between(self, a: int, b: int) -> Fabric:
        """Choose by topology; the probe, not peak BW, is what matters at
        decode (§5.5)."""
        if self.instances[a].pod == self.instances[b].pod:
            return C.fabric(self.cfg.intra_pod_fabric)
        return C.fabric(self.cfg.cross_pod_fabric)

    # -- admission ------------------------------------------------------------

    def register_chunk(self, chunk_id: str, holder: int, length: int,
                       position_base: int = 0):
        return self.store.register(chunk_id, holder, length, position_base)

    # -- scheduling one decode step --------------------------------------------

    def schedule_step(self, requests: List[Request]) -> List[DispatchRecord]:
        """Plan all transports for one global decode step: per-chunk
        predicate, cross-request batching per holder, fan-in capping,
        replica spawning."""
        self.step_idx += 1
        # group (holder, chunk) -> [(request, decision)]
        groups: Dict[Tuple[int, str], List[Tuple[Request, P.Decision]]] = \
            defaultdict(list)
        records: List[DispatchRecord] = []

        for rq in requests:
            for cid in rq.chunk_ids:
                chunk = self.store.lookup(cid)
                holders = [h for h in self.store.holders_of(cid)
                           if self.instances[h].alive]
                if not holders:
                    # orphaned: LOCAL re-prefill, then re-home the chunk to
                    # the requester so subsequent steps serve it normally
                    records.append(DispatchRecord(
                        self.step_idx, rq.home, "local", cid, 1, rq.m_q,
                        cm.t_local(chunk.length)))
                    self.store.allocate(rq.home, chunk.length)
                    chunk.holder = rq.home
                    continue
                # nearest live holder by fabric probe
                holder = min(holders, key=lambda h: self.fabric_between(
                    rq.home, h).t_probe_s if h != rq.home else 0.0)
                if holder == rq.home:
                    continue          # resident: free local attention
                dec = P.decide(P.Request(
                    m_q=rq.m_q, c_t=chunk.length,
                    fabric=self.fabric_between(rq.home, holder),
                    payload=self.cfg.payload,
                    expected_reuse_steps=rq.expected_reuse_steps,
                    k_selected=rq.k_selected,
                    n_holders=len(holders)))
                groups[(holder, cid)].append((rq, dec))

        # cross-request dispatcher batching + fan-in capping
        for (holder, cid), entries in groups.items():
            primitive = self._majority_primitive(entries)
            n_req = len(entries)
            if primitive == "route" and n_req > self.cfg.fanin_cap:
                # beyond the elbow: spawn a replica (amortised FETCH) for
                # the overflow and rebalance (§6.3 replication boundary)
                overflow = entries[self.cfg.fanin_cap:]
                entries = entries[: self.cfg.fanin_cap]
                replica = self._spawn_replica(cid, overflow)
                records.append(replica)
                n_req = len(entries)
            m_q_total = sum(rq.m_q for rq, _ in entries)
            fab = self.fabric_between(entries[0][0].home, holder)
            if primitive == "route":
                cost = cm.t_route(fab, m_q_total, self.cfg.payload)
            elif primitive == "fetch":
                cost = cm.t_fetch(fab, self.store.lookup(cid).length,
                                  self.cfg.payload)
            else:
                cost = cm.t_local(self.store.lookup(cid).length)
            cost *= self.instances[holder].slowdown
            rec = DispatchRecord(self.step_idx, holder, primitive, cid,
                                 n_req, m_q_total, cost)
            records.append(rec)
            # straggler mitigation: fire a backup to a replica if the
            # holder's (simulated) latency blows the p99 deadline
            nominal = cost / self.instances[holder].slowdown
            if (self.instances[holder].slowdown
                    >= self.cfg.straggler_p99_factor):
                alt = [h for h in self.store.holders_of(cid)
                       if h != holder and self.instances[h].alive]
                if alt:
                    fab2 = self.fabric_between(entries[0][0].home, alt[0])
                    records.append(DispatchRecord(
                        self.step_idx, alt[0], primitive, cid, n_req,
                        m_q_total,
                        cm.t_route(fab2, m_q_total, self.cfg.payload),
                        backup=True))
        self.log.extend(records)
        return records

    def _majority_primitive(self, entries) -> str:
        votes = defaultdict(int)
        for _, dec in entries:
            votes[dec.primitive.value] += 1
        return max(votes, key=votes.get)

    def _spawn_replica(self, cid: str, overflow) -> DispatchRecord:
        """Amortised FETCH: replicate the chunk onto the requester instance
        with the most overflow demand."""
        by_home = defaultdict(int)
        for rq, _ in overflow:
            by_home[rq.home] += rq.m_q
        target = max(by_home, key=by_home.get)
        chunk = self.store.lookup(cid)
        fab = self.fabric_between(target, chunk.holder)
        self.store.add_replica(cid, target)
        return DispatchRecord(self.step_idx, target, "fetch_replica", cid,
                              len(overflow), sum(m for m in by_home.values()),
                              cm.t_fetch(fab, chunk.length, self.cfg.payload))

    # -- faults ---------------------------------------------------------------

    def fail_instance(self, idx: int) -> List[str]:
        self.instances[idx].alive = False
        return self.store.drop_holder(idx)

    def set_straggler(self, idx: int, slowdown: float):
        self.instances[idx].slowdown = slowdown

    # -- metrics ---------------------------------------------------------------

    def step_latency(self, step: int) -> float:
        """Critical-path latency of one step: max over primary dispatches,
        where a backup caps its primary's contribution."""
        primaries = [r for r in self.log
                     if r.step == step and not r.backup]
        backups = {(r.holder, r.chunk_id): r for r in self.log
                   if r.step == step and r.backup}
        worst = 0.0
        for r in primaries:
            cost = r.est_cost_s
            for b in backups.values():
                if b.chunk_id == r.chunk_id:
                    cost = min(cost, b.est_cost_s)
            worst = max(worst, cost)
        return worst
