"""Predicate-driven serving engine: the layer that CONSUMES the paper's
cost model (§5: "the serving system that consumes the rule").

Since ISSUE 3 a decode step runs through three layers:

  PLAN    (plan_step, this module) — residency resolution (chunk_store),
          ONE vectorized decide_batch() over every non-resident
          (request, chunk) pair (core.predicate: the closed-form §5
          predicate as numpy arrays, fabric picked per pair from the
          instance topology — probe latency, not peak bandwidth, §5.5),
          §8 link-subscription pricing with k_flows DERIVED from observed
          occupancy, per-(holder, chunk, fabric) dispatch batching (§5.3),
          fan-in capping at the N~8 elbow with replica spawns (§6.3),
          fetch persistence (the amortisation the predicate priced
          actually accrues) and LRU replica retirement under pool
          pressure. Output: a StepPlan (repro.serving.plan).
  EXECUTE (a pluggable ExecutionBackend, repro.serving.backends) — the
          AnalyticBackend schedules the plan on the overlap-aware
          transport timeline (repro.serving.timeline: wire stages
          serialize per (link, fabric), holder compute charged
          per-instance, StepStats.latency_s is the MAKESPAN); the
          JaxExecBackend additionally RUNS the planned attention on real
          c^KV arrays and returns actual decode outputs (§3.3 exactness,
          end-to-end through the scheduler).
  ACCOUNT (_account) — StepStats from the plan + the executed timeline.

Straggler backups past the p99 deadline and LOCAL re-homing of orphaned
chunks on holder failure are planned like any other dispatch.

run() drives the loop over a trace (see repro.serving.workload) and emits
per-step StepStats — the substrate benchmarks/bench_serving_steadystate.py
reports p50/p99 step latency and scheduler decisions/sec from.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
import warnings
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core import predicate as P
from repro.core.chunk_store import ChunkStore
from repro.core.constants import Fabric
from repro.serving import timeline as TL
from repro.serving.backends.base import (ExecutionBackend, StepExecution,
                                         StepTicket, await_step, submit_step)
# Plan-layer types live in repro.serving.plan; re-exported here so the
# historical `from repro.serving.engine import ...` imports keep working.
from repro.serving.plan import (DispatchRecord, Request, ResidentPair,
                                StepPlan, StepPlanArrays, StepStats,
                                _critical_path, build_timeline,
                                transport_latencies)

# static stage-code rows for the template-priced dispatch kinds (ISSUE 6)
_ROUTE_CODES = np.array([TL.STAGE_CODE[n]
                         for n in cm.StageTemplates.route_names], np.int64)
_FETCH_CODES = np.array([TL.STAGE_CODE[n]
                         for n in cm.StageTemplates.fetch_names], np.int64)
_LOCAL_CODES = np.array([TL.STAGE_CODE[n]
                         for n in cm.StageTemplates.local_names], np.int64)
_SELR_CODES = np.array([TL.STAGE_CODE[n]
                        for n in cm.StageTemplates.route_selected_names],
                       np.int64)
_SELF_CODES = np.array([TL.STAGE_CODE[n]
                        for n in cm.StageTemplates.fetch_selected_names],
                       np.int64)

__all__ = [
    "DispatchRecord", "EngineConfig", "Instance", "Request", "ResidentPair",
    "ServingEngine", "StepPlan", "StepStats", "build_timeline",
    "transport_latencies",
]


@dataclasses.dataclass
class Instance:
    idx: int
    pod: int = 0
    # simulated holder-side service-time scale (stragglers: > 1)
    slowdown: float = 1.0
    alive: bool = True


@dataclasses.dataclass
class EngineConfig:
    fanin_cap: int = C.HOLDER_COMPUTE_ELBOW_N      # §6.3 elbow
    staging_streams: int = C.STAGING_STREAMS_ELBOW_K  # §6.2 policy constant
    straggler_p99_factor: float = 3.0              # backup fire threshold
    intra_pod_fabric: str = "tpu_ici"
    cross_pod_fabric: str = "tpu_dcn"
    payload: cm.Payload = cm.MLA_PAYLOAD
    congestion_aware: bool = True                  # §8 link-subscription pricing
    persist_fetches: bool = True                   # fetched chunks stay resident
    # ISSUE 6: plan through the columnar array path (StepPlanArrays +
    # timeline.simulate_arrays). False forces the object oracle — the two
    # are bit-identical (tests/test_plan_arrays.py), so this is a kill
    # switch and an A/B handle, not a behavior choice.
    vectorized_plan: bool = True
    # exec mode: steps of decode-output history to retain (outputs hold
    # real arrays; keeping every step would grow memory linearly over a
    # run). < 0 keeps everything.
    retain_outputs: int = 8
    # ISSUE 10: max steps in flight between submit and account. 1 = the
    # historical lockstep plan->execute->account loop (the bit-identical
    # A/B oracle and the kill switch); >= 2 lets the engine plan step N+1
    # while step N's device work runs, hiding the planner wall under the
    # backend's deferred barrier.
    pipeline_depth: int = 1


# one resolved (request, chunk) access, pre-decision
@dataclasses.dataclass
class _Pair:
    rq: Request
    chunk_id: str
    holder: int
    fabric_idx: int
    c_t: int
    n_holders: int


# ISSUE 10: one submitted-but-not-accounted step in the engine's pipeline
@dataclasses.dataclass
class _InFlight:
    plan: StepPlan
    ticket: StepTicket
    t_plan0: float
    t_plan1: float
    t_submit1: float
    plan_wall_s: float
    # planner wall that ran between this step's submit and its await —
    # the wall the pipeline is trying to hide under the device barrier
    overlap_candidate_s: float = 0.0


# ISSUE 10: a plan produced ahead of its schedule_step call. Residency
# changes are plan-determined (plan_step commits promotions/evictions
# before execute runs), so a speculative plan advanced from the previous
# plan's own deltas is exact unless the world mutates in between — the
# epoch captures exactly the inputs a mutation would change.
@dataclasses.dataclass
class _Speculative:
    requests: List[Request]
    plan: StepPlan
    epoch: tuple
    plan_wall_s: float
    t_plan0: float
    t_plan1: float


# an await that returns faster than this never actually blocked on the
# device — treat the step as having hidden nothing (eager backends)
_AWAIT_BLOCK_EPS_S = 5e-5


class ServingEngine:
    def __init__(self, n_instances: int, pool_tokens: int,
                 cfg: EngineConfig = EngineConfig(),
                 instances_per_pod: int = 0,
                 backend: Optional[ExecutionBackend] = None,
                 selector=None, obs=None):
        self.cfg = cfg
        self.store = ChunkStore(n_instances, pool_tokens)
        ipp = instances_per_pod or n_instances
        self.instances = [Instance(i, pod=i // ipp)
                          for i in range(n_instances)]
        if backend is None:
            from repro.serving.backends.analytic import AnalyticBackend
            backend = AnalyticBackend()
        self.backend: ExecutionBackend = backend
        # §5.4 selection regime (ISSUE 4): the indexer that turns a
        # request's k_selected budget into per-(request, holder) masks —
        # repro.serving.selection.IndexerService (live scoring) or
        # ReplaySelector (recorded trace). None: selection requests are
        # PRICED but executed dense, warn-once + counted in StepStats.
        self.selector = selector
        self._warned_selection_fallback = False
        self.log: List[DispatchRecord] = []
        self.stats: List[StepStats] = []
        self.plans: List[StepPlan] = []          # parallel to self.stats
        self.timelines: List[TL.Timeline] = []   # parallel to self.stats
        # exec-mode decode outputs per step: req_id -> merged Partial
        # (empty dicts under the analytic backend)
        self.step_outputs: List[Dict[int, object]] = []
        # measured-vs-analytic reports per step (ISSUE 7): a
        # timeline.MeasuredReport when the backend timed real collectives
        # (the shard_map backend), else None — parallel to self.stats
        self.measured_reports: List[Optional[TL.MeasuredReport]] = []
        self.step_idx = 0
        # fabric table shared by every decide_batch call: idx 0 = intra-pod,
        # idx 1 = cross-pod
        self._fa = cm.FabricArrays.from_fabrics(
            [C.fabric(cfg.intra_pod_fabric), C.fabric(cfg.cross_pod_fabric)])
        # broadcast-assembled §4 stage templates + the store's columnar
        # residency snapshot, cached on ChunkStore.version (ISSUE 6)
        self._templates = cm.StageTemplates(self._fa, cfg.payload)
        self._mirror: Optional[dict] = None
        self._ntab: Optional[dict] = None
        # phase-1 cross-step cache (ISSUE 6): resolved pairs + grouping,
        # keyed on the residency epoch and the request-set signature
        self._p1: Optional[dict] = None
        # §5 decision memo: pricing-column combo -> costs + per-reuse codes,
        # and the §8 congested-route cost per (m_q, fabric, k_flows) point.
        # Both are pure functions of the cost model, never invalidated.
        self._dec_memo: Dict[tuple, list] = {}
        self._cong_memo: Dict[tuple, float] = {}
        # planner-cache effectiveness counters (ISSUE 9): plain ints bumped
        # on the hot path (one integer add each — cheap enough to keep
        # unconditionally), published through planner_cache_stats() and the
        # obs metrics registry. sig = per-request signature cache, step =
        # full-step column replay, p3 = phase-3/4 assembly replay, dec =
        # §5 decision memo, obj_fallback = array planner bailed to objects.
        self._n_sig_hit = 0
        self._n_sig_miss = 0
        self._n_step_replay_hit = 0
        self._n_step_replay_miss = 0
        self._n_p3_hit = 0
        self._n_dec_hit = 0
        self._n_dec_miss = 0
        self._n_obj_fallback = 0
        # ISSUE 10 pipeline state: FIFO of submitted-not-yet-accounted
        # steps (at most pipeline_depth - 1 after schedule_step returns),
        # plus at most one speculative plan for the step after that.
        self._inflight: List[_InFlight] = []
        self._spec: Optional[_Speculative] = None
        self.misspeculation_replans = 0
        # planner seconds that demonstrably ran under a blocked device
        # barrier (the pipelining win, published through obs)
        self.planner_overlap_s = 0.0
        # per accounted step, the wall plan_step took (speculative or not)
        self.plan_walls: List[float] = []
        # the flight recorder (ISSUE 9): NULL_OBS is an inert singleton —
        # the step path pays one identity comparison when observability is
        # off. A live Obs gets every accounted step via obs.on_step.
        from repro.obs import NULL_OBS
        self.obs = NULL_OBS if obs is None else obs
        if self.obs.enabled:
            self.obs.bind_engine(self)

    # -- topology -------------------------------------------------------------

    def fabric_idx_between(self, a: int, b: int) -> int:
        """0 (intra-pod) or 1 (cross-pod); the probe, not peak BW, is what
        matters at decode (§5.5)."""
        return 0 if self.instances[a].pod == self.instances[b].pod else 1

    def fabric_between(self, a: int, b: int) -> Fabric:
        name = (self.cfg.intra_pod_fabric
                if self.fabric_idx_between(a, b) == 0
                else self.cfg.cross_pod_fabric)
        return C.fabric(name)

    # -- admission ------------------------------------------------------------

    def register_chunk(self, chunk_id: str, holder: int, length: int,
                       position_base: int = 0, data=None):
        return self.store.register(chunk_id, holder, length, position_base,
                                   data=data)

    # -- pool pressure ---------------------------------------------------------

    def _make_resident(self, chunk_id: str, instance: int) -> bool:
        """Replicate chunk onto instance, retiring cold replicas LRU under
        pool pressure. Returns False when it cannot fit (replication is an
        optimisation — never evict hotter data to force it)."""
        chunk = self.store.lookup(chunk_id)
        if self.store.resident_on(chunk_id, instance):
            return True
        need = chunk.length
        if self.store.capacity_left(instance) < need:
            victims = sorted(
                self.store.replicas_on(instance),
                key=lambda cid: self.store.lookup(cid).last_access)
            for vic in victims:
                if self.store.lookup(vic).last_access >= chunk.last_access:
                    break          # nothing colder than the newcomer
                self.store.evict_replica(vic, instance)
                self._evictions_this_step += 1
                if self.store.capacity_left(instance) >= need:
                    break
        if self.store.capacity_left(instance) < need:
            return False
        self.store.add_replica(chunk_id, instance)
        return True

    # -- PLAN: one decode step -------------------------------------------------

    def plan_step(self, requests: List[Request]) -> StepPlan:
        """Plan all transports for one global decode step: batched
        predicate, per-(holder, chunk, fabric) dispatch batching, link
        congestion pricing, fan-in capping, replica persistence. Planning
        COMMITS residency state (persisted fetches, replica spawns, LRU
        evictions); execution replays the plan without re-deciding.

        Since ISSUE 6 the hot path is `_plan_step_arrays` (columnar
        residency resolution + template-priced dispatch assembly); the
        original object planner survives verbatim as `_plan_step_objects`,
        the oracle the array path is pinned bit-identical to, and the
        fallback for the rare step shapes the array path does not carry
        (orphaned chunks on a dead holder)."""
        self.step_idx += 1
        self._evictions_this_step = 0
        selections, selection_fallbacks = self._plan_selections(requests)
        if self.cfg.vectorized_plan:
            plan = self._plan_step_arrays(requests, selections,
                                          selection_fallbacks)
            if plan is not None:
                return plan
            self._n_obj_fallback += 1
        return self._plan_step_objects(requests, selections,
                                       selection_fallbacks)

    def _plan_selections(self, requests: List[Request]):
        """Phase 0: the indexer's selections (§5.4, ISSUE 4). Score ->
        select happens BEFORE residency resolution: the masks are a
        per-request property (the global top-k over the request's chunks),
        independent of which holder ends up serving each shard."""
        selections: Dict[int, object] = {}
        selection_fallbacks = 0
        sel_reqs = [rq for rq in requests if rq.k_selected is not None]
        if sel_reqs:
            if self.selector is not None:
                selections = self.selector.select_step(self, sel_reqs,
                                                       self.step_idx)
            else:
                selection_fallbacks = len(sel_reqs)
                self._warn_selection_fallback()
        return selections, selection_fallbacks

    def _plan_step_objects(self, requests: List[Request],
                           selections: Dict[int, object],
                           selection_fallbacks: int) -> StepPlan:
        """The original per-request object planner — the exactness oracle
        for `_plan_step_arrays` and the fallback for orphaned-chunk steps."""
        replicas_spawned = 0
        records: List[DispatchRecord] = []
        resident_pairs: List[ResidentPair] = []
        pairs: List[_Pair] = []
        n_resident = 0
        n_pairs = 0
        # distinct instances a request's selection spans — the M of the
        # §5.4 fan-out/gather the predicate prices (resident shards count
        # their home)
        span: Dict[int, set] = {rid: set() for rid in selections}

        # -- phase 1: residency resolution ---------------------------------
        for rq in requests:
            selected = rq.req_id in selections
            for cid in rq.chunk_ids:
                n_pairs += 1
                chunk = self.store.lookup(cid)
                self.store.touch(cid, self.step_idx)
                holders = [h for h in self.store.holders_of(cid)
                           if self.instances[h].alive]
                if not holders:
                    # orphaned: LOCAL re-prefill, then re-home the chunk to
                    # the requester so subsequent steps serve it normally
                    sd = self.instances[rq.home].slowdown
                    records.append(DispatchRecord(
                        self.step_idx, rq.home, "local", cid, 1, rq.m_q,
                        cm.t_local(chunk.length,
                                   self.cfg.payload.n_layers) * sd,
                        home=rq.home,
                        stages=cm.scale_stages(
                            cm.local_stages(chunk.length,
                                            self.cfg.payload.n_layers), sd),
                        req_ids=(rq.req_id,)))
                    self.store.rehome(cid, rq.home)
                    if selected:
                        span[rq.req_id].add(rq.home)
                    continue
                # nearest live holder by fabric probe (home wins if resident)
                holder = min(holders, key=lambda h: 0.0 if h == rq.home
                             else self.fabric_between(rq.home, h).t_probe_s)
                if selected:
                    span[rq.req_id].add(holder)
                if holder == rq.home:
                    n_resident += 1    # resident: free local attention
                    resident_pairs.append(
                        ResidentPair(rq.req_id, cid, rq.home))
                    continue
                fi = self.fabric_idx_between(rq.home, holder)
                pairs.append(_Pair(rq, cid, holder, fi,
                                   chunk.length, len(holders)))

        # -- phase 2: one vectorized predicate over all pairs ---------------
        if pairs:
            # under an ACTIVE selection, the predicate's n_holders is the M
            # the request's selection SPANS (the §5.4 fan-out/gather width),
            # not the chunk's replica count; without a selector the historic
            # per-chunk count is kept so priced-only runs stay bit-stable
            def _n_holders(p: _Pair) -> int:
                if p.rq.req_id in selections:
                    return max(1, len(span[p.rq.req_id]))
                return p.n_holders
            batch = P.RequestBatch(
                fabrics=self._fa,
                m_q=np.array([p.rq.m_q for p in pairs], np.int64),
                c_t=np.array([p.c_t for p in pairs], np.int64),
                fabric_idx=np.array([p.fabric_idx for p in pairs], np.int64),
                expected_reuse_steps=np.array(
                    [p.rq.expected_reuse_steps for p in pairs], np.int64),
                k_selected=np.array(
                    [-1 if p.rq.k_selected is None else p.rq.k_selected
                     for p in pairs], np.int64),
                n_holders=np.array([_n_holders(p) for p in pairs], np.int64),
                position_delta=np.ones(len(pairs), np.int64),
                holder_can_compute=np.ones(len(pairs), bool),
                host_overhead=np.zeros(len(pairs), bool),
                payload=self.cfg.payload)
            # link subscription (§8): one batched dispatch per
            # (holder, chunk, fabric) group = one flow on the
            # (holder, fabric) link. The k_flows premium is DERIVED from
            # observed occupancy, not assumed from raw group counts: an
            # uncontended pass decides provisional primitives, only groups
            # that elect a transport (ROUTE/FETCH) occupy their link, and
            # the observed per-link flow count re-prices the batch. (One
            # relaxation round: a group the congested pass flips to LOCAL
            # still counts toward the occupancy its neighbours saw.)
            # selection pairs group PER REQUEST (4th key component): each
            # request's masks differ, and its indexer round trip + masked
            # partial is its own flow on the holder's link — dense pairs
            # keep the historic 3-way batching (srid = -1)
            group_keys = [(p.holder, p.chunk_id, p.fabric_idx,
                           p.rq.req_id if p.rq.req_id in selections else -1)
                          for p in pairs]
            if self.cfg.congestion_aware:
                dec0 = P.decide_batch(batch, None)
                k_flows = self._occupancy_k_flows(pairs, group_keys, dec0)
                # the §8 premium is flat through K<=2: re-pricing is the
                # identity unless some link is actually subscribed past
                # the knee — skip the second pass in the common case
                dec = (P.decide_batch(batch, k_flows)
                       if int(k_flows.max()) >= 3 else dec0)
            else:
                k_flows, dec = None, P.decide_batch(batch, None)
        else:
            group_keys, k_flows, dec = [], None, None

        # -- phase 3: dispatch batching + fan-in + persistence --------------
        groups: Dict[Tuple[int, str, int, int], List[int]] = defaultdict(list)
        for i, key in enumerate(group_keys):
            groups[key].append(i)
        # fan-in cap is a property of the HOLDER's compute elbow: per
        # (holder, chunk) at most fanin_cap requesters route, ACROSS fabric
        # sub-groups — a shared budget drained as dispatches are planned
        route_budget: Dict[Tuple[int, str], int] = defaultdict(
            lambda: self.cfg.fanin_cap)

        for (holder, cid, fi, srid), idxs in sorted(groups.items(),
                                                    key=lambda kv: kv[0][:2]):
            entries = [pairs[i] for i in idxs]
            votes = defaultdict(int)
            for i in idxs:
                votes[int(dec.code[i])] += 1
            code = max(votes, key=votes.get)
            primitive = P.PRIMITIVE_BY_CODE[code].value
            sel = selections.get(srid) if srid >= 0 else None
            # selection routes sit outside the §6.3 fan-in budget: the
            # elbow is a FULL-chunk batched-partial property, and selected
            # compute is scaled to the budget KB far below it
            if primitive == "route" and sel is None:
                keep = min(len(idxs), max(0, route_budget[(holder, cid)]))
                if keep < len(idxs):
                    # beyond the elbow: spawn a replica (amortised FETCH)
                    # for the overflow and rebalance (§6.3 boundary)
                    overflow, idxs = idxs[keep:], idxs[:keep]
                    rep = self._spawn_replica(
                        cid, [pairs[i] for i in overflow])
                    if rep is not None:
                        records.append(rep)
                        replicas_spawned += 1
                    else:          # no room anywhere: keep them on the batch
                        idxs = idxs + overflow
                    entries = [pairs[i] for i in idxs]
                    if not entries:
                        continue
                # clamp at 0: a failed replica spawn can overdraw the
                # budget, but a negative balance must not leak into the
                # NEXT sub-group's slice arithmetic
                route_budget[(holder, cid)] = max(
                    0, route_budget[(holder, cid)] - len(entries))
            n_req = len(entries)
            m_q_total = sum(p.rq.m_q for p in entries)
            fab = C.fabric(self._fa.names[fi])
            chunk = self.store.lookup(cid)
            if primitive == "local":
                # re-prefill runs at each REQUESTER, not the holder: one
                # dispatch per requesting home, at that home's speed, and
                # no transport => no straggler backup
                by_home: Dict[int, List[_Pair]] = defaultdict(list)
                for p in entries:
                    by_home[p.rq.home].append(p)
                for home, ps in sorted(by_home.items()):
                    sd = self.instances[home].slowdown
                    records.append(DispatchRecord(
                        self.step_idx, home, "local", cid, len(ps),
                        sum(p.rq.m_q for p in ps),
                        cm.t_local(chunk.length,
                                   self.cfg.payload.n_layers) * sd,
                        home=home,
                        stages=cm.scale_stages(
                            cm.local_stages(chunk.length,
                                            self.cfg.payload.n_layers), sd),
                        req_ids=tuple(p.rq.req_id for p in ps)))
                continue
            # timeline stage durations are UNCONTENDED (k=0): on the
            # timeline, §8 queueing is simulated — flows serialize on the
            # shared (link, fabric) resource — while est_cost_s keeps the
            # congested closed form the predicate priced the pairs with
            dest = self._busiest_home(entries)
            if sel is not None:
                # §5.4 selection dispatch: the indexer round trip leads the
                # stage chain, holder compute/gather scale with the budget
                # resident HERE (selected & resident — possibly 0: the
                # query still fans out, the partial merges as identity),
                # FETCH gathers scattered entries and never persists (the
                # selection is re-chosen next step), and no straggler
                # backup shadows it.
                rq0 = entries[0].rq
                bt = self.selector.block_tokens
                # candidates on the wire: the budget in blocks, capped by
                # what this holder could possibly return
                kb_wire = min(max(1, -(-int(rq0.k_selected) // bt)),
                              max(1, -(-chunk.length // bt)))
                k_local = sel.k_on(cid)
                d_index = self.selector.d_index
                if primitive == "route":
                    kf = (int(k_flows[idxs[0]])
                          if self.cfg.congestion_aware else 0)
                    frac = min(1.0, k_local / max(1, chunk.length))
                    cost = cm.t_route_selected_full(
                        fab, m_q_total, kf, frac, kb_wire, d_index,
                        self.cfg.payload)
                    stages = cm.route_selected_stages(
                        fab, m_q_total, 0, frac, kb_wire, d_index,
                        self.cfg.payload)
                else:          # fetch: scattered gather of the local picks
                    cost = cm.t_fetch_selected(
                        fab, k_local, m_q_total, kb_wire, d_index,
                        self.cfg.payload)
                    stages = cm.fetch_selected_stages(
                        fab, k_local, m_q_total, kb_wire, d_index,
                        self.cfg.payload)
                sd = self.instances[holder].slowdown
                records.append(DispatchRecord(
                    self.step_idx, holder, primitive, cid, n_req, m_q_total,
                    cost * sd, fabric_idx=fi, link_instance=holder,
                    home=dest, stages=cm.scale_stages(stages, sd),
                    req_ids=tuple(p.rq.req_id for p in entries)))
                continue
            if primitive == "route":
                kf = (int(k_flows[idxs[0]])
                      if self.cfg.congestion_aware else 0)
                # same formula the predicate priced the pairs with
                cost = cm.t_route_congested_full(fab, m_q_total, kf,
                                                 self.cfg.payload)
                stages = cm.route_stages(fab, m_q_total, 0, self.cfg.payload)
            else:                  # fetch
                raw = cm.t_fetch(fab, chunk.length, self.cfg.payload)
                persisted = False
                if self.cfg.persist_fetches:
                    persisted = self._make_resident(cid, dest)
                if persisted:
                    # amortised exactly as the predicate priced it (§5.5
                    # rule 2): the pull+splice is paid once and the copy
                    # stays resident for the reuse horizon
                    reuse = max(p.rq.expected_reuse_steps for p in entries)
                    cost = raw / max(1, reuse)
                else:
                    # the copy could not persist (pool pressure or
                    # persistence off): the pull+splice really is paid
                    # every time, so no amortisation discount
                    reuse = 1
                    cost = raw
                stages = cm.fetch_stages(fab, chunk.length, self.cfg.payload,
                                         reuse_steps=reuse)
            sd = self.instances[holder].slowdown
            cost *= sd
            records.append(DispatchRecord(
                self.step_idx, holder, primitive, cid, n_req, m_q_total,
                cost, fabric_idx=fi, link_instance=holder, home=dest,
                stages=cm.scale_stages(stages, sd),
                req_ids=tuple(p.rq.req_id for p in entries)))
            # straggler mitigation: fire a backup to a replica if the
            # holder's (simulated) latency blows the p99 deadline
            if (self.instances[holder].slowdown
                    >= self.cfg.straggler_p99_factor):
                alt = [h for h in self.store.holders_of(cid)
                       if h != holder and self.instances[h].alive]
                if alt:
                    # the least-loaded live replica — backing up onto
                    # another straggler helps nobody
                    tgt = min(alt, key=lambda h: self.instances[h].slowdown)
                    fab2 = self.fabric_between(entries[0].rq.home, tgt)
                    fi2 = self.fabric_idx_between(entries[0].rq.home, tgt)
                    sd2 = self.instances[tgt].slowdown
                    backup_cost = (
                        cm.t_route(fab2, m_q_total, self.cfg.payload)
                        if primitive == "route"
                        else cm.t_fetch(fab2, chunk.length, self.cfg.payload)
                    ) * sd2
                    backup_stages = (
                        cm.route_stages(fab2, m_q_total, 0, self.cfg.payload)
                        if primitive == "route"
                        else cm.fetch_stages(fab2, chunk.length,
                                             self.cfg.payload))
                    records.append(DispatchRecord(
                        self.step_idx, tgt, primitive, cid, n_req,
                        m_q_total, backup_cost, backup=True,
                        fabric_idx=fi2, link_instance=tgt, home=dest,
                        stages=cm.scale_stages(backup_stages, sd2),
                        req_ids=tuple(p.rq.req_id for p in entries)))

        return StepPlan(
            step=self.step_idx, requests=list(requests), records=records,
            resident_pairs=resident_pairs, n_pairs=n_pairs,
            n_priced=len(pairs), n_resident=n_resident,
            replicas_spawned=replicas_spawned,
            evictions=self._evictions_this_step,
            selections=selections,
            selection_fallbacks=selection_fallbacks)

    # -- the ISSUE 6 columnar planner ----------------------------------------

    def _residency_mirror(self) -> dict:
        """Columnar snapshot of the chunk store (ids in insertion order,
        lengths, [canonical] + replicas holder matrix), cached on
        ChunkStore.version so steady-state steps pay zero rebuild cost."""
        v = self.store.version
        mir = self._mirror
        if mir is None or mir["version"] != v:
            ids, length, holders, chunks = self.store.residency_columns()
            # rank[i] = position of ids[i] in sorted-by-chunk-id order, so
            # integer (holder, rank) sort keys reproduce the object
            # planner's (holder, chunk_id) string order exactly (ids are
            # unique, making rank a total order consistent with the string
            # order)
            rank = [0] * len(ids)
            for r, i in enumerate(sorted(range(len(ids)),
                                         key=ids.__getitem__)):
                rank[i] = r
            mir = {"version": v, "ids": ids, "length": length,
                   "length_l": length.tolist(), "rank": rank,
                   "holders": holders, "chunks": chunks,
                   "index": {cid: i for i, cid in enumerate(ids)}}
            self._mirror = mir
        return mir

    def _nearest_table(self, mir: dict) -> dict:
        """Per-(chunk, home) nearest-live-holder table, cached on the
        (store version, instance aliveness) epoch. One vectorized argmin
        over (chunk, home, holder-slot) replaces per-step per-pair probe
        pricing: resolving a pair becomes two nested-list lookups. The
        tie-break is argmin's first minimum over the [canonical] +
        replicas columns — exactly the object planner's min(). Entries
        for orphaned chunks (live == 0) are garbage; callers must check
        `live` first (the planner falls back to the object path)."""
        av = tuple(i.alive for i in self.instances)
        nt = self._ntab
        if (nt is not None and nt["version"] == mir["version"]
                and nt["alive"] == av):
            return nt
        Hm = mir["holders"]                          # (nc, W)
        nc = Hm.shape[0]
        if nc == 0:
            nt = {"version": mir["version"], "alive": av, "Hm": Hm,
                  "holder": [], "live": [], "fi": [], "changed": None}
            self._ntab = nt
            return nt
        # incremental rebuild: a version bump from a replica spawn /
        # persist / eviction touches a handful of chunks — when aliveness
        # and matrix shape are unchanged, recompute only the rows whose
        # holder sets differ and patch them in place. `changed` carries the
        # dirty chunk rows to the planner's epoch-delta splice (None means
        # everything may have moved).
        if (nt is not None and nt["alive"] == av and "Hm" in nt
                and nt["Hm"].shape == Hm.shape):
            rows = np.nonzero((nt["Hm"] != Hm).any(axis=1))[0]
            if rows.shape[0] <= (nc >> 2):
                sub = self._ntab_rows(Hm, av, rows)
                holder_l, live_l, fi_l_ = nt["holder"], nt["live"], nt["fi"]
                hs, ls, fs = (sub["holder"].tolist(), sub["live"].tolist(),
                              sub["fi"].tolist())
                for x, ci in enumerate(rows.tolist()):
                    holder_l[ci] = hs[x]
                    live_l[ci] = ls[x]
                    fi_l_[ci] = fs[x]
                nt["prev"] = nt["version"]
                nt["version"] = mir["version"]
                nt["Hm"] = Hm
                nt["changed"] = set(rows.tolist())
                return nt
        sub = self._ntab_rows(Hm, av, None)
        nt = {"version": mir["version"], "alive": av, "Hm": Hm,
              "holder": sub["holder"].tolist(), "live": sub["live"].tolist(),
              "fi": sub["fi"].tolist(), "changed": None}
        self._ntab = nt
        return nt

    def _ntab_rows(self, Hm: np.ndarray, av: tuple,
                   rows: Optional[np.ndarray]) -> dict:
        """The nearest-table argmin for a row subset (all rows when None)."""
        if rows is not None:
            Hm = Hm[rows]
        nc = Hm.shape[0]
        n_inst = len(self.instances)
        pod = np.fromiter((i.pod for i in self.instances), np.int64, n_inst)
        alive = np.asarray(av, bool)
        Hc = np.clip(Hm, 0, None)
        alive_m = (Hm >= 0) & alive[Hc]              # (nc, W)
        live = alive_m.sum(axis=1)
        inst = np.arange(n_inst)
        probe = np.where(pod[Hc][:, None, :] == pod[None, :, None],
                         self._fa.t_probe_s[0], self._fa.t_probe_s[1])
        keyc = np.where(Hm[:, None, :] == inst[None, :, None], 0.0, probe)
        keyc = np.where(alive_m[:, None, :], keyc, np.inf)
        am = np.argmin(keyc, axis=2)                 # (nc, n_inst)
        holder_tab = Hm[np.arange(nc)[:, None], am]
        fi_tab = (pod[np.clip(holder_tab, 0, None)]
                  != pod[None, :]).astype(np.int64)
        return {"holder": holder_tab, "live": live, "fi": fi_tab}

    def _pair_entry(self, mq: int, ct: int, fi: int, ksel: int,
                    nh: int) -> list:
        """Decision-memo entry for one pricing-column combo: the §5 costs
        that do not depend on the reuse countdown, evaluated once through
        the SAME cost-model batch functions the full-width predicate uses
        (1-element arrays — pure element-wise math, so each lane is bitwise
        what a wide pass produces). Layout: [t_route, t_local, fetch_core,
        is_selection, {reuse -> (code, t_fetch)}] where fetch_core is the
        scattered-gather cost under selection (reuse-independent, §5.4) or
        the UN-amortised bulk pull otherwise."""
        memo = self._dec_memo
        key = (mq, ct, fi, ksel, nh)
        ent = memo.get(key)
        if ent is not None:
            self._n_dec_hit += 1
        else:
            self._n_dec_miss += 1
        if ent is None:
            fa = self._fa
            pay = self.cfg.payload
            fi_a = np.array([fi], np.int64)
            mq_a = np.array([mq], np.int64)
            if ksel >= 0 and nh > 1:
                tr = cm.t_route_fanout_batch(
                    fa, fi_a, mq_a, np.array([max(nh, 1)], np.int64), pay)
            else:
                tr = cm.t_route_batch(fa, fi_a, mq_a, pay)
            tl = cm.t_local_batch(np.array([ct], np.int64), pay.n_layers,
                                  C.PREFILL_PER_TOKEN_LAYER_MID_S)
            if ksel >= 0:
                aux = cm.t_fetch_scattered_batch(
                    fa, fi_a, np.array([max(ksel, 0)], np.int64),
                    np.array([max(nh, 1)], np.int64), pay)
            else:
                aux = cm.t_fetch_batch(fa, fi_a, np.array([ct], np.int64),
                                       pay, np.array([True]))
            ent = memo[key] = [float(tr[0]), float(tl[0]), float(aux[0]),
                               ksel >= 0, {}]
        return ent

    def _plan_step_arrays(self, requests: List[Request],
                          selections: Dict[int, object],
                          selection_fallbacks: int) -> Optional[StepPlan]:
        """Columnar plan_step (ISSUE 6): one vectorized residency pass over
        all (request, chunk) pairs, one decide_batch (plus an incremental
        §8 repricing of only the pairs whose link crossed the congestion
        knee), and template-priced dispatch assembly straight into
        StepPlanArrays columns. The Python control pass that remains runs
        per GROUP (fan-in budget, persistence, backups — a handful per
        step), never per pair. Returns None when the step needs the object
        fallback: a chunk with no live holder (mid-step re-homing)."""
        step = self.step_idx
        cfg = self.cfg
        mir = self._residency_mirror()
        ids: Tuple[str, ...] = mir["ids"]
        idx_of = mir["index"]
        chunks = mir["chunks"]
        length_l = mir["length_l"]
        slowdown = [i.slowdown for i in self.instances]
        ntab = self._nearest_table(mir)
        holder_tab = ntab["holder"]
        live_tab = ntab["live"]
        fi_tab = ntab["fi"]

        # -- phase 1: residency resolution, one Python pass over pairs ------
        # (per-pair work is two table lookups; pair order == the object
        # planner's, so column order and group insertion order match it)
        #
        # Cross-step cache, two layers keyed on the (store version,
        # aliveness) residency epoch. A request's resolution — which pairs
        # are resident, each priced pair's holder / fabric / group key —
        # depends only on the epoch and the request's own fields MINUS the
        # reuse countdown, so it is cached per request and spliced into the
        # step columns; when the whole request SET repeats (no session
        # rolled over), the assembled columns themselves are reused and the
        # splice is skipped too. Reuse, the one per-step-varying column, is
        # rebuilt from the live requests either way.
        epoch = (mir["version"], ntab["alive"])
        p1 = self._p1
        force_k0 = -1      # first residency-dirty request under epoch delta
        if p1 is not None and p1["epoch"] != epoch:
            # Epoch delta: when the nearest table knows exactly which chunk
            # rows moved since the version this cache was built against
            # (and aliveness held), only cache entries touching those
            # chunks are stale — prune them and force the step splice to
            # restart at the first dirty request instead of discarding
            # everything.
            ch = (ntab["changed"]
                  if (ntab.get("prev"), ntab["alive"]) == p1["epoch"]
                  else None)
            if ch is None:
                p1 = None
            else:
                rc = p1["req"]
                for rk in [rk for rk, ent in rc.items()
                           if any(idx_of[c] in ch for c in ent[-1])]:
                    del rc[rk]
                stp = p1["step"]
                if stp is not None:
                    sg = stp["sig"]
                    force_k0 = len(sg)
                    for k in range(len(sg)):
                        if any(idx_of[c] in ch for c in sg[k][5]):
                            force_k0 = k
                            break
                p1["epoch"] = epoch
        if p1 is None:
            p1 = self._p1 = {"epoch": epoch, "req": {}, "step": None}
        rcache: Dict[tuple, tuple] = p1["req"]
        st = p1["step"]
        nreq = len(requests)
        k0 = -1                        # first request needing a (re)splice
        if st is not None and len(st["sig"]) == nreq:
            sig = st["sig"]
            k0 = nreq
            for k, rq in enumerate(requests):
                s = sig[k]
                if (s[0] != rq.req_id or s[1] != rq.home
                        or s[2] != rq.m_q or s[3] != rq.k_selected
                        or s[4] != (rq.req_id in selections)
                        or s[5] != rq.chunk_ids):
                    k0 = k
                    break
            if 0 <= force_k0 < k0:
                k0 = force_k0
        full_hit = k0 == nreq
        if full_hit:
            self._n_step_replay_hit += 1
        else:
            self._n_step_replay_miss += 1
        if full_hit:                                 # whole step repeated
            for c in st["touch"]:            # replica-LRU touch, idempotent
                c.last_access = step
            resident_pairs = st["resident"]
            n_pairs = st["n_pairs"]
            pair_req = st["pair_req"]
            (mq_l, ct_l, fi_l, ksel_l, nh_l, home_l, rid_l, hold_l,
             groups, pkey_l, dec_l) = st["cols"]
        else:
            if k0 < 0:                               # no reusable prefix
                k0 = 0
                # cols: mq, ct, fi, ksel, nh, home, rid, hold, then the
                # (holder, chunk idx, fabric idx, selection req) ->
                # priced-pair-rows dict in first-occurrence order (the
                # object planner's group key), pair -> group key, and
                # pair -> decision-memo entry
                st = p1["step"] = {
                    "sig": [], "touch": [], "pair_req": [], "resident": [],
                    "n_pairs": 0,
                    # per-request cumulative offsets into pairs / priced
                    # pairs / residents / touches — the delta-splice cut
                    # points
                    "np_off": [0], "p_off": [0], "r_off": [0], "t_off": [0],
                    "cols": ([], [], [], [], [], [], [], [], {}, [], [])}
            sig = st["sig"]
            touch = st["touch"]
            pair_req = st["pair_req"]
            resident_pairs = st["resident"]
            np_off = st["np_off"]
            p_off = st["p_off"]
            r_off = st["r_off"]
            t_off = st["t_off"]
            (mq_l, ct_l, fi_l, ksel_l, nh_l, home_l, rid_l, hold_l,
             groups, pkey_l, dec_l) = st["cols"]
            # Delta splice: requests before k0 verified unchanged, so their
            # column rows are already right — truncate everything past
            # their boundary and replay only the suffix. Group member
            # lists hold pair rows in ascending order, so each suffix pair
            # sits at its group's tail; popping in reverse pair order and
            # deleting emptied groups restores exactly the dict state
            # (insertion order included) a prefix-only build would have
            # produced, and the replay then re-inserts suffix-first groups
            # at the end — the fresh-build order.
            cut_p = p_off[k0]
            for j in range(len(pkey_l) - 1, cut_p - 1, -1):
                g = groups[pkey_l[j]]
                g.pop()
                if not g:
                    del groups[pkey_l[j]]
            del mq_l[cut_p:]
            del ct_l[cut_p:]
            del fi_l[cut_p:]
            del ksel_l[cut_p:]
            del nh_l[cut_p:]
            del home_l[cut_p:]
            del rid_l[cut_p:]
            del hold_l[cut_p:]
            del pkey_l[cut_p:]
            del dec_l[cut_p:]
            del pair_req[cut_p:]
            del sig[k0:]
            del resident_pairs[r_off[k0]:]
            del touch[t_off[k0]:]
            del np_off[k0 + 1:]
            del p_off[k0 + 1:]
            del r_off[k0 + 1:]
            del t_off[k0 + 1:]
            n_pairs = np_off[k0]
            st.pop("order_g", None)       # derived caches are now stale
            st.pop("lid", None)
            st.pop("p3", None)
            for c in touch:               # prefix replica-LRU touch
                c.last_access = step
            for k in range(k0, nreq):
                rq = requests[k]
                rid = rq.req_id
                home = rq.home
                mq = rq.m_q
                selflag = rid in selections
                cids = rq.chunk_ids
                # scalar cache key; the chunk-id list is checked by equality
                # against the cached copy (identity-equal string elements
                # make the compare a pointer scan, far cheaper than hashing
                # a 12-string tuple every step)
                rkey = (rid, home, mq, rq.k_selected, selflag)
                ent = rcache.get(rkey)
                if ent is not None and ent[-1] != cids:
                    ent = None
                if ent is not None:
                    self._n_sig_hit += 1
                else:
                    self._n_sig_miss += 1
                if ent is None:
                    srid = rid if selflag else -1
                    span: Optional[set] = set() if selflag else None
                    ksel = -1 if rq.k_selected is None else rq.k_selected
                    s_res: List[ResidentPair] = []
                    s_touch: List[object] = []
                    s_ct: List[int] = []
                    s_fi: List[int] = []
                    s_nh: List[int] = []
                    s_hold: List[int] = []
                    s_key: List[tuple] = []
                    for cid in cids:
                        ci = idx_of[cid]
                        s_touch.append(chunks[ci])
                        live = live_tab[ci]
                        if not live:
                            # orphaned chunk -> object fallback; the half-
                            # replayed step cache must not survive
                            p1["step"] = None
                            return None
                        h = holder_tab[ci][home]
                        if span is not None:
                            span.add(h)
                        if h == home:
                            s_res.append(ResidentPair(rid, cid, home))
                            continue
                        s_ct.append(length_l[ci])
                        s_fi.append(fi_tab[ci][home])
                        s_nh.append(live)
                        s_hold.append(h)
                        s_key.append((h, ci, s_fi[-1], srid))
                    if span is not None:
                        # under an active selection the predicate's
                        # n_holders is the M the request's selection SPANS
                        # (§5.4) — distinct chosen holders over ALL its
                        # pairs, resident shards counting their home — not
                        # the chunk's replica count
                        s_nh = [max(1, len(span))] * len(s_nh)
                    seg = len(s_ct)
                    s_dec = [self._pair_entry(mq, s_ct[x], s_fi[x], ksel,
                                              s_nh[x]) for x in range(seg)]
                    ent = (len(cids), s_touch, s_res, seg, [mq] * seg,
                           s_ct, s_fi, [ksel] * seg, s_nh, [home] * seg,
                           [rid] * seg, s_hold, s_key, s_dec, list(cids))
                    rcache[rkey] = ent
                (ncids, s_touch, s_res, seg, s_mq, s_ct, s_fi, s_ksel,
                 s_nh, s_home, s_rid, s_hold, s_key, s_dec, _) = ent
                sig.append((rid, home, mq, rq.k_selected, selflag,
                            list(cids)))
                n_pairs += ncids
                touch.extend(s_touch)
                for c in s_touch:
                    c.last_access = step     # replica-LRU touch
                if s_res:
                    resident_pairs.extend(s_res)
                if seg:
                    i = len(mq_l)
                    mq_l.extend(s_mq)
                    ct_l.extend(s_ct)
                    fi_l.extend(s_fi)
                    ksel_l.extend(s_ksel)
                    nh_l.extend(s_nh)
                    home_l.extend(s_home)
                    rid_l.extend(s_rid)
                    hold_l.extend(s_hold)
                    pair_req.extend([k] * seg)
                    pkey_l.extend(s_key)
                    dec_l.extend(s_dec)
                    for gk in s_key:
                        g = groups.get(gk)
                        if g is None:
                            groups[gk] = [i]
                        else:
                            g.append(i)
                        i += 1
                np_off.append(n_pairs)
                p_off.append(len(mq_l))
                r_off.append(len(resident_pairs))
                t_off.append(len(touch))
            st["n_pairs"] = n_pairs
        # reuse, the one per-step-varying pricing column, is rebuilt from
        # the live requests every step
        reuse_l = [requests[k].expected_reuse_steps for k in pair_req]
        n_resident = len(resident_pairs)
        n_priced = len(mq_l)
        replicas_spawned = 0

        if n_pairs == 0:
            return StepPlan(
                step=step, requests=list(requests), records=[],
                resident_pairs=[], n_pairs=0, n_priced=0, n_resident=0,
                replicas_spawned=0, evictions=self._evictions_this_step,
                selections=selections,
                selection_fallbacks=selection_fallbacks,
                arrays=StepPlanArrays.from_records(step, []))

        # record rows under construction (row order == the object planner's
        # record order; unzipped into columns at assembly) + per-pricing-
        # kind row buckets
        rows: List[tuple] = []
        kr_i: List[int] = []
        kr_kf: List[int] = []
        kfh_i: List[int] = []
        kfh_reuse: List[int] = []
        kfh_p3: List[tuple] = []   # (persisted, m0, mem|None) per fetch row
        kl_i: List[int] = []
        ksr_i: List[int] = []
        ksr_kf: List[int] = []
        ksr_frac: List[float] = []
        ksr_kb: List[int] = []
        ksf_i: List[int] = []
        ksf_kl: List[int] = []
        ksf_kb: List[int] = []
        ex_i: List[int] = []
        ex_est: List[float] = []
        ex_stages: List[tuple] = []

        def _row(prim, holder_, cidx_, nreq, mqt, fi_, link, home_, sd,
                 scnt, rids, backup=False):
            rows.append((prim, holder_, cidx_, nreq, mqt, backup, fi_,
                         link, home_, sd, scnt, rids))
            return len(rows) - 1

        if n_priced:
            # -- phase 2: the §5 predicate per pair via the decision memo.
            # A pair's three costs depend only on its pricing columns plus
            # the reuse countdown, and the distinct column combos number a
            # few hundred over a whole run — so each (columns, reuse) point
            # is priced once (through the cm batch functions on 1-element
            # arrays, bitwise the lane a full-width pass would produce) and
            # every later occurrence is a dict probe.
            code_l: List[int] = []
            tf_l: List[float] = []
            for E, re_ in zip(dec_l, reuse_l):
                rd = E[4]
                v = rd.get(re_)
                if v is None:
                    # dense fetch amortises bulk over reuse; the selection
                    # scattered gather never amortises (§5.4)
                    tf = E[2] if E[3] else E[2] / (re_ if re_ > 1 else 1)
                    tr = E[0]
                    tl = E[1]
                    cdd = 0 if (tr <= tf and tr <= tl) else \
                        (1 if tf <= tl else 2)
                    v = rd[re_] = (cdd, tf)
                code_l.append(v[0])
                tf_l.append(v[1])

            def _maj(mem: List[int]) -> int:
                # max(votes, key=votes.get) returns the first-INSERTED code
                # among tied maxima — the object planner's tie-break,
                # expression for expression
                if len(mem) == 1:
                    return code_l[mem[0]]
                votes: Dict[int, int] = {}
                for j in mem:
                    cj = code_l[j]
                    votes[cj] = votes.get(cj, 0) + 1
                return max(votes, key=votes.get)

            gmaj = {key: (code_l[mem[0]] if len(mem) == 1 else _maj(mem))
                    for key, mem in groups.items()}
            kf_l: Optional[List[int]] = None
            if cfg.congestion_aware:
                # §8 link occupancy: transport-majority groups each put one
                # flow on their (holder, fabric) link. Links are dense small
                # ints (holder * 2 + fabric), so the per-pair occupancy is
                # a plain-list scatter + gather (the arrays here are far
                # below numpy's break-even); the pair -> link map is
                # epoch-stable and cached with the step columns.
                lid = st.get("lid")
                if lid is None:
                    lid = st["lid"] = [h * 2 + f
                                       for h, f in zip(hold_l, fi_l)]
                lcnt = [0] * (2 * len(self.instances))
                for key, mj in gmaj.items():
                    if mj != P.LOCAL_CODE:
                        lcnt[key[0] * 2 + key[2]] += 1
                kf_l = [lcnt[x] for x in lid]
                hot = [i for i, v in enumerate(kf_l) if v >= 3] \
                    if max(lcnt) >= 3 else []
                if hot:
                    # incremental repricing: the §8 premium is flat through
                    # K<=2, so only pairs on links past the knee can price
                    # differently — and congestion only enters the ROUTE
                    # term, so reprice that one cost on the knee slice
                    # (memoized per (m_q, fabric, k_flows) point) and re-run
                    # the argmin against the uncontended fetch/local
                    cong = self._cong_memo
                    pay = cfg.payload
                    for j in hot:
                        E = dec_l[j]
                        if E[3] and nh_l[j] > 1:
                            trh = E[0]  # fan-out ROUTE is kf-independent
                        else:
                            ck = (mq_l[j], fi_l[j], kf_l[j])
                            trh = cong.get(ck)
                            if trh is None:
                                trh = cong[ck] = float(
                                    cm.t_route_congested_full_batch(
                                        self._fa,
                                        np.array([fi_l[j]], np.int64),
                                        np.array([mq_l[j]], np.int64),
                                        np.array([kf_l[j]], np.int64),
                                        pay)[0])
                        tf = tf_l[j]
                        tl = E[1]
                        code_l[j] = 2 if (tl < trh and tl < tf) else \
                            (1 if tf < trh else 0)
                    # only groups holding a repriced pair can change their
                    # majority; every other group's votes are untouched
                    for key in {pkey_l[j] for j in hot}:
                        gmaj[key] = _maj(groups[key])

            # -- phase-3/4 cache: when the whole step repeated AND the
            # post-congestion codes, slowdowns, and fetch amortisations all
            # match the step that built the cached assembly, the group walk
            # is a pure replay — every row, stage, and est is bitwise the
            # cached one (mutating walks — spawns, persists, evictions —
            # bump the store version, which resets the epoch and this
            # cache with it). Only the step stamp differs.
            p3 = st.get("p3") if full_hit and not selections else None
            if (p3 is not None and p3["code"] == code_l
                    and p3["slow"] == slowdown):
                new_kfh = [
                    (reuse_l[m0] if mem is None
                     else max(reuse_l[j] for j in mem)) if persisted else 1
                    for persisted, m0, mem in p3["kfh_rows"]]
                if new_kfh == p3["kfh_reuse"]:
                    self._n_p3_hit += 1
                    arr0 = p3["arrays"]
                    arrays = dataclasses.replace(arr0, step=step)
                    fa_memo = getattr(arr0, "_fa_memo", None)
                    if fa_memo is not None:
                        arrays._fa_memo = fa_memo
                    return StepPlan(
                        step=step, requests=list(requests), records=None,
                        resident_pairs=resident_pairs, n_pairs=n_pairs,
                        n_priced=n_priced, n_resident=n_resident,
                        replicas_spawned=0,
                        evictions=self._evictions_this_step,
                        selections=selections,
                        selection_fallbacks=selection_fallbacks,
                        arrays=arrays)
            # phase-3 iteration order: the object planner sorts first-
            # occurrence group order stably by (holder, chunk_id) — dict
            # insertion order IS first-occurrence order, and the stable
            # sort preserves it between equal (holder, chunk_id) keys.
            # Integer (holder, rank) keys stand in for the string pair
            # (rank is the chunk-id sort rank, see _residency_mirror), and
            # the sorted order is cached with the step's columns since it
            # is a pure function of them.
            order_g = st.get("order_g")
            if order_g is None:
                rank_l = mir["rank"]
                order_g = st["order_g"] = sorted(
                    groups.items(),
                    key=lambda kv: (kv[0][0], rank_l[kv[0][1]]))
            route_budget: Dict[Tuple[int, int], int] = {}
            sel_get = selections.get
            fanin_cap = cfg.fanin_cap
            p99 = cfg.straggler_p99_factor
            persist = cfg.persist_fetches

            for key, mem in order_g:
                hld, cidx_g, fi, srid = key
                sel = sel_get(srid) if srid >= 0 else None
                mj = gmaj[key]            # 0 ROUTE / 1 FETCH / 2 LOCAL
                if mj == 0 and sel is None:
                    budget = route_budget.get(key[:2], fanin_cap)
                    keep = min(len(mem), max(0, budget))
                    if keep < len(mem):
                        overflow, mem = mem[keep:], mem[:keep]
                        rep = self._spawn_replica_cols(
                            ids[cidx_g], [home_l[j] for j in overflow],
                            [mq_l[j] for j in overflow],
                            [rid_l[j] for j in overflow])
                        if rep is not None:
                            i = _row(3, rep.holder, cidx_g,
                                     rep.n_requesters, rep.m_q_total,
                                     rep.fabric_idx, rep.link_instance,
                                     rep.home, 1.0, len(rep.stages),
                                     rep.req_ids)
                            ex_i.append(i)
                            ex_est.append(rep.est_cost_s)
                            ex_stages.append(rep.stages)
                            replicas_spawned += 1
                        else:
                            mem = mem + overflow
                        if not mem:
                            continue
                    route_budget[key[:2]] = max(0, budget - len(mem))
                m0 = mem[0]
                nreq = len(mem)
                if nreq == 1:
                    mqt = mq_l[m0]
                else:
                    mqt = 0
                    for j in mem:
                        mqt += mq_l[j]
                if mj == 2:
                    if nreq == 1:
                        hm = home_l[m0]
                        kl_i.append(_row(2, hm, cidx_g, 1, mqt, -1, -1,
                                         hm, slowdown[hm], 1,
                                         (rid_l[m0],)))
                        continue
                    by_home: Dict[int, List[int]] = {}
                    for j in mem:
                        by_home.setdefault(home_l[j], []).append(j)
                    for hm in sorted(by_home):
                        ps = by_home[hm]
                        kl_i.append(_row(
                            2, hm, cidx_g, len(ps),
                            sum(mq_l[j] for j in ps), -1, -1, hm,
                            slowdown[hm], 1, tuple(rid_l[j] for j in ps)))
                    continue
                if nreq == 1:
                    dest = home_l[m0]
                    rids = (rid_l[m0],)
                else:
                    dest = self._busiest_home_cols(
                        [home_l[j] for j in mem], [mq_l[j] for j in mem])
                    rids = tuple([rid_l[j] for j in mem])
                sd = slowdown[hld]
                if sel is not None:
                    bt = self.selector.block_tokens
                    ct = ct_l[m0]
                    kb_wire = min(max(1, -(-int(ksel_l[m0]) // bt)),
                                  max(1, -(-ct // bt)))
                    k_local = sel.k_on(ids[cidx_g])
                    if mj == 0:
                        ksr_i.append(_row(0, hld, cidx_g, nreq, mqt, fi,
                                          hld, dest, sd, 6, rids))
                        ksr_kf.append(kf_l[m0]
                                      if kf_l is not None else 0)
                        ksr_frac.append(min(1.0, k_local / max(1, ct)))
                        ksr_kb.append(kb_wire)
                    else:
                        ksf_i.append(_row(1, hld, cidx_g, nreq, mqt, fi,
                                          hld, dest, sd, 2, rids))
                        ksf_kl.append(k_local)
                        ksf_kb.append(kb_wire)
                    continue
                if mj == 0:
                    kr_i.append(_row(0, hld, cidx_g, nreq, mqt, fi, hld,
                                     dest, sd, 5, rids))
                    kr_kf.append(kf_l[m0] if kf_l is not None else 0)
                else:
                    persisted = False
                    if persist:
                        persisted = self._make_resident(ids[cidx_g], dest)
                    kfh_i.append(_row(1, hld, cidx_g, nreq, mqt, fi, hld,
                                      dest, sd, 2, rids))
                    kfh_reuse.append(
                        (reuse_l[m0] if nreq == 1
                         else max(reuse_l[j] for j in mem))
                        if persisted else 1)
                    kfh_p3.append((persisted, m0,
                                   None if nreq == 1 else mem))
                # straggler backup shadows dense route/fetch only
                if sd >= p99:
                    cid = ids[cidx_g]
                    alt = [h for h in self.store.holders_of(cid)
                           if h != hld and self.instances[h].alive]
                    if alt:
                        tgt = min(alt, key=lambda h: slowdown[h])
                        h0 = home_l[m0]
                        fab2 = self.fabric_between(h0, tgt)
                        fi2 = self.fabric_idx_between(h0, tgt)
                        sd2 = slowdown[tgt]
                        if mj == 0:
                            bcost = cm.t_route(fab2, mqt,
                                               cfg.payload) * sd2
                            bstages = cm.route_stages(fab2, mqt, 0,
                                                      cfg.payload)
                        else:
                            ct = ct_l[m0]
                            bcost = cm.t_fetch(fab2, ct,
                                               cfg.payload) * sd2
                            bstages = cm.fetch_stages(fab2, ct,
                                                      cfg.payload)
                        bstages = cm.scale_stages(bstages, sd2)
                        bi = _row(mj, tgt,
                                  cidx_g, nreq, mqt, fi2, tgt, dest, sd2,
                                  len(bstages), rids, backup=True)
                        ex_i.append(bi)
                        ex_est.append(bcost)
                        ex_stages.append(bstages)

        # -- broadcast pricing: one template call per dispatch kind ---------
        R = len(rows)
        if R:
            (r_prim, r_holder, r_cidx, r_nreq, r_mqt, r_backup, r_fi,
             r_link, r_home, r_sd, r_scnt, r_rids) = zip(*rows)
        else:
            r_prim = r_holder = r_cidx = r_nreq = r_mqt = r_backup = \
                r_fi = r_link = r_home = r_sd = r_scnt = r_rids = ()
        # every row lands in exactly one pricing bucket and every stage slot
        # is filled by its bucket's _fill (or the explicit-stage loop), so
        # uninitialised allocation is safe here
        est = np.empty(R, np.float64)
        stage_off = np.zeros(R + 1, np.int64)
        np.cumsum(np.asarray(r_scnt, np.int64), out=stage_off[1:])
        S = int(stage_off[-1])
        stage_code = np.empty(S, np.int64)
        stage_dur = np.empty(S, np.float64)
        fi_col = np.asarray(r_fi, np.int64)
        mqt_col = np.asarray(r_mqt, np.int64)
        cidx_col = np.asarray(r_cidx, np.int64)
        sd_col = np.asarray(r_sd, np.float64)
        length = mir["length"]
        T = self._templates

        def _fill(rows, codes, dur):
            pos = stage_off[rows][:, None] + np.arange(codes.shape[0])
            stage_code[pos] = codes
            stage_dur[pos] = dur

        if kr_i:
            rows = np.asarray(kr_i, np.intp)
            sd = sd_col[rows]
            est[rows] = T.route_est(fi_col[rows], mqt_col[rows],
                                    np.asarray(kr_kf, np.int64)) * sd
            _fill(rows, _ROUTE_CODES,
                  T.route(fi_col[rows], mqt_col[rows]) * sd[:, None])
        if kfh_i:
            rows = np.asarray(kfh_i, np.intp)
            sd = sd_col[rows]
            reuse = np.asarray(kfh_reuse, np.int64)
            ct = length[cidx_col[rows]]
            est[rows] = T.fetch_est(fi_col[rows], ct, reuse) * sd
            _fill(rows, _FETCH_CODES,
                  T.fetch(fi_col[rows], ct, reuse) * sd[:, None])
        if kl_i:
            rows = np.asarray(kl_i, np.intp)
            sd = sd_col[rows]
            ct = length[cidx_col[rows]]
            est[rows] = T.local_est(ct) * sd
            _fill(rows, _LOCAL_CODES, T.local(ct) * sd[:, None])
        if ksr_i:
            rows = np.asarray(ksr_i, np.intp)
            sd = sd_col[rows]
            frac = np.asarray(ksr_frac, np.float64)
            kb = np.asarray(ksr_kb, np.int64)
            kf = np.asarray(ksr_kf, np.int64)
            d_index = self.selector.d_index
            est[rows] = T.route_selected_est(
                fi_col[rows], mqt_col[rows], kf, frac, kb, d_index) * sd
            _fill(rows, _SELR_CODES,
                  T.route_selected(fi_col[rows], mqt_col[rows], frac, kb,
                                   d_index) * sd[:, None])
        if ksf_i:
            rows = np.asarray(ksf_i, np.intp)
            sd = sd_col[rows]
            kl = np.asarray(ksf_kl, np.int64)
            kb = np.asarray(ksf_kb, np.int64)
            d_index = self.selector.d_index
            est[rows] = T.fetch_selected_est(
                fi_col[rows], kl, mqt_col[rows], kb, d_index) * sd
            _fill(rows, _SELF_CODES,
                  T.fetch_selected(fi_col[rows], kl, mqt_col[rows], kb,
                                   d_index) * sd[:, None])
        for i, e, stages in zip(ex_i, ex_est, ex_stages):
            est[i] = e
            o = int(stage_off[i])
            for j, (name, dur) in enumerate(stages):
                stage_code[o + j] = TL.STAGE_CODE[name]
                stage_dur[o + j] = dur

        req_off = np.zeros(R + 1, np.int64)
        np.cumsum(np.asarray([len(t) for t in r_rids], np.int64),
                  out=req_off[1:])
        arrays = StepPlanArrays(
            step=step, chunk_ids=ids, prim=np.asarray(r_prim, np.int64),
            holder=np.asarray(r_holder, np.int64), chunk=cidx_col,
            n_requesters=np.asarray(r_nreq, np.int64), m_q_total=mqt_col,
            est_cost_s=est, backup=np.asarray(r_backup, bool),
            fabric_idx=fi_col, link_instance=np.asarray(r_link, np.int64),
            home=np.asarray(r_home, np.int64), stage_off=stage_off,
            stage_code=stage_code, stage_dur=stage_dur, req_off=req_off,
            req_ids=np.asarray([q for t in r_rids for q in t], np.int64))
        if n_priced and not selections and replicas_spawned == 0:
            # the phase-3/4 replay cache (see the hit check above); a step
            # with spawns mutated the store, so its assembly can never be
            # replayed under the same epoch
            st["p3"] = {"code": list(code_l), "slow": list(slowdown),
                        "kfh_rows": kfh_p3, "kfh_reuse": list(kfh_reuse),
                        "arrays": arrays}
        return StepPlan(
            step=step, requests=list(requests),
            records=None, resident_pairs=resident_pairs,
            n_pairs=n_pairs, n_priced=n_priced, n_resident=n_resident,
            replicas_spawned=replicas_spawned,
            evictions=self._evictions_this_step, selections=selections,
            selection_fallbacks=selection_fallbacks, arrays=arrays)

    def _warn_selection_fallback(self) -> None:
        """A request carried k_selected but no selector is configured: the
        predicate PRICES the §5.4 selection regime while both backends
        execute dense full-chunk attention. Warn once per engine and count
        every occurrence in StepStats.selection_fallbacks, so priced-vs-
        executed regimes can never diverge silently (ISSUE 4)."""
        if self._warned_selection_fallback:
            return
        self._warned_selection_fallback = True
        warnings.warn(
            "requests carry k_selected but the engine has no selection "
            "service: the selection regime is priced but executed as dense "
            "full-chunk attention (recorded in StepStats.selection_"
            "fallbacks). Pass selector=IndexerService() "
            "(repro.serving.selection) or a ReplaySelector to run the "
            "indexer.", RuntimeWarning, stacklevel=3)

    # -- PLAN -> EXECUTE -> ACCOUNT --------------------------------------------

    def schedule_step(self, requests: List[Request]) -> List[DispatchRecord]:
        """One decode step: plan the transports (or claim a speculative
        plan, see speculate_step), submit them to the backend, and drain
        completed steps down to cfg.pipeline_depth - 1 in flight. At
        depth 1 (the default) the step is accounted before this returns —
        the historical lockstep plan->execute->account loop, bit-for-bit.
        Returns the planned records (the engine's historical contract)."""
        depth = max(1, self.cfg.pipeline_depth)
        t_plan0 = time.perf_counter()
        spec = self._claim_speculative(requests)
        if spec is not None:
            plan = spec.plan
            t_plan0, t_plan1 = spec.t_plan0, spec.t_plan1
            plan_wall = spec.plan_wall_s
        else:
            plan = self.plan_step(requests)
            t_plan1 = time.perf_counter()
            plan_wall = t_plan1 - t_plan0
            if self._inflight:
                # this plan ran while the oldest submitted step's device
                # work was still un-awaited: it is overlap if that step's
                # await turns out to actually block
                self._inflight[0].overlap_candidate_s += plan_wall
        ticket = submit_step(self.backend, self, plan)
        t_submit1 = time.perf_counter()
        self._inflight.append(_InFlight(
            plan=plan, ticket=ticket, t_plan0=t_plan0, t_plan1=t_plan1,
            t_submit1=t_submit1, plan_wall_s=plan_wall))
        while len(self._inflight) > depth - 1:
            self._drain_one()
        return plan.records

    def speculate_step(self, requests: List[Request]) -> None:
        """Plan the NEXT step now, while submitted device work is in
        flight. plan_step commits its own promotion/eviction deltas, so
        the plan produced here is exactly the plan schedule_step would
        have produced later — unless the world mutates first, in which
        case _claim_speculative discards it and replans (counted in
        misspeculation_replans). No-op at depth 1 or when a speculative
        plan is already parked."""
        if max(1, self.cfg.pipeline_depth) < 2 or self._spec is not None:
            return
        t0 = time.perf_counter()
        plan = self.plan_step(requests)
        t1 = time.perf_counter()
        if self._inflight:
            self._inflight[0].overlap_candidate_s += t1 - t0
        self._spec = _Speculative(
            requests=list(requests), plan=plan, epoch=self._world_epoch(),
            plan_wall_s=t1 - t0, t_plan0=t0, t_plan1=t1)

    def _world_epoch(self) -> tuple:
        """Everything a between-steps mutation can change that planning
        reads: residency structure (store.version — set_replica_data
        deliberately does NOT bump it, so in-flight byte persistence
        can't fault a speculation), liveness, and straggler factors."""
        return (self.store.version,
                tuple(i.alive for i in self.instances),
                tuple(i.slowdown for i in self.instances))

    def _claim_speculative(self,
                           requests: List[Request]) -> Optional[_Speculative]:
        """Return the parked speculative plan iff it matches this call's
        requests and the world has not mutated since it was planned;
        otherwise discard it, rewind step_idx, and count the replan. The
        discarded plan's residency commits are NOT rolled back: promoted
        replicas are delta-0 (content-identical to canonical), so a
        superseding replan against the post-speculation mirror prices the
        same bytes and the chosen plan's outputs stay §3.3-exact — the
        replan simply re-decides against what is actually resident."""
        spec, self._spec = self._spec, None
        if spec is None:
            return None
        if spec.epoch == self._world_epoch() \
                and spec.requests == list(requests):
            return spec
        self.misspeculation_replans += 1
        self.step_idx = spec.plan.step - 1
        return None

    def _invalidate_speculation(self) -> bool:
        """Drop the parked speculative plan (mutation incoming). The next
        schedule_step replans from scratch against the mutated world."""
        spec, self._spec = self._spec, None
        if spec is None:
            return False
        self.misspeculation_replans += 1
        self.step_idx = spec.plan.step - 1
        return True

    def _drain_one(self) -> None:
        """Await + account the oldest in-flight step (FIFO — submit
        order, which the backends require)."""
        entry = self._inflight.pop(0)
        t_await0 = time.perf_counter()
        execution = await_step(self.backend, self, entry.ticket)
        t_await1 = time.perf_counter()
        await_wall = t_await1 - t_await0
        # the await blocked => the device was busy from submit straight
        # through it, so every planner second that ran in between was
        # fully hidden; an instant return means there was nothing to hide
        # under (eager backend, or the device finished long ago)
        hidden = entry.overlap_candidate_s \
            if (entry.overlap_candidate_s > 0.0
                and await_wall > _AWAIT_BLOCK_EPS_S) else 0.0
        self.planner_overlap_s += hidden
        wall = entry.plan_wall_s + (entry.t_submit1 - entry.t_plan1) \
            + await_wall
        self._account(entry.plan, execution, wall)
        self.plan_walls.append(entry.plan_wall_s)
        obs = self.obs
        if obs.enabled:
            # everything observability-heavy happens HERE — after
            # sched_wall_s was measured, outside the planner wall
            obs.on_step(self, entry.plan, execution, self.stats[-1],
                        (entry.t_plan0, entry.t_plan1, t_await1,
                         time.perf_counter()),
                        overlap_s=hidden,
                        replans=self.misspeculation_replans)
            if self._spec is not None and obs.drift is not None \
                    and obs.drift.tripped():
                # a drift trip between plan and account invalidates the
                # speculative plan exactly like an explicit mutation
                self._invalidate_speculation()

    def flush(self) -> None:
        """Drain every in-flight step (await + account). Call after the
        last schedule_step of a pipelined run — run() does. Leaves any
        speculative plan parked for the next schedule_step."""
        while self._inflight:
            self._drain_one()

    def planner_cache_stats(self) -> Dict[str, int]:
        """Cumulative planner-cache effectiveness counters (ISSUE 9):
        hit/miss for the per-request signature cache, the full-step column
        replay, the phase-3/4 assembly replay, the §5 decision memo, and
        array->object planner fallbacks. (The timeline's schedule-memo
        counters live in timeline.sim_memo_stats() — module-global, like
        the memo itself.)"""
        return {
            "sig_hit": self._n_sig_hit,
            "sig_miss": self._n_sig_miss,
            "step_replay_hit": self._n_step_replay_hit,
            "step_replay_miss": self._n_step_replay_miss,
            "p3_replay_hit": self._n_p3_hit,
            "dec_memo_hit": self._n_dec_hit,
            "dec_memo_miss": self._n_dec_miss,
            "object_fallbacks": self._n_obj_fallback,
        }

    def _account(self, plan: StepPlan, execution: StepExecution,
                 wall_s: float) -> None:
        """Fold one planned + executed step into the engine's telemetry."""
        self.log.extend(plan.records)
        self.plans.append(plan)
        self.step_outputs.append(execution.outputs)
        self.measured_reports.append(getattr(execution, "measured", None))
        if self.cfg.retain_outputs >= 0:
            # exactly one step falls out of the window per step
            idx = len(self.step_outputs) - self.cfg.retain_outputs - 1
            if idx >= 0:
                self.step_outputs[idx] = {}
        prim_counts: Dict[str, int] = defaultdict(int)
        for r in plan.records:
            if not r.backup:
                prim_counts[r.primitive] += 1
        timeline = execution.timeline
        self.timelines.append(timeline)
        self.stats.append(StepStats(
            step=plan.step, n_requests=len(plan.requests),
            n_pairs=plan.n_pairs, n_priced=plan.n_priced,
            n_resident=plan.n_resident,
            n_dispatches=sum(1 for r in plan.records if not r.backup),
            primitives=dict(prim_counts),
            latency_s=timeline.makespan_s,
            sched_wall_s=wall_s,
            replicas_spawned=plan.replicas_spawned,
            evictions=plan.evictions,
            max_dispatch_s=(plan.arrays.critical_path_s()
                            if plan.arrays is not None
                            else _critical_path(plan.records)),
            serial_stage_s=timeline.serial_s,
            stage_totals=timeline.stage_totals(),
            n_selected=sum(len(rq.chunk_ids) for rq in plan.requests
                           if rq.req_id in plan.selections),
            selection_fallbacks=plan.selection_fallbacks))

    # -- multi-step driver -----------------------------------------------------

    def run(self, trace: Iterable[List[Request]],
            max_steps: Optional[int] = None) -> List[StepStats]:
        """Drive the scheduler over a trace (an iterable of per-step request
        lists, e.g. repro.serving.workload.agentic_trace). Returns the
        StepStats of the steps executed this call.

        islice bounds the pull count exactly: a generator-backed trace is
        never advanced past max_steps items (the old loop peeked one
        extra element before breaking). At pipeline_depth >= 2 each
        scheduled step's successor is planned speculatively while the
        step's device work is in flight, and the pipeline is flushed
        before returning — the trace is still pulled one item at a time,
        after the previous step was scheduled, so generator side effects
        interleave exactly as they do at depth 1."""
        start = len(self.stats)
        it = iter(trace) if max_steps is None \
            else itertools.islice(trace, max_steps)
        if max(1, self.cfg.pipeline_depth) < 2:
            for step_requests in it:
                self.schedule_step(step_requests)
            return self.stats[start:]
        sentinel = object()
        pending = next(it, sentinel)
        while pending is not sentinel:
            self.schedule_step(pending)
            pending = next(it, sentinel)
            if pending is not sentinel:
                self.speculate_step(pending)
        self.flush()
        return self.stats[start:]

    # -- internals -------------------------------------------------------------

    def _busiest_home(self, entries: List[_Pair]) -> int:
        return self._busiest_home_cols([p.rq.home for p in entries],
                                       [p.rq.m_q for p in entries])

    def _busiest_home_cols(self, homes: List[int], m_qs: List[int]) -> int:
        if len(homes) == 1:
            return homes[0]
        by_home: Dict[int, int] = defaultdict(int)
        for h, m in zip(homes, m_qs):
            by_home[h] += m
        return max(by_home, key=by_home.get)

    def _occupancy_k_flows(self, pairs: List[_Pair],
                           group_keys: List[Tuple[int, str, int]],
                           dec: "P.DecisionBatch") -> np.ndarray:
        """Per-pair §8 k_flows from OBSERVED link occupancy: each
        (holder, chunk, fabric) group whose (uncontended) majority vote is a
        transport — ROUTE or FETCH both put wire stages on the link — counts
        as one flow on its (holder, fabric) link; LOCAL groups never touch
        the wire and must not inflate their neighbours' premium."""
        groups: Dict[Tuple[int, str, int], List[int]] = defaultdict(list)
        for i, key in enumerate(group_keys):
            groups[key].append(i)
        flows_per_link: Dict[Tuple[int, int], int] = defaultdict(int)
        for key, idxs in groups.items():
            votes: Dict[int, int] = defaultdict(int)
            for i in idxs:
                votes[int(dec.code[i])] += 1
            if max(votes, key=votes.get) != P.LOCAL_CODE:
                flows_per_link[(key[0], key[2])] += 1
        return np.array(
            [flows_per_link.get((p.holder, p.fabric_idx), 0) for p in pairs],
            np.int64)

    def _spawn_replica(self, cid: str,
                       overflow: List[_Pair]) -> Optional[DispatchRecord]:
        """Amortised FETCH: replicate the chunk onto the requester instance
        with the most overflow demand. None when pool pressure wins."""
        return self._spawn_replica_cols(
            cid, [p.rq.home for p in overflow],
            [p.rq.m_q for p in overflow],
            [p.rq.req_id for p in overflow])

    def _spawn_replica_cols(self, cid: str, homes: List[int],
                            m_qs: List[int],
                            rids: List[int]) -> Optional[DispatchRecord]:
        target = self._busiest_home_cols(homes, m_qs)
        chunk = self.store.lookup(cid)
        fab = self.fabric_between(target, chunk.holder)
        if not self._make_resident(cid, target):
            return None
        return DispatchRecord(
            self.step_idx, target, "fetch_replica", cid, len(homes),
            sum(m_qs),
            cm.t_fetch(fab, chunk.length, self.cfg.payload),
            fabric_idx=self.fabric_idx_between(target, chunk.holder),
            link_instance=chunk.holder, home=target,
            stages=cm.fetch_stages(fab, chunk.length, self.cfg.payload),
            req_ids=tuple(rids))

    # -- faults ---------------------------------------------------------------

    def fail_instance(self, idx: int) -> List[str]:
        # a mid-pipeline fault invalidates any speculative plan (it was
        # planned against the pre-fault world) and drains in-flight steps
        # — their plans predate the fault, and the store mutation below
        # must not race their merge. Both are no-ops at depth 1.
        self._invalidate_speculation()
        self.flush()
        self.instances[idx].alive = False
        return self.store.drop_holder(idx)

    def set_straggler(self, idx: int, slowdown: float):
        self._invalidate_speculation()
        self.flush()
        self.instances[idx].slowdown = slowdown

    # -- metrics ---------------------------------------------------------------

    def step_latency(self, step: int) -> float:
        """Timeline makespan of a past step (0.0 for a fully-resident step
        — see transport_latencies() for why aggregation must skip those).
        Step ids are sequential and 1-based, so this is a direct index."""
        if 1 <= step <= len(self.stats) and self.stats[step - 1].step == step:
            return self.stats[step - 1].latency_s
        return _critical_path([r for r in self.log if r.step == step])

    def timeline_of(self, step: int) -> TL.Timeline:
        """The overlap-aware schedule of a past step (1-based sequential
        step ids, parallel to self.stats)."""
        if 1 <= step <= len(self.timelines) \
                and self.stats[step - 1].step == step:
            return self.timelines[step - 1]
        raise KeyError(f"no timeline recorded for step {step}")

    def outputs_of(self, step: int) -> Dict[int, object]:
        """Exec-backend decode outputs of a past step: req_id -> merged
        Partial ({} under the analytic backend, and {} once the step falls
        out of the cfg.retain_outputs window — outputs hold real arrays,
        so only a bounded history stays live)."""
        if 1 <= step <= len(self.step_outputs) \
                and self.stats[step - 1].step == step:
            return self.step_outputs[step - 1]
        raise KeyError(f"no outputs recorded for step {step}")

    def measured_overview(self) -> Optional[str]:
        """One-line aggregate of the run's measured-vs-analytic reports
        (None when no backend produced any): median/max makespan ratio
        over transporting steps, median overlap efficiency, and the
        committed-copy pool's final population (ISSUE 8)."""
        reps = [r for r in self.measured_reports if r is not None]
        if not reps:
            return None
        ratios = sorted(r.makespan_ratio for r in reps
                        if r.analytic.makespan_s > 0)
        if not ratios:
            return None
        eff = sorted(r.overlap_efficiency for r in reps
                     if r.analytic.makespan_s > 0)
        last = reps[-1]
        return (f"measured/analytic ratio p50 x{ratios[len(ratios)//2]:.1f} "
                f"max x{ratios[-1]:.1f} over {len(ratios)} transporting "
                f"steps ({last.mode}); overlap efficiency p50 "
                f"{eff[len(eff)//2]:.2f}; pool {last.pool_entries} entries/"
                f"{last.pool_bytes}B; {sum(r.stage_fills for r in reps)} "
                f"stage fills")
