"""Predicate-driven serving engine: the layer that CONSUMES the paper's
cost model (§5: "the serving system that consumes the rule").

Since ISSUE 3 a decode step runs through three layers:

  PLAN    (plan_step, this module) — residency resolution (chunk_store),
          ONE vectorized decide_batch() over every non-resident
          (request, chunk) pair (core.predicate: the closed-form §5
          predicate as numpy arrays, fabric picked per pair from the
          instance topology — probe latency, not peak bandwidth, §5.5),
          §8 link-subscription pricing with k_flows DERIVED from observed
          occupancy, per-(holder, chunk, fabric) dispatch batching (§5.3),
          fan-in capping at the N~8 elbow with replica spawns (§6.3),
          fetch persistence (the amortisation the predicate priced
          actually accrues) and LRU replica retirement under pool
          pressure. Output: a StepPlan (repro.serving.plan).
  EXECUTE (a pluggable ExecutionBackend, repro.serving.backends) — the
          AnalyticBackend schedules the plan on the overlap-aware
          transport timeline (repro.serving.timeline: wire stages
          serialize per (link, fabric), holder compute charged
          per-instance, StepStats.latency_s is the MAKESPAN); the
          JaxExecBackend additionally RUNS the planned attention on real
          c^KV arrays and returns actual decode outputs (§3.3 exactness,
          end-to-end through the scheduler).
  ACCOUNT (_account) — StepStats from the plan + the executed timeline.

Straggler backups past the p99 deadline and LOCAL re-homing of orphaned
chunks on holder failure are planned like any other dispatch.

run() drives the loop over a trace (see repro.serving.workload) and emits
per-step StepStats — the substrate benchmarks/bench_serving_steadystate.py
reports p50/p99 step latency and scheduler decisions/sec from.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core import predicate as P
from repro.core.chunk_store import ChunkStore
from repro.core.constants import Fabric
from repro.serving import timeline as TL
from repro.serving.backends.base import ExecutionBackend, StepExecution
# Plan-layer types live in repro.serving.plan; re-exported here so the
# historical `from repro.serving.engine import ...` imports keep working.
from repro.serving.plan import (DispatchRecord, Request, ResidentPair,
                                StepPlan, StepStats, _critical_path,
                                build_timeline, transport_latencies)

__all__ = [
    "DispatchRecord", "EngineConfig", "Instance", "Request", "ResidentPair",
    "ServingEngine", "StepPlan", "StepStats", "build_timeline",
    "transport_latencies",
]


@dataclasses.dataclass
class Instance:
    idx: int
    pod: int = 0
    # simulated holder-side service-time scale (stragglers: > 1)
    slowdown: float = 1.0
    alive: bool = True


@dataclasses.dataclass
class EngineConfig:
    fanin_cap: int = C.HOLDER_COMPUTE_ELBOW_N      # §6.3 elbow
    staging_streams: int = C.STAGING_STREAMS_ELBOW_K  # §6.2 policy constant
    straggler_p99_factor: float = 3.0              # backup fire threshold
    intra_pod_fabric: str = "tpu_ici"
    cross_pod_fabric: str = "tpu_dcn"
    payload: cm.Payload = cm.MLA_PAYLOAD
    congestion_aware: bool = True                  # §8 link-subscription pricing
    persist_fetches: bool = True                   # fetched chunks stay resident
    # exec mode: steps of decode-output history to retain (outputs hold
    # real arrays; keeping every step would grow memory linearly over a
    # run). < 0 keeps everything.
    retain_outputs: int = 8


# one resolved (request, chunk) access, pre-decision
@dataclasses.dataclass
class _Pair:
    rq: Request
    chunk_id: str
    holder: int
    fabric_idx: int
    c_t: int
    n_holders: int


class ServingEngine:
    def __init__(self, n_instances: int, pool_tokens: int,
                 cfg: EngineConfig = EngineConfig(),
                 instances_per_pod: int = 0,
                 backend: Optional[ExecutionBackend] = None,
                 selector=None):
        self.cfg = cfg
        self.store = ChunkStore(n_instances, pool_tokens)
        ipp = instances_per_pod or n_instances
        self.instances = [Instance(i, pod=i // ipp)
                          for i in range(n_instances)]
        if backend is None:
            from repro.serving.backends.analytic import AnalyticBackend
            backend = AnalyticBackend()
        self.backend: ExecutionBackend = backend
        # §5.4 selection regime (ISSUE 4): the indexer that turns a
        # request's k_selected budget into per-(request, holder) masks —
        # repro.serving.selection.IndexerService (live scoring) or
        # ReplaySelector (recorded trace). None: selection requests are
        # PRICED but executed dense, warn-once + counted in StepStats.
        self.selector = selector
        self._warned_selection_fallback = False
        self.log: List[DispatchRecord] = []
        self.stats: List[StepStats] = []
        self.plans: List[StepPlan] = []          # parallel to self.stats
        self.timelines: List[TL.Timeline] = []   # parallel to self.stats
        # exec-mode decode outputs per step: req_id -> merged Partial
        # (empty dicts under the analytic backend)
        self.step_outputs: List[Dict[int, object]] = []
        self.step_idx = 0
        # fabric table shared by every decide_batch call: idx 0 = intra-pod,
        # idx 1 = cross-pod
        self._fa = cm.FabricArrays.from_fabrics(
            [C.fabric(cfg.intra_pod_fabric), C.fabric(cfg.cross_pod_fabric)])

    # -- topology -------------------------------------------------------------

    def fabric_idx_between(self, a: int, b: int) -> int:
        """0 (intra-pod) or 1 (cross-pod); the probe, not peak BW, is what
        matters at decode (§5.5)."""
        return 0 if self.instances[a].pod == self.instances[b].pod else 1

    def fabric_between(self, a: int, b: int) -> Fabric:
        name = (self.cfg.intra_pod_fabric
                if self.fabric_idx_between(a, b) == 0
                else self.cfg.cross_pod_fabric)
        return C.fabric(name)

    # -- admission ------------------------------------------------------------

    def register_chunk(self, chunk_id: str, holder: int, length: int,
                       position_base: int = 0, data=None):
        return self.store.register(chunk_id, holder, length, position_base,
                                   data=data)

    # -- pool pressure ---------------------------------------------------------

    def _make_resident(self, chunk_id: str, instance: int) -> bool:
        """Replicate chunk onto instance, retiring cold replicas LRU under
        pool pressure. Returns False when it cannot fit (replication is an
        optimisation — never evict hotter data to force it)."""
        chunk = self.store.lookup(chunk_id)
        if self.store.resident_on(chunk_id, instance):
            return True
        need = chunk.length
        if self.store.capacity_left(instance) < need:
            victims = sorted(
                self.store.replicas_on(instance),
                key=lambda cid: self.store.lookup(cid).last_access)
            for vic in victims:
                if self.store.lookup(vic).last_access >= chunk.last_access:
                    break          # nothing colder than the newcomer
                self.store.evict_replica(vic, instance)
                self._evictions_this_step += 1
                if self.store.capacity_left(instance) >= need:
                    break
        if self.store.capacity_left(instance) < need:
            return False
        self.store.add_replica(chunk_id, instance)
        return True

    # -- PLAN: one decode step -------------------------------------------------

    def plan_step(self, requests: List[Request]) -> StepPlan:
        """Plan all transports for one global decode step: batched
        predicate, per-(holder, chunk, fabric) dispatch batching, link
        congestion pricing, fan-in capping, replica persistence. Planning
        COMMITS residency state (persisted fetches, replica spawns, LRU
        evictions); execution replays the plan without re-deciding."""
        self.step_idx += 1
        self._evictions_this_step = 0
        replicas_spawned = 0
        records: List[DispatchRecord] = []
        resident_pairs: List[ResidentPair] = []
        pairs: List[_Pair] = []
        n_resident = 0
        n_pairs = 0

        # -- phase 0: the indexer's selections (§5.4, ISSUE 4) --------------
        # score -> select happens BEFORE residency resolution: the masks are
        # a per-request property (the global top-k over the request's
        # chunks), independent of which holder ends up serving each shard.
        selections: Dict[int, object] = {}
        selection_fallbacks = 0
        sel_reqs = [rq for rq in requests if rq.k_selected is not None]
        if sel_reqs:
            if self.selector is not None:
                selections = self.selector.select_step(self, sel_reqs,
                                                       self.step_idx)
            else:
                selection_fallbacks = len(sel_reqs)
                self._warn_selection_fallback()
        # distinct instances a request's selection spans — the M of the
        # §5.4 fan-out/gather the predicate prices (resident shards count
        # their home)
        span: Dict[int, set] = {rid: set() for rid in selections}

        # -- phase 1: residency resolution ---------------------------------
        for rq in requests:
            selected = rq.req_id in selections
            for cid in rq.chunk_ids:
                n_pairs += 1
                chunk = self.store.lookup(cid)
                self.store.touch(cid, self.step_idx)
                holders = [h for h in self.store.holders_of(cid)
                           if self.instances[h].alive]
                if not holders:
                    # orphaned: LOCAL re-prefill, then re-home the chunk to
                    # the requester so subsequent steps serve it normally
                    sd = self.instances[rq.home].slowdown
                    records.append(DispatchRecord(
                        self.step_idx, rq.home, "local", cid, 1, rq.m_q,
                        cm.t_local(chunk.length,
                                   self.cfg.payload.n_layers) * sd,
                        home=rq.home,
                        stages=cm.scale_stages(
                            cm.local_stages(chunk.length,
                                            self.cfg.payload.n_layers), sd),
                        req_ids=(rq.req_id,)))
                    if self.store.capacity_left(rq.home) >= chunk.length:
                        self.store.allocate(rq.home, chunk.length)
                        chunk.holder = rq.home
                    if selected:
                        span[rq.req_id].add(rq.home)
                    continue
                # nearest live holder by fabric probe (home wins if resident)
                holder = min(holders, key=lambda h: 0.0 if h == rq.home
                             else self.fabric_between(rq.home, h).t_probe_s)
                if selected:
                    span[rq.req_id].add(holder)
                if holder == rq.home:
                    n_resident += 1    # resident: free local attention
                    resident_pairs.append(
                        ResidentPair(rq.req_id, cid, rq.home))
                    continue
                fi = self.fabric_idx_between(rq.home, holder)
                pairs.append(_Pair(rq, cid, holder, fi,
                                   chunk.length, len(holders)))

        # -- phase 2: one vectorized predicate over all pairs ---------------
        if pairs:
            # under an ACTIVE selection, the predicate's n_holders is the M
            # the request's selection SPANS (the §5.4 fan-out/gather width),
            # not the chunk's replica count; without a selector the historic
            # per-chunk count is kept so priced-only runs stay bit-stable
            def _n_holders(p: _Pair) -> int:
                if p.rq.req_id in selections:
                    return max(1, len(span[p.rq.req_id]))
                return p.n_holders
            batch = P.RequestBatch(
                fabrics=self._fa,
                m_q=np.array([p.rq.m_q for p in pairs], np.int64),
                c_t=np.array([p.c_t for p in pairs], np.int64),
                fabric_idx=np.array([p.fabric_idx for p in pairs], np.int64),
                expected_reuse_steps=np.array(
                    [p.rq.expected_reuse_steps for p in pairs], np.int64),
                k_selected=np.array(
                    [-1 if p.rq.k_selected is None else p.rq.k_selected
                     for p in pairs], np.int64),
                n_holders=np.array([_n_holders(p) for p in pairs], np.int64),
                position_delta=np.ones(len(pairs), np.int64),
                holder_can_compute=np.ones(len(pairs), bool),
                host_overhead=np.zeros(len(pairs), bool),
                payload=self.cfg.payload)
            # link subscription (§8): one batched dispatch per
            # (holder, chunk, fabric) group = one flow on the
            # (holder, fabric) link. The k_flows premium is DERIVED from
            # observed occupancy, not assumed from raw group counts: an
            # uncontended pass decides provisional primitives, only groups
            # that elect a transport (ROUTE/FETCH) occupy their link, and
            # the observed per-link flow count re-prices the batch. (One
            # relaxation round: a group the congested pass flips to LOCAL
            # still counts toward the occupancy its neighbours saw.)
            # selection pairs group PER REQUEST (4th key component): each
            # request's masks differ, and its indexer round trip + masked
            # partial is its own flow on the holder's link — dense pairs
            # keep the historic 3-way batching (srid = -1)
            group_keys = [(p.holder, p.chunk_id, p.fabric_idx,
                           p.rq.req_id if p.rq.req_id in selections else -1)
                          for p in pairs]
            if self.cfg.congestion_aware:
                dec0 = P.decide_batch(batch, None)
                k_flows = self._occupancy_k_flows(pairs, group_keys, dec0)
                # the §8 premium is flat through K<=2: re-pricing is the
                # identity unless some link is actually subscribed past
                # the knee — skip the second pass in the common case
                dec = (P.decide_batch(batch, k_flows)
                       if int(k_flows.max()) >= 3 else dec0)
            else:
                k_flows, dec = None, P.decide_batch(batch, None)
        else:
            group_keys, k_flows, dec = [], None, None

        # -- phase 3: dispatch batching + fan-in + persistence --------------
        groups: Dict[Tuple[int, str, int, int], List[int]] = defaultdict(list)
        for i, key in enumerate(group_keys):
            groups[key].append(i)
        # fan-in cap is a property of the HOLDER's compute elbow: per
        # (holder, chunk) at most fanin_cap requesters route, ACROSS fabric
        # sub-groups — a shared budget drained as dispatches are planned
        route_budget: Dict[Tuple[int, str], int] = defaultdict(
            lambda: self.cfg.fanin_cap)

        for (holder, cid, fi, srid), idxs in sorted(groups.items(),
                                                    key=lambda kv: kv[0][:2]):
            entries = [pairs[i] for i in idxs]
            votes = defaultdict(int)
            for i in idxs:
                votes[int(dec.code[i])] += 1
            code = max(votes, key=votes.get)
            primitive = P.PRIMITIVE_BY_CODE[code].value
            sel = selections.get(srid) if srid >= 0 else None
            # selection routes sit outside the §6.3 fan-in budget: the
            # elbow is a FULL-chunk batched-partial property, and selected
            # compute is scaled to the budget KB far below it
            if primitive == "route" and sel is None:
                keep = min(len(idxs), max(0, route_budget[(holder, cid)]))
                if keep < len(idxs):
                    # beyond the elbow: spawn a replica (amortised FETCH)
                    # for the overflow and rebalance (§6.3 boundary)
                    overflow, idxs = idxs[keep:], idxs[:keep]
                    rep = self._spawn_replica(
                        cid, [pairs[i] for i in overflow])
                    if rep is not None:
                        records.append(rep)
                        replicas_spawned += 1
                    else:          # no room anywhere: keep them on the batch
                        idxs = idxs + overflow
                    entries = [pairs[i] for i in idxs]
                    if not entries:
                        continue
                # clamp at 0: a failed replica spawn can overdraw the
                # budget, but a negative balance must not leak into the
                # NEXT sub-group's slice arithmetic
                route_budget[(holder, cid)] = max(
                    0, route_budget[(holder, cid)] - len(entries))
            n_req = len(entries)
            m_q_total = sum(p.rq.m_q for p in entries)
            fab = C.fabric(self._fa.names[fi])
            chunk = self.store.lookup(cid)
            if primitive == "local":
                # re-prefill runs at each REQUESTER, not the holder: one
                # dispatch per requesting home, at that home's speed, and
                # no transport => no straggler backup
                by_home: Dict[int, List[_Pair]] = defaultdict(list)
                for p in entries:
                    by_home[p.rq.home].append(p)
                for home, ps in sorted(by_home.items()):
                    sd = self.instances[home].slowdown
                    records.append(DispatchRecord(
                        self.step_idx, home, "local", cid, len(ps),
                        sum(p.rq.m_q for p in ps),
                        cm.t_local(chunk.length,
                                   self.cfg.payload.n_layers) * sd,
                        home=home,
                        stages=cm.scale_stages(
                            cm.local_stages(chunk.length,
                                            self.cfg.payload.n_layers), sd),
                        req_ids=tuple(p.rq.req_id for p in ps)))
                continue
            # timeline stage durations are UNCONTENDED (k=0): on the
            # timeline, §8 queueing is simulated — flows serialize on the
            # shared (link, fabric) resource — while est_cost_s keeps the
            # congested closed form the predicate priced the pairs with
            dest = self._busiest_home(entries)
            if sel is not None:
                # §5.4 selection dispatch: the indexer round trip leads the
                # stage chain, holder compute/gather scale with the budget
                # resident HERE (selected & resident — possibly 0: the
                # query still fans out, the partial merges as identity),
                # FETCH gathers scattered entries and never persists (the
                # selection is re-chosen next step), and no straggler
                # backup shadows it.
                rq0 = entries[0].rq
                bt = self.selector.block_tokens
                # candidates on the wire: the budget in blocks, capped by
                # what this holder could possibly return
                kb_wire = min(max(1, -(-int(rq0.k_selected) // bt)),
                              max(1, -(-chunk.length // bt)))
                k_local = sel.k_on(cid)
                d_index = self.selector.d_index
                if primitive == "route":
                    kf = (int(k_flows[idxs[0]])
                          if self.cfg.congestion_aware else 0)
                    frac = min(1.0, k_local / max(1, chunk.length))
                    cost = cm.t_route_selected_full(
                        fab, m_q_total, kf, frac, kb_wire, d_index,
                        self.cfg.payload)
                    stages = cm.route_selected_stages(
                        fab, m_q_total, 0, frac, kb_wire, d_index,
                        self.cfg.payload)
                else:          # fetch: scattered gather of the local picks
                    cost = cm.t_fetch_selected(
                        fab, k_local, m_q_total, kb_wire, d_index,
                        self.cfg.payload)
                    stages = cm.fetch_selected_stages(
                        fab, k_local, m_q_total, kb_wire, d_index,
                        self.cfg.payload)
                sd = self.instances[holder].slowdown
                records.append(DispatchRecord(
                    self.step_idx, holder, primitive, cid, n_req, m_q_total,
                    cost * sd, fabric_idx=fi, link_instance=holder,
                    home=dest, stages=cm.scale_stages(stages, sd),
                    req_ids=tuple(p.rq.req_id for p in entries)))
                continue
            if primitive == "route":
                kf = (int(k_flows[idxs[0]])
                      if self.cfg.congestion_aware else 0)
                # same formula the predicate priced the pairs with
                cost = cm.t_route_congested_full(fab, m_q_total, kf,
                                                 self.cfg.payload)
                stages = cm.route_stages(fab, m_q_total, 0, self.cfg.payload)
            else:                  # fetch
                raw = cm.t_fetch(fab, chunk.length, self.cfg.payload)
                persisted = False
                if self.cfg.persist_fetches:
                    persisted = self._make_resident(cid, dest)
                if persisted:
                    # amortised exactly as the predicate priced it (§5.5
                    # rule 2): the pull+splice is paid once and the copy
                    # stays resident for the reuse horizon
                    reuse = max(p.rq.expected_reuse_steps for p in entries)
                    cost = raw / max(1, reuse)
                else:
                    # the copy could not persist (pool pressure or
                    # persistence off): the pull+splice really is paid
                    # every time, so no amortisation discount
                    reuse = 1
                    cost = raw
                stages = cm.fetch_stages(fab, chunk.length, self.cfg.payload,
                                         reuse_steps=reuse)
            sd = self.instances[holder].slowdown
            cost *= sd
            records.append(DispatchRecord(
                self.step_idx, holder, primitive, cid, n_req, m_q_total,
                cost, fabric_idx=fi, link_instance=holder, home=dest,
                stages=cm.scale_stages(stages, sd),
                req_ids=tuple(p.rq.req_id for p in entries)))
            # straggler mitigation: fire a backup to a replica if the
            # holder's (simulated) latency blows the p99 deadline
            if (self.instances[holder].slowdown
                    >= self.cfg.straggler_p99_factor):
                alt = [h for h in self.store.holders_of(cid)
                       if h != holder and self.instances[h].alive]
                if alt:
                    # the least-loaded live replica — backing up onto
                    # another straggler helps nobody
                    tgt = min(alt, key=lambda h: self.instances[h].slowdown)
                    fab2 = self.fabric_between(entries[0].rq.home, tgt)
                    fi2 = self.fabric_idx_between(entries[0].rq.home, tgt)
                    sd2 = self.instances[tgt].slowdown
                    backup_cost = (
                        cm.t_route(fab2, m_q_total, self.cfg.payload)
                        if primitive == "route"
                        else cm.t_fetch(fab2, chunk.length, self.cfg.payload)
                    ) * sd2
                    backup_stages = (
                        cm.route_stages(fab2, m_q_total, 0, self.cfg.payload)
                        if primitive == "route"
                        else cm.fetch_stages(fab2, chunk.length,
                                             self.cfg.payload))
                    records.append(DispatchRecord(
                        self.step_idx, tgt, primitive, cid, n_req,
                        m_q_total, backup_cost, backup=True,
                        fabric_idx=fi2, link_instance=tgt, home=dest,
                        stages=cm.scale_stages(backup_stages, sd2),
                        req_ids=tuple(p.rq.req_id for p in entries)))

        return StepPlan(
            step=self.step_idx, requests=list(requests), records=records,
            resident_pairs=resident_pairs, n_pairs=n_pairs,
            n_priced=len(pairs), n_resident=n_resident,
            replicas_spawned=replicas_spawned,
            evictions=self._evictions_this_step,
            selections=selections,
            selection_fallbacks=selection_fallbacks)

    def _warn_selection_fallback(self) -> None:
        """A request carried k_selected but no selector is configured: the
        predicate PRICES the §5.4 selection regime while both backends
        execute dense full-chunk attention. Warn once per engine and count
        every occurrence in StepStats.selection_fallbacks, so priced-vs-
        executed regimes can never diverge silently (ISSUE 4)."""
        if self._warned_selection_fallback:
            return
        self._warned_selection_fallback = True
        warnings.warn(
            "requests carry k_selected but the engine has no selection "
            "service: the selection regime is priced but executed as dense "
            "full-chunk attention (recorded in StepStats.selection_"
            "fallbacks). Pass selector=IndexerService() "
            "(repro.serving.selection) or a ReplaySelector to run the "
            "indexer.", RuntimeWarning, stacklevel=3)

    # -- PLAN -> EXECUTE -> ACCOUNT --------------------------------------------

    def schedule_step(self, requests: List[Request]) -> List[DispatchRecord]:
        """One decode step end-to-end: plan the transports, execute them on
        the configured backend, account the StepStats. Returns the planned
        records (the engine's historical contract)."""
        t_wall0 = time.perf_counter()
        plan = self.plan_step(requests)
        execution = self.backend.execute(self, plan)
        self._account(plan, execution, time.perf_counter() - t_wall0)
        return plan.records

    def _account(self, plan: StepPlan, execution: StepExecution,
                 wall_s: float) -> None:
        """Fold one planned + executed step into the engine's telemetry."""
        self.log.extend(plan.records)
        self.plans.append(plan)
        self.step_outputs.append(execution.outputs)
        if self.cfg.retain_outputs >= 0:
            # exactly one step falls out of the window per step
            idx = len(self.step_outputs) - self.cfg.retain_outputs - 1
            if idx >= 0:
                self.step_outputs[idx] = {}
        prim_counts: Dict[str, int] = defaultdict(int)
        for r in plan.records:
            if not r.backup:
                prim_counts[r.primitive] += 1
        timeline = execution.timeline
        self.timelines.append(timeline)
        self.stats.append(StepStats(
            step=plan.step, n_requests=len(plan.requests),
            n_pairs=plan.n_pairs, n_priced=plan.n_priced,
            n_resident=plan.n_resident,
            n_dispatches=sum(1 for r in plan.records if not r.backup),
            primitives=dict(prim_counts),
            latency_s=timeline.makespan_s,
            sched_wall_s=wall_s,
            replicas_spawned=plan.replicas_spawned,
            evictions=plan.evictions,
            max_dispatch_s=_critical_path(plan.records),
            serial_stage_s=timeline.serial_s,
            stage_totals=timeline.stage_totals(),
            n_selected=sum(len(rq.chunk_ids) for rq in plan.requests
                           if rq.req_id in plan.selections),
            selection_fallbacks=plan.selection_fallbacks))

    # -- multi-step driver -----------------------------------------------------

    def run(self, trace: Iterable[List[Request]],
            max_steps: Optional[int] = None) -> List[StepStats]:
        """Drive the scheduler over a trace (an iterable of per-step request
        lists, e.g. repro.serving.workload.agentic_trace). Returns the
        StepStats of the steps executed this call."""
        start = len(self.stats)
        for i, step_requests in enumerate(trace):
            if max_steps is not None and i >= max_steps:
                break
            self.schedule_step(step_requests)
        return self.stats[start:]

    # -- internals -------------------------------------------------------------

    def _busiest_home(self, entries: List[_Pair]) -> int:
        by_home: Dict[int, int] = defaultdict(int)
        for p in entries:
            by_home[p.rq.home] += p.rq.m_q
        return max(by_home, key=by_home.get)

    def _occupancy_k_flows(self, pairs: List[_Pair],
                           group_keys: List[Tuple[int, str, int]],
                           dec: "P.DecisionBatch") -> np.ndarray:
        """Per-pair §8 k_flows from OBSERVED link occupancy: each
        (holder, chunk, fabric) group whose (uncontended) majority vote is a
        transport — ROUTE or FETCH both put wire stages on the link — counts
        as one flow on its (holder, fabric) link; LOCAL groups never touch
        the wire and must not inflate their neighbours' premium."""
        groups: Dict[Tuple[int, str, int], List[int]] = defaultdict(list)
        for i, key in enumerate(group_keys):
            groups[key].append(i)
        flows_per_link: Dict[Tuple[int, int], int] = defaultdict(int)
        for key, idxs in groups.items():
            votes: Dict[int, int] = defaultdict(int)
            for i in idxs:
                votes[int(dec.code[i])] += 1
            if max(votes, key=votes.get) != P.LOCAL_CODE:
                flows_per_link[(key[0], key[2])] += 1
        return np.array(
            [flows_per_link.get((p.holder, p.fabric_idx), 0) for p in pairs],
            np.int64)

    def _spawn_replica(self, cid: str,
                       overflow: List[_Pair]) -> Optional[DispatchRecord]:
        """Amortised FETCH: replicate the chunk onto the requester instance
        with the most overflow demand. None when pool pressure wins."""
        target = self._busiest_home(overflow)
        chunk = self.store.lookup(cid)
        fab = self.fabric_between(target, chunk.holder)
        if not self._make_resident(cid, target):
            return None
        return DispatchRecord(
            self.step_idx, target, "fetch_replica", cid, len(overflow),
            sum(p.rq.m_q for p in overflow),
            cm.t_fetch(fab, chunk.length, self.cfg.payload),
            fabric_idx=self.fabric_idx_between(target, chunk.holder),
            link_instance=chunk.holder, home=target,
            stages=cm.fetch_stages(fab, chunk.length, self.cfg.payload),
            req_ids=tuple(p.rq.req_id for p in overflow))

    # -- faults ---------------------------------------------------------------

    def fail_instance(self, idx: int) -> List[str]:
        self.instances[idx].alive = False
        return self.store.drop_holder(idx)

    def set_straggler(self, idx: int, slowdown: float):
        self.instances[idx].slowdown = slowdown

    # -- metrics ---------------------------------------------------------------

    def step_latency(self, step: int) -> float:
        """Timeline makespan of a past step (0.0 for a fully-resident step
        — see transport_latencies() for why aggregation must skip those).
        Step ids are sequential and 1-based, so this is a direct index."""
        if 1 <= step <= len(self.stats) and self.stats[step - 1].step == step:
            return self.stats[step - 1].latency_s
        return _critical_path([r for r in self.log if r.step == step])

    def timeline_of(self, step: int) -> TL.Timeline:
        """The overlap-aware schedule of a past step (1-based sequential
        step ids, parallel to self.stats)."""
        if 1 <= step <= len(self.timelines) \
                and self.stats[step - 1].step == step:
            return self.timelines[step - 1]
        raise KeyError(f"no timeline recorded for step {step}")

    def outputs_of(self, step: int) -> Dict[int, object]:
        """Exec-backend decode outputs of a past step: req_id -> merged
        Partial ({} under the analytic backend, and {} once the step falls
        out of the cfg.retain_outputs window — outputs hold real arrays,
        so only a bounded history stays live)."""
        if 1 <= step <= len(self.step_outputs) \
                and self.stats[step - 1].step == step:
            return self.step_outputs[step - 1]
        raise KeyError(f"no outputs recorded for step {step}")
