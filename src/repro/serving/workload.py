"""Trace-driven agentic workload generator (§1, §6.3).

Models the regime the paper motivates: IndexCache-style many-agents-one-
corpus fan-in. A provider pins canonical chunks across instances; agent
sessions arrive with a home instance and a Zipf-skewed working set of
corpus chunks, issue one decode step per engine step for the length of
their session, then depart (replaced, so concurrency — i.e. sustained
traffic — is constant). An agent's expected_reuse_steps is its remaining
session life: exactly the amortisation horizon FETCH needs (§5.5 rule 2),
so popular chunks replicate toward their readers over the run while
one-shot readers keep routing.

The trace is a plain iterator of per-step List[Request] — the engine's
run() drives it; bench_serving_steadystate.py measures it. Every Request
carries a deterministic query_seed (derived from the session id, no extra
RNG draws), so the SAME trace drives the analytic and the exec backend:
the analytic path ignores the seed, the exec path materializes the
request's query tensor from it. materialize_trace / save_trace /
load_trace snapshot a trace so both backends (or a later session) replay
the identical request stream.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.serving.engine import Request, ServingEngine


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_steps: int = 128
    agents: int = 64                 # concurrent sessions (fan-in N)
    n_corpus_chunks: int = 24
    chunk_tokens: int = 2048
    chunks_per_request: int = 2      # chunks an agent attends per step
    zipf_a: float = 1.2              # corpus popularity skew
    m_q_choices: Sequence[int] = (1, 4, 8, 16)   # decode-shaped row counts
    session_steps: Sequence[int] = (8, 64)       # lifetime range, inclusive
    selection_frac: float = 0.1      # agents in the §5.4 selection regime
    k_selected: int = 2048
    seed: int = 0


def _zipf_probs(n: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** a
    return p / p.sum()


def register_corpus(engine: ServingEngine, cfg: WorkloadConfig) -> List[str]:
    """Pin the canonical corpus round-robin over the engine's instances."""
    n_inst = len(engine.instances)
    cids = []
    for i in range(cfg.n_corpus_chunks):
        cid = f"corpus_{i:04d}"
        engine.register_chunk(cid, holder=i % n_inst,
                              length=cfg.chunk_tokens)
        cids.append(cid)
    return cids


@dataclasses.dataclass
class _Session:
    req_id: int
    home: int
    working_set: List[str]
    m_q: int
    steps_left: int
    k_selected: int = -1             # -1 => dense regime


def agentic_trace(cfg: WorkloadConfig, engine: ServingEngine,
                  chunk_ids: Sequence[str]) -> Iterator[List[Request]]:
    """Yield cfg.n_steps per-step request lists, deterministic in cfg.seed."""
    rng = np.random.RandomState(cfg.seed)
    n_inst = len(engine.instances)
    probs = _zipf_probs(len(chunk_ids), cfg.zipf_a)
    next_id = [0]

    def spawn() -> _Session:
        k = min(cfg.chunks_per_request, len(chunk_ids))
        ws = list(rng.choice(chunk_ids, size=k, replace=False, p=probs))
        s = _Session(
            req_id=next_id[0],
            home=int(rng.randint(n_inst)),
            working_set=ws,
            m_q=int(rng.choice(cfg.m_q_choices)),
            steps_left=int(rng.randint(cfg.session_steps[0],
                                       cfg.session_steps[1] + 1)),
            k_selected=(cfg.k_selected
                        if rng.rand() < cfg.selection_frac else -1))
        next_id[0] += 1
        return s

    sessions = [spawn() for _ in range(cfg.agents)]
    for _ in range(cfg.n_steps):
        step: List[Request] = []
        for i, s in enumerate(sessions):
            step.append(Request(
                req_id=s.req_id, home=s.home,
                chunk_ids=list(s.working_set), m_q=s.m_q,
                expected_reuse_steps=max(1, s.steps_left),
                k_selected=None if s.k_selected < 0 else s.k_selected,
                # deterministic in the session id — no RNG draw, so the
                # request stream is identical with or without exec mode
                query_seed=cfg.seed * 1_000_003 + s.req_id))
            s.steps_left -= 1
            if s.steps_left <= 0:
                sessions[i] = spawn()    # departure + fresh arrival
        yield step


# ---------------------------------------------------------------------------
# Trace snapshots: one trace, many consumers (analytic vs exec backend,
# CLI replays, golden fixtures).
# ---------------------------------------------------------------------------

def materialize_trace(trace: Iterable[List[Request]]) -> List[List[Request]]:
    """Exhaust a trace iterator into a replayable list of steps (agentic_
    trace is a generator — the same object cannot drive two engines)."""
    return [list(step) for step in trace]


def save_trace(path: Union[str, pathlib.Path],
               trace: Iterable[List[Request]],
               meta: Optional[dict] = None) -> List[List[Request]]:
    """Write a trace as JSON (one dict per request); returns the
    materialized steps so the caller can keep driving them. `meta` rides
    along (corpus geometry, engine topology, seeds) so a replay can
    reconstruct the WORLD the trace was recorded against — chunk ids in
    a trace mean nothing if the corpus is registered differently."""
    steps = materialize_trace(trace)
    payload = {
        "meta": meta or {},
        "steps": [[dataclasses.asdict(rq) for rq in step]
                  for step in steps],
    }
    pathlib.Path(path).write_text(json.dumps(payload, indent=1) + "\n")
    return steps


def read_trace(path: Union[str, pathlib.Path]
               ) -> "tuple[dict, List[List[Request]]]":
    """One parse of a save_trace() JSON -> (meta, per-step Request lists).
    The bare-list pre-meta format is accepted too (meta = {})."""
    payload = json.loads(pathlib.Path(path).read_text())
    if isinstance(payload, dict):
        meta, raw = payload.get("meta", {}), payload["steps"]
    else:
        meta, raw = {}, payload
    return meta, [[Request(**rq) for rq in step] for step in raw]


def load_trace(path: Union[str, pathlib.Path]) -> List[List[Request]]:
    """Just the steps of a saved trace."""
    return read_trace(path)[1]


def trace_meta(path: Union[str, pathlib.Path]) -> dict:
    """Just the meta header of a saved trace."""
    return read_trace(path)[0]
