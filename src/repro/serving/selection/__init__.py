"""The distributed indexer subsystem (§5.4, ISSUE 4): score -> select ->
scatter-attend through the scheduler.

Numpy-only pieces (types, trace replay) import eagerly — the planner and
the ReplaySelector must work without jax; the live IndexerService loads
lazily (it materializes chunk arrays through the exec backend's helpers).
"""

from repro.serving.selection.replay import (ReplaySelector,
                                            load_selection_trace,
                                            save_selection_trace,
                                            selection_trace_payload)
from repro.serving.selection.types import RequestSelection, token_mask


def __getattr__(name: str):
    if name in ("IndexerService", "SelectionConfig",
                "ShardMapIndexerService"):
        from repro.serving.selection import service
        return getattr(service, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
