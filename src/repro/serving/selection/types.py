"""Numpy-only data types of the selection subsystem (ISSUE 4).

A RequestSelection is the indexer's verdict for one request at one decode
step: which NSA blocks (64-token granularity) of which chunks made the
global top-k, plus the per-chunk boolean token masks the plan layer
threads to the backends. It must stay importable without jax — the
planner and the ReplaySelector (trace replay) are numpy-only; only the
live IndexerService (repro.serving.selection.service) touches jax.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Tuple

import numpy as np


def token_mask(block_ids: Iterable[int], block_tokens: int,
               length: int) -> np.ndarray:
    """Selected block ids -> (length,) bool token mask. Blocks are counted
    on the padded length (ceil — core.selection.topk_blocks' convention, so
    a partial tail block is addressable) and the mask truncates back."""
    n_blocks = -(-length // block_tokens)
    bm = np.zeros(n_blocks, bool)
    ids = list(block_ids)
    if ids:
        bm[np.asarray(ids, np.int64)] = True
    return np.repeat(bm, block_tokens)[:length]


@dataclasses.dataclass(frozen=True)
class RequestSelection:
    """One request's global top-k selection, split per chunk (the
    distributed form of §5.4: each holder attends selected & resident)."""
    req_id: int
    block_tokens: int
    blocks: Dict[str, Tuple[int, ...]]      # chunk_id -> block ids, ascending
    masks: Dict[str, np.ndarray]            # chunk_id -> (c_t,) bool mask

    @property
    def k_eff(self) -> int:
        """Selected tokens across every chunk (the block-rounded budget)."""
        return int(sum(int(m.sum()) for m in self.masks.values()))

    def k_on(self, chunk_id: str) -> int:
        """Selected tokens resident in one chunk (0: the indexer chose
        nothing there — the query still fans out, the partial is identity)."""
        m = self.masks.get(chunk_id)
        return 0 if m is None else int(m.sum())

    def kb_on(self, chunk_id: str) -> int:
        """Selected blocks in one chunk."""
        return len(self.blocks.get(chunk_id, ()))
