"""The distributed indexer service (§5.4 tentpole, ISSUE 4): score ->
select -> scatter-attend, through the scheduler.

Per decode step, for every request in the selection regime:

  score  — the requester derives a NARROW indexer query from its absorbed
           decode rows (the DSA rule of models/model.py's decode path:
           mean-over-heads of the leading d_index latent columns) and
           broadcasts it to every holder of the request's chunks; each
           holder scores its RESIDENT index keys (the chunk store's
           sidecar, materialized alongside c^KV) — index_scores is a
           rank-d_index dot, noise next to the attention compute.
  select — each holder pools scores over the request's query rows, takes a
           LOCAL top-k at NSA 64-token block granularity (padded tail —
           core.selection.block_scores), and returns (block, score)
           candidates; the requester merges them into the GLOBAL top-k.
           Because every holder keeps its k best under one strict total
           order (score desc, then chunk order, then block id), the merged
           set equals the single-instance top-k over the concatenated
           cache — the distributed form is exact, not approximate.
  scatter-attend — the resulting per-(request, holder) masks
           (RequestSelection.masks, the residency_split of the global
           choice) ride the StepPlan into the backends: the exec backend
           attends selected & resident in place and merges partials.

Everything here is host-side control plane on small arrays: scoring runs
in numpy (deterministic, trace-recordable); jax appears only to
materialize the canonical chunk arrays the index keys derive from (the
same deterministic materialization the exec backend uses).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, TYPE_CHECKING

import numpy as np

from repro.core import constants as C
from repro.core import selection as SEL
from repro.core.chunk_store import ChunkStore
from repro.models.mla import MLAConfig
from repro.serving.backends.jax_exec import TINY_MLA, chunk_array, query_for
from repro.serving.plan import Request
from repro.serving.selection.types import RequestSelection, token_mask

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import ServingEngine


def pooled_max(scores: np.ndarray) -> np.ndarray:
    """Pool index scores over a request's query rows: max — a token ANY
    row wants is kept. (S,) from (m_q, S)."""
    return np.asarray(scores).max(axis=0)


@dataclasses.dataclass(frozen=True)
class SelectionConfig:
    block_tokens: int = C.NSA_BLOCK_TOKENS          # NSA granularity (64)
    # scoring-projection width; None -> the full latent band (d_c), which
    # is exactly the parameter-free rule models/model.py decodes with
    d_index: Optional[int] = None


class IndexerService:
    """The live scoring service. mla fixes the EXECUTION geometry (must
    match the engine's JaxExecBackend so indexer queries and index keys
    derive from the same tensors the backend attends with); the planner's
    cost payload is independent, as everywhere else."""

    name = "indexer"

    def __init__(self, cfg: SelectionConfig = SelectionConfig(),
                 mla: MLAConfig = TINY_MLA, dtype=None):
        import jax.numpy as jnp
        self.cfg = cfg
        self.mla = mla
        self.dtype = jnp.float32 if dtype is None else dtype
        self.block_tokens = cfg.block_tokens
        self.d_index = cfg.d_index or mla.kv_lora_rank
        # every verdict, by engine step — the recordable selection trace
        # (repro.serving.selection.replay.save_selection_trace)
        self.log: Dict[int, Dict[int, RequestSelection]] = {}
        # service telemetry (ISSUE 9), read by the obs metrics registry:
        # roundtrips = per-(request, chunk) scoring round trips, merges =
        # requester-side global merges, merge_candidates / merge_selected =
        # cumulative candidate-in / block-out volumes of those merges.
        self.obs_counts: Dict[str, int] = {
            "roundtrips": 0, "merges": 0,
            "merge_candidates": 0, "merge_selected": 0}
        # per-merge candidate-set sizes since the last drain — bounded by
        # the obs layer draining every step into a streaming histogram
        self._merge_sizes: List[int] = []

    def drain_merge_sizes(self) -> List[int]:
        """Per-merge candidate counts accumulated since the last call
        (the obs layer folds them into a histogram once per step)."""
        out = self._merge_sizes
        self._merge_sizes = []
        return out

    # -- sidecar materialization --------------------------------------------

    def ensure_index_keys(self, store: ChunkStore,
                          chunk_id: str) -> np.ndarray:
        """The chunk's index keys, materializing the sidecar on first
        touch: the leading d_index latent columns of the canonical c^KV
        entries (core.selection.latent_index_keys — position-invariant, so
        replicas carry byte-identical keys). Kept as numpy: scoring is
        host-side control plane."""
        chunk = store.lookup(chunk_id)
        if chunk.index_keys is None:
            src = chunk.data
            if src is None:
                # analytic engines never materialize c^KV; derive the keys
                # from the same deterministic array exec would attend
                src = chunk_array(self.mla, chunk_id, chunk.length,
                                  self.dtype)
            store.attach_index_keys(chunk_id, np.asarray(
                SEL.latent_index_keys(src, self.d_index), np.float32))
        return np.asarray(chunk.index_keys)

    # -- scoring ------------------------------------------------------------

    def index_query(self, rq: Request, step: int) -> np.ndarray:
        """The request's narrow indexer query rows (m_q, d_index): mean
        over heads of the latent band of the SAME absorbed decode queries
        the exec backend materializes (query_for) — the DSA scoring rule of
        models/model.py, so single-instance selection_k decode is the
        oracle this service must reproduce."""
        q = np.asarray(query_for(self.mla, rq, step, self.dtype), np.float32)
        return q[..., :self.d_index].mean(axis=1)

    def pooled_scores(self, store: ChunkStore, rq: Request, iq: np.ndarray,
                      chunk_id: str, step: int) -> np.ndarray:
        """One holder's scoring round: index_scores over the chunk's
        resident keys, max-pooled over the request's query rows (a token
        any row wants is kept) -> (S,). THE distributed hook: the mesh
        service (ShardMapIndexerService) overrides exactly this — the
        candidate policy downstream (topk_from_pooled, _merge) is shared,
        so the two services can only differ in where scores computed."""
        keys = self.ensure_index_keys(store, chunk_id)
        scores = iq @ keys.T                       # (m_q, S) index_scores
        return pooled_max(scores)

    def topk_from_pooled(self, pooled: np.ndarray,
                         k_blocks: int) -> List[Tuple[int, float]]:
        """Aggregate pooled token scores per NSA block (padded tail) and
        return the local top-k (block id, score) candidates under the
        strict total order — score desc, ties toward the lower id."""
        bs = SEL.block_scores(pooled, self.block_tokens)
        k = min(k_blocks, bs.shape[-1])
        order = np.lexsort((np.arange(bs.shape[-1]), -bs))[:k]
        return [(int(b), float(bs[b])) for b in order]

    def local_topk(self, iq: np.ndarray, keys: np.ndarray,
                   k_blocks: int) -> List[Tuple[int, float]]:
        """One holder's side of the service: score + pool + per-block
        top-k. Kept as the single-array entry (tests, examples); the
        service pipeline goes through pooled_scores/topk_from_pooled."""
        return self.topk_from_pooled(pooled_max(iq @ keys.T), k_blocks)

    # -- selection ----------------------------------------------------------

    def _merge(self, rq: Request, per_chunk: Dict[str, list],
               k_blocks: int) -> RequestSelection:
        """Requester-side merge: global top-k over every holder's
        candidates under the strict total order (score desc, chunk
        position, block id) — the same order a single instance ranking
        every block of the concatenated cache would use, so distributed ==
        global (tests assert it; ties cannot diverge, the order is total)."""
        cands = []
        for pos, cid in enumerate(rq.chunk_ids):
            for b, s in per_chunk[cid]:
                cands.append((-s, pos, b))
        cands.sort()
        chosen = cands[:k_blocks]
        self.obs_counts["merges"] += 1
        self.obs_counts["merge_candidates"] += len(cands)
        self.obs_counts["merge_selected"] += len(chosen)
        self._merge_sizes.append(len(cands))
        blocks: Dict[str, Tuple[int, ...]] = {cid: () for cid in rq.chunk_ids}
        for _, pos, b in chosen:
            cid = rq.chunk_ids[pos]
            blocks[cid] = blocks[cid] + (b,)
        blocks = {cid: tuple(sorted(bs)) for cid, bs in blocks.items()}
        # masks need chunk lengths; the callers attach them from the store
        return RequestSelection(rq.req_id, self.block_tokens, blocks, {})

    def _select(self, store: ChunkStore, rq: Request, step: int,
                truncate_local: bool) -> RequestSelection:
        """The one score -> local top-k -> merge -> mask pipeline.
        truncate_local=True is the distributed service (each holder
        returns at most k_blocks candidates); False ranks EVERY block —
        the single-instance reference. Both share this body so the
        distributed==global theorem compares selection POLICY, not two
        drifting implementations."""
        k_blocks = max(1, -(-int(rq.k_selected) // self.block_tokens))
        iq = self.index_query(rq, step)
        per_chunk = {}
        for cid in rq.chunk_ids:
            length = store.lookup(cid).length
            k = (k_blocks if truncate_local
                 else -(-length // self.block_tokens))
            self.obs_counts["roundtrips"] += 1
            pooled = self.pooled_scores(store, rq, iq, cid, step)
            per_chunk[cid] = self.topk_from_pooled(pooled, k)
        sel = self._merge(rq, per_chunk, k_blocks)
        masks = {cid: token_mask(sel.blocks[cid], self.block_tokens,
                                 store.lookup(cid).length)
                 for cid in rq.chunk_ids}
        return dataclasses.replace(sel, masks=masks)

    def select_request(self, store: ChunkStore, rq: Request,
                       step: int) -> RequestSelection:
        """score -> local top-k per holder -> global merge for one
        request. k_blocks = ceil(budget / block_tokens): NSA granularity
        rounds the token budget up to whole blocks."""
        return self._select(store, rq, step, truncate_local=True)

    def global_select(self, store: ChunkStore, rq: Request,
                      step: int) -> RequestSelection:
        """The single-instance reference selection: every block of every
        chunk ranked at once (no per-holder truncation). select_request
        must return exactly this — the distributed-top-k theorem the tests
        pin down."""
        return self._select(store, rq, step, truncate_local=False)

    # -- the engine's entry point -------------------------------------------

    def select_step(self, engine: "ServingEngine", requests: List[Request],
                    step: int) -> Dict[int, RequestSelection]:
        out = {rq.req_id: self.select_request(engine.store, rq, step)
               for rq in requests}
        self.log[step] = out
        return out


class ShardMapIndexerService(IndexerService):
    """The scoring round trip as a REAL mesh collective (ISSUE 7): the
    requester's narrow indexer query rides an all_gather across the
    "instance" axis, the HOLDER shard scores its resident keys and pools
    locally, and only the (S,) pooled scores come back off the mesh. The
    candidate policy (block top-k, global merge) is byte-for-byte the
    inherited IndexerService code — only WHERE scores compute moved, so
    verdicts match the host service and the distributed==global theorem
    carries over unchanged.

    Each scoring call's wall time accumulates in measured_index_s keyed
    (step, req_id, chunk_id); the shard_map exec backend folds it into the
    dispatch's measured "index" stage (the plan prices the indexer round
    trip as part of selection transport)."""

    name = "indexer-shard_map"

    def __init__(self, cfg: SelectionConfig = SelectionConfig(),
                 mla: MLAConfig = TINY_MLA, dtype=None):
        super().__init__(cfg, mla, dtype)
        self.measured_index_s: Dict[Tuple[int, int, str], float] = {}
        self._jits: Dict[tuple, object] = {}

    def pooled_scores(self, store: ChunkStore, rq: Request, iq: np.ndarray,
                      chunk_id: str, step: int) -> np.ndarray:
        import jax
        import jax.numpy as jnp
        from jax import lax
        from jax.sharding import PartitionSpec as P

        from repro import compat
        from repro.serving.backends import shard_map as SM

        keys = self.ensure_index_keys(store, chunk_id)
        holder = store.lookup(chunk_id).holder
        home = rq.home
        mesh, _devices = SM.mesh_for(store.n_instances)
        asm = SM.assembler_for(store.n_instances)
        iq32 = np.asarray(iq, np.float32)
        iq_g = asm.stack({home: iq32}, iq32.shape, jnp.float32)
        keys_g = asm.stack({holder: np.asarray(keys, np.float32)},
                           keys.shape, jnp.float32)
        PS = P(SM.AXIS)

        def build():
            def body(iq_l, keys_l):
                all_iq = lax.all_gather(iq_l, SM.AXIS)    # (NI, m_q, d)
                scores = jnp.einsum("md,sd->ms", all_iq[home], keys_l)
                return scores.max(axis=0)                 # (S,) pooled
            return jax.jit(compat.shard_map(body, mesh=mesh,
                                            in_specs=(PS, PS),
                                            out_specs=PS))

        cache_key = ("pooled", home, holder,
                     tuple(iq32.shape), tuple(keys.shape))
        pooled_g, dt = SM.staged_call(self._jits, cache_key, build,
                                      (iq_g, keys_g))
        tk = (step, rq.req_id, chunk_id)
        self.measured_index_s[tk] = self.measured_index_s.get(tk, 0.0) + dt
        return np.asarray(asm.take(pooled_g, holder))
