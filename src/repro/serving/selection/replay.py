"""Selection-trace record/replay (numpy-only).

One selection trace, many consumers — the same seam the request traces
(repro.serving.workload save_trace/load_trace) provide: the live
IndexerService records its per-step verdicts; save_selection_trace writes
them as JSON; a ReplaySelector feeds the identical masks back into the
planner. A plan built from a replayed trace is byte-for-byte the plan the
live indexer produced (same masks -> same pricing), which is what makes
the AnalyticBackend's StepStats bit-identical between the two — the
acceptance criterion tests/test_selection_service.py locks down. Replay
needs no jax at all, so an analytic engine can price the selection regime
from a trace on a machine that cannot score it.
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Tuple, Union

from repro.serving.plan import Request
from repro.serving.selection.types import RequestSelection, token_mask

# steps as recorded by a selector: engine step -> req_id -> RequestSelection
SelectionLog = Dict[int, Dict[int, "RequestSelection"]]


def selection_trace_payload(log: SelectionLog, block_tokens: int,
                            d_index: int, meta: dict = None) -> dict:
    """The JSON form of a selector's log. meta carries world geometry the
    way request-trace meta does; block_tokens/d_index ride in meta because
    replayed PRICING (indexer wire bytes, block counts) depends on them."""
    return {
        "meta": dict(meta or {}, block_tokens=block_tokens, d_index=d_index),
        "steps": {str(step): {str(rid): {cid: list(map(int, blocks))
                                         for cid, blocks in
                                         sel.blocks.items()}
                              for rid, sel in sels.items()}
                  for step, sels in log.items()},
    }


def save_selection_trace(path: Union[str, pathlib.Path], log: SelectionLog,
                         block_tokens: int, d_index: int,
                         meta: dict = None) -> dict:
    payload = selection_trace_payload(log, block_tokens, d_index, meta)
    pathlib.Path(path).write_text(json.dumps(payload, indent=1) + "\n")
    return payload


def load_selection_trace(trace: Union[str, pathlib.Path, dict]
                         ) -> Tuple[dict, Dict[int, Dict[int, dict]]]:
    """(meta, steps) of a saved trace; steps maps engine step -> req_id ->
    {chunk_id: [block ids]}. Accepts a path or an already-parsed payload."""
    payload = (trace if isinstance(trace, dict)
               else json.loads(pathlib.Path(trace).read_text()))
    steps = {int(step): {int(rid): {cid: tuple(blocks)
                                    for cid, blocks in by_chunk.items()}
                         for rid, by_chunk in sels.items()}
             for step, sels in payload["steps"].items()}
    return payload.get("meta", {}), steps


class ReplaySelector:
    """Feed a recorded selection trace back through the planner. The trace
    only means anything against the world (corpus, request stream, step
    numbering) it was recorded on — a missing (step, request) is a world
    mismatch and raises rather than silently de-selecting."""

    name = "replay"

    def __init__(self, trace: Union[str, pathlib.Path, dict]):
        meta, self._steps = load_selection_trace(trace)
        self.meta = meta
        self.block_tokens = int(meta["block_tokens"])
        self.d_index = int(meta["d_index"])

    def select_step(self, engine, requests: List[Request],
                    step: int) -> Dict[int, RequestSelection]:
        if step not in self._steps:
            raise KeyError(f"selection trace has no step {step} "
                           f"(recorded: {sorted(self._steps)})")
        raw = self._steps[step]
        out: Dict[int, RequestSelection] = {}
        for rq in requests:
            if rq.req_id not in raw:
                raise KeyError(f"selection trace step {step} has no request "
                               f"{rq.req_id}")
            by_chunk = raw[rq.req_id]
            # a live recording writes an entry for EVERY chunk of a
            # selected request (an empty tuple when the indexer chose
            # nothing there) — a missing chunk id is a trace/world
            # mismatch, never a de-selection
            missing = [cid for cid in rq.chunk_ids if cid not in by_chunk]
            if missing:
                raise KeyError(f"selection trace step {step} request "
                               f"{rq.req_id} has no entry for chunks "
                               f"{missing}")
            blocks = {cid: tuple(sorted(by_chunk[cid]))
                      for cid in rq.chunk_ids}
            masks = {cid: token_mask(blocks[cid], self.block_tokens,
                                     engine.store.lookup(cid).length)
                     for cid in rq.chunk_ids}
            out[rq.req_id] = RequestSelection(rq.req_id, self.block_tokens,
                                              blocks, masks)
        return out
