"""Train step: grad-accumulation microbatching (lax.scan) + AdamW update.

Memory posture for the big configs (DESIGN.md §5): remat at block
boundaries (model._scan_fwd), SP residuals via the sharding policy, f32
grad accumulation (configurable), donated params/opt-state buffers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model as MD
from repro.optim.adamw import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_micro: int = 1                 # grad-accumulation microbatches
    accum_dtype = jnp.float32
    ep_axis: Optional[str] = None


def make_train_step(cfg: MD.ModelConfig, opt_cfg: AdamWConfig,
                    tcfg: TrainConfig = TrainConfig(),
                    lr_fn: Optional[Callable] = None,
                    param_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params', opt_state',
    metrics). Batch leading dim = global batch (sharded by the caller's
    in_shardings); microbatching splits it inside the step.

    param_shardings (optional tree of NamedSharding) pins the grad-accum
    scan carry to the FSDP param layout: without it GSPMD replicates the
    carry, turning every microbatch's gradient reduction into a FULL f32
    all-reduce + weight re-gather (measured: 5.3 TB/device/step on
    nemotron-340B — EXPERIMENTS.md §Perf B2)."""

    def loss(p, mb):
        return MD.loss_fn(p, cfg, mb, ep_axis=tcfg.ep_axis)

    def _pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def train_step(params, opt_state, batch):
        n = tcfg.n_micro
        if n == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                batch)

            def micro(acc, one):
                l, g = jax.value_and_grad(loss)(params, one)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(tcfg.accum_dtype), acc, g)
                return _pin(acc), l

            zeros = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params))
            grads, ls = lax.scan(micro, zeros, mb)
            grads = jax.tree.map(lambda g: g / n, grads)
            l = jnp.mean(ls)
        lr = lr_fn(opt_state["step"]) if lr_fn is not None else None
        params, opt_state, mets = adamw_update(params, grads, opt_state,
                                               opt_cfg, lr)
        mets["loss"] = l
        return params, opt_state, mets

    return train_step
