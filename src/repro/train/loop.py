"""Fault-tolerant training loop.

Large-scale posture (DESIGN.md §5): periodic async sharded checkpoints;
on step failure, restore the latest snapshot and REPLAY the data from the
step index (the stateless pipeline makes resume exact); metrics logged per
step. Node-failure handling at this layer means: the job restarts on a new
(possibly different) mesh and restores elastically — which
tests/progs/dist_ckpt_prog.py exercises across mesh shapes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticPipeline


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    max_restores: int = 3
    log_every: int = 10


def train_loop(train_step: Callable, params, opt_state,
               pipeline: SyntheticPipeline, ckpt: CheckpointManager,
               cfg: LoopConfig,
               fault_hook: Optional[Callable[[int], None]] = None,
               log: Optional[List[dict]] = None) -> tuple:
    """Runs to cfg.total_steps, surviving up to max_restores induced/real
    step failures. fault_hook(step) may raise to simulate a node failure
    (tests use this). Returns (params, opt_state, log)."""
    log = log if log is not None else []
    start = ckpt.latest_step()
    step = 0
    if start is not None:       # warm start from an earlier run
        snap = ckpt.restore(start, {"params": params, "opt": opt_state})
        params, opt_state = snap["params"], snap["opt"]
        step = start
    restores = 0
    while step < cfg.total_steps:
        try:
            if fault_hook is not None:
                fault_hook(step)
            batch = pipeline.batch_at(step)
            params, opt_state, mets = train_step(params, opt_state, batch)
            if step % cfg.log_every == 0:
                log.append({"step": step,
                            "loss": float(mets["loss"]),
                            "grad_norm": float(mets["grad_norm"]),
                            "t": time.time()})
            step += 1
            if step % cfg.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
        except Exception:                                  # noqa: BLE001
            restores += 1
            if restores > cfg.max_restores:
                raise
            latest = ckpt.latest_step()
            if latest is None:
                raise
            ckpt.wait()
            snap = ckpt.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = snap["params"], snap["opt"]
            step = latest
            log.append({"step": step, "event": "restored",
                        "restores": restores})
    ckpt.wait()
    return params, opt_state, log
