from repro.kernels.mla_decode.ops import mla_decode
from repro.kernels.mla_decode.ref import mla_decode_ref
