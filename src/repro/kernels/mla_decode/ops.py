"""jit'd public wrapper for the absorbed-MLA decode kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.merge import Partial
from repro.kernels.common import use_interpret
from repro.kernels.mla_decode.kernel import mla_decode_pallas


@functools.partial(jax.jit,
                   static_argnames=("d_v", "scale", "block_s", "interpret"))
def mla_decode(q: jax.Array, ckv: jax.Array,
               lengths: Optional[jax.Array] = None, *, d_v: int = 512,
               scale: float = 1.0, block_s: int = 512,
               interpret: Optional[bool] = None) -> Partial:
    """Absorbed-MLA decode partial: q (B, H, D) over ckv (B, S, D).

    Returns Partial(o (B,H,d_v), m, l) — the (o, m, l) wire triple of §3.2.
    """
    if lengths is None:
        lengths = jnp.full((q.shape[0],), ckv.shape[1], jnp.int32)
    interp = use_interpret() if interpret is None else interpret
    o, m, l = mla_decode_pallas(q, ckv, lengths.astype(jnp.int32), d_v,
                                scale, block_s, interp)
    return Partial(o=o, m=m, l=l)
