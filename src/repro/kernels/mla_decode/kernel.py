"""Pallas TPU kernel: absorbed-MLA flash decode (FlashMLA analogue).

The holder-side hot-spot of ROUTE (§6.3): a small batch of absorbed query
rows (B requesters x H heads, each d_qk=576 wide) attends the resident
latent cache. TPU-native tiling (DESIGN.md §6):

* grid (B, S/BS): batch major, cache blocks minor (sequential) — the online
  -softmax accumulator lives in VMEM scratch across the S sweep;
* q tile (H, D) stays resident; one (BS, D) c^KV tile streams HBM->VMEM per
  step; BS=512 rows x 576 lanes x 2 B ~ 0.6 MB — well inside VMEM, and the
  (H x D) @ (D x BS) score matmul feeds the MXU with a 128-multiple
  contraction (576 = 4.5 x 128; H pads to the sublane quantum);
* the value contraction reuses the SAME resident tile (values are the first
  d_v=512 lanes of the latent entry — MLA's byte-asymmetry trick), so no
  second stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, ckv_ref, len_ref, o_ref, m_ref, l_ref,
            acc, m_scr, l_scr, *, scale: float, d_v: int, block_s: int):
    s_idx = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(s_idx == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0].astype(jnp.float32)                  # (H, D)
    ckv = ckv_ref[0].astype(jnp.float32)              # (BS, D)
    scores = jax.lax.dot_general(
        q, ckv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (H, BS)
    # residency mask for the ragged tail (valid cache length per batch row)
    valid = (s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)) < len_ref[0]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)                   # exp(-inf - m) = 0 ok
    p = jnp.exp(scores - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, ckv[:, :d_v], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...] = m_new, l_new

    @pl.when(s_idx == ns - 1)
    def _finish():
        l = l_scr[...]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0] = acc[...] / denom[:, None]
        m_ref[0] = m_scr[...]
        l_ref[0] = l

def mla_decode_pallas(q: jax.Array, ckv: jax.Array, lengths: jax.Array,
                      d_v: int, scale: float, block_s: int = 512,
                      interpret: bool = True):
    """q (B, H, D); ckv (B, S, D); lengths (B,) valid entries per row."""
    B, H, D = q.shape
    S = ckv.shape[1]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    grid = (B, S // block_s)
    kernel = functools.partial(_kernel, scale=scale, d_v=d_v,
                               block_s=block_s)
    out_shape = (jax.ShapeDtypeStruct((B, H, d_v), jnp.float32),
                 jax.ShapeDtypeStruct((B, H), jnp.float32),
                 jax.ShapeDtypeStruct((B, H), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_s, D), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=(
            pl.BlockSpec((1, H, d_v), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, s: (b, 0)),
            pl.BlockSpec((1, H), lambda b, s: (b, 0)),
        ),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((H, d_v), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
        interpret=interpret,
    )(q, ckv, lengths)
