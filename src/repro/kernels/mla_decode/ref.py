"""Pure-jnp oracle for the absorbed-MLA decode kernel (the holder-side
partial attention of ROUTE, §6.3 — our FlashMLA analogue)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mla_decode_ref(q: jax.Array, ckv: jax.Array, d_v: int,
                   scale: float = 1.0):
    """q (B, H, D); ckv (B, S, D) with values = ckv[..., :d_v].

    Returns the normalized partial + sufficient statistic:
    (o (B, H, d_v) f32, m (B, H) f32, l (B, H) f32)."""
    logits = jnp.einsum("bhd,bsd->bhs", q.astype(jnp.float32),
                        ckv.astype(jnp.float32)) * scale
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhs,bsd->bhd", p / l[..., None],
                   ckv[..., :d_v].astype(jnp.float32))
    return o, m, l
