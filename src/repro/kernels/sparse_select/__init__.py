from repro.kernels.sparse_select.ops import sparse_select_decode
from repro.kernels.sparse_select.ref import sparse_select_ref
