"""Pure-jnp oracle for block-sparse selected attention (the DSA/NSA
selection regime, §5.4, at TPU-native 64-token-block granularity)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_select_ref(q: jax.Array, ckv: jax.Array, block_idx: jax.Array,
                      d_v: int, block_tokens: int, scale: float = 1.0):
    """q (B, H, D); ckv (B, S, D); block_idx (B, KB) selected block ids.

    Gathers the selected blocks (canonical positions — no re-rotation, §3.3)
    and attends. Returns (o (B,H,d_v), m, l) f32."""
    B, KB = block_idx.shape

    def one(qb, cb, ib):
        blocks = cb.reshape(-1, block_tokens, cb.shape[-1])   # (NB, T, D)
        sel = blocks[ib].reshape(KB * block_tokens, cb.shape[-1])
        logits = (qb.astype(jnp.float32) @ sel.astype(jnp.float32).T) * scale
        m = jnp.max(logits, axis=-1)
        p = jnp.exp(logits - m[:, None])
        l = jnp.sum(p, axis=-1)
        o = (p / l[:, None]) @ sel[:, :d_v].astype(jnp.float32)
        return o, m, l

    return jax.vmap(one)(q, ckv, block_idx)
