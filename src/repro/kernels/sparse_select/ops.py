"""jit'd public wrapper for block-sparse selected attention."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.merge import Partial
from repro.kernels.common import use_interpret
from repro.kernels.sparse_select.kernel import sparse_select_pallas


@functools.partial(jax.jit, static_argnames=("d_v", "scale", "block_tokens",
                                             "interpret"))
def sparse_select_decode(q: jax.Array, ckv: jax.Array,
                         block_idx: jax.Array, *, d_v: int = 512,
                         scale: float = 1.0, block_tokens: int = 64,
                         interpret: Optional[bool] = None) -> Partial:
    """Selected-set decode partial (§5.4): the holder attends the indexer's
    chosen blocks in place. Cost tracks KB (the selection budget), not the
    resident store size."""
    interp = use_interpret() if interpret is None else interpret
    o, m, l = sparse_select_pallas(q, ckv, block_idx.astype(jnp.int32),
                                   d_v, scale, block_tokens, interp)
    return Partial(o=o, m=m, l=l)
