"""Pallas TPU kernel: block-sparse selected attention (DSA/NSA regime).

TPU adaptation of the token-level indexer gather (DESIGN.md §6): selection
is at 64-token *block* granularity so the gather is a BlockSpec index_map
driven by scalar-prefetched block ids — the sparse access becomes a dense
(BLOCK, D) VMEM stream per grid step, which is what the MXU wants. The
holder cost tracks the selection budget KB, not the store size (§6.3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(idx_ref, q_ref, ckv_ref, o_ref, m_ref, l_ref,
            acc, m_scr, l_scr, *, scale: float, d_v: int):
    k_idx = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k_idx == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0].astype(jnp.float32)                  # (H, D)
    blk = ckv_ref[0].astype(jnp.float32)              # (BLOCK, D) gathered
    scores = jax.lax.dot_general(
        q, blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (H, BLOCK)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, blk[:, :d_v], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...] = m_new, l_new

    @pl.when(k_idx == nk - 1)
    def _finish():
        l = l_scr[...]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0] = acc[...] / denom[:, None]
        m_ref[0] = m_scr[...]
        l_ref[0] = l


def sparse_select_pallas(q: jax.Array, ckv: jax.Array, block_idx: jax.Array,
                         d_v: int, scale: float, block_tokens: int = 64,
                         interpret: bool = True):
    """q (B, H, D); ckv (B, S, D); block_idx (B, KB) int32 block ids.
    S % block_tokens == 0. The index_map gathers selected blocks directly
    from HBM via scalar prefetch."""
    B, H, D = q.shape
    KB = block_idx.shape[1]
    kernel = functools.partial(_kernel, scale=scale, d_v=d_v)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KB),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, k, idx: (b, 0, 0)),
            # the gather: block k of batch b reads cache block idx[b, k]
            pl.BlockSpec((1, block_tokens, D),
                         lambda b, k, idx: (b, idx[b, k], 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, H, d_v), lambda b, k, idx: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, k, idx: (b, 0)),
            pl.BlockSpec((1, H), lambda b, k, idx: (b, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((H, d_v), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
    )
    out_shape = (jax.ShapeDtypeStruct((B, H, d_v), jnp.float32),
                 jax.ShapeDtypeStruct((B, H), jnp.float32),
                 jax.ShapeDtypeStruct((B, H), jnp.float32))
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(block_idx, q, ckv)
