"""Pallas TPU kernel: causal flash attention over the latent cache
(absorbed-MLA prefill — fills the canonical c^KV store while computing).

Tiling: grid (B, Sq/BQ, Sk/BK), k innermost (sequential accumulation).
Causal block skipping: a (BQ, BK) tile is skipped when its query block ends
before its key block starts — upper-triangle tiles cost nothing, the
classic flash schedule. Heads fold into the q tile (H*BQ rows) so the MXU
sees a tall-skinny (H*BQ, D) @ (D, BK) matmul with D = 576.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, ckv_ref, o_ref, acc, m_scr, l_scr,
            *, scale: float, d_v: int, block_q: int, block_k: int,
            sq: int, sk: int):
    k_idx = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_idx == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_idx = pl.program_id(1)
    q_end = (q_idx + 1) * block_q - 1 + (sk - sq)     # last query's kv reach
    k_start = k_idx * block_k

    @pl.when(k_start <= q_end)                        # causal block skip
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (BQ, H, D)
        BQ, H, D = q.shape
        qf = q.reshape(BQ * H, D)
        kv = ckv_ref[0].astype(jnp.float32)           # (BK, D)
        scores = jax.lax.dot_general(
            qf, kv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ*H, BK)
        qpos = (q_idx * block_q
                + jax.lax.broadcasted_iota(jnp.int32, (BQ, H), 0)
                + (sk - sq)).reshape(BQ * H)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, scores.shape, 1)
        scores = jnp.where(kpos <= qpos[:, None], scores, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, kv[:, :d_v], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(k_idx == nk - 1)
    def _finish():
        l = l_scr[...]
        denom = jnp.where(l > 0, l, 1.0)
        BQ = o_ref.shape[1]
        H = o_ref.shape[2]
        o_ref[0] = (acc[...] / denom[:, None]).reshape(BQ, H, d_v)


def flash_prefill_pallas(q: jax.Array, ckv: jax.Array, d_v: int,
                         scale: float, block_q: int = 128,
                         block_k: int = 512, interpret: bool = True):
    """q (B, Sq, H, D); ckv (B, Sk, D) with Sq <= Sk, tail-aligned causal."""
    B, Sq, H, D = q.shape
    Sk = ckv.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    kernel = functools.partial(_kernel, scale=scale, d_v=d_v,
                               block_q=block_q, block_k=block_k,
                               sq=Sq, sk=Sk)
    return pl.pallas_call(
        kernel,
        grid=(B, Sq // block_q, Sk // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, H, D), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, H, d_v),
                               lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, d_v), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q * H, d_v), jnp.float32),
            pltpu.VMEM((block_q * H,), jnp.float32),
            pltpu.VMEM((block_q * H,), jnp.float32),
        ],
        interpret=interpret,
    )(q, ckv)
