"""Pure-jnp oracle: causal latent attention over the c^KV store (the
prefill/training hot-spot that fills the canonical cache)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q: jax.Array, ckv: jax.Array, d_v: int,
                      scale: float = 1.0) -> jax.Array:
    """q (B, Sq, H, D); ckv (B, Sk, D); causal with queries aligned to the
    cache tail (query i attends entries [0, Sk - Sq + i]). Returns
    (B, Sq, H, d_v) f32."""
    B, Sq, H, D = q.shape
    Sk = ckv.shape[1]
    logits = jnp.einsum("bqhd,bkd->bhqk", q.astype(jnp.float32),
                        ckv.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    mask = qpos >= jnp.arange(Sk)[None, :]
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkd->bqhd", p, ckv[..., :d_v].astype(jnp.float32))
