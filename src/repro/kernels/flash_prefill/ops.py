"""jit'd public wrapper for causal latent flash prefill."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import use_interpret
from repro.kernels.flash_prefill.kernel import flash_prefill_pallas


@functools.partial(jax.jit, static_argnames=("d_v", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_prefill(q: jax.Array, ckv: jax.Array, *, d_v: int = 512,
                  scale: float = 1.0, block_q: int = 128,
                  block_k: int = 512,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Causal absorbed-MLA attention: q (B,Sq,H,D) over ckv (B,Sk,D)."""
    interp = use_interpret() if interpret is None else interpret
    return flash_prefill_pallas(q, ckv, d_v, scale, block_q, block_k, interp)
