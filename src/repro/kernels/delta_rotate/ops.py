"""jit'd public wrapper for the splice delta-rotation kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.delta_rotate.kernel import delta_rotate_pallas
from repro.models.layers import rope_cos_sin


@functools.partial(jax.jit, static_argnames=("head_dim", "theta", "block_s",
                                             "interpret"))
def delta_rotate_band(band: jax.Array, delta: jax.Array, *, head_dim: int,
                      theta: float = 10000.0, block_s: int = 1024,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Re-home a fetched chunk's rope band by delta positions (§2.2).
    band (S, d_r). Plugs into core.splice.splice_delta_rotate(rotate_fn=...).
    """
    cos, sin = rope_cos_sin(jnp.asarray(delta, jnp.float32), head_dim, theta)
    interp = use_interpret() if interpret is None else interpret
    return delta_rotate_pallas(band, cos, sin, block_s, interp)
