from repro.kernels.delta_rotate.ops import delta_rotate_band
from repro.kernels.delta_rotate.ref import delta_rotate_ref
