"""Pallas TPU kernel: delta-rotation of the decoupled-RoPE band.

The FETCH splice's dominant cost (~80% of the ~3 ms, §2.2/§7) is this
purely positional rotation. The angle depends only on delta — cos/sin are
precomputed once (d_r/2 values) and broadcast from VMEM while (BS, d_r)
tiles stream through; the kernel is bandwidth-bound and token-count-flat
per launch, which is exactly the cost shape the paper measures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(band_ref, cos_ref, sin_ref, out_ref):
    x = band_ref[...].astype(jnp.float32)             # (BS, d_r)
    d2 = x.shape[-1] // 2
    x1, x2 = x[:, :d2], x[:, d2:]
    c = cos_ref[...].astype(jnp.float32)              # (1, d2)
    s = sin_ref[...].astype(jnp.float32)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    out_ref[...] = out.astype(out_ref.dtype)


def delta_rotate_pallas(band: jax.Array, cos: jax.Array, sin: jax.Array,
                        block_s: int = 1024, interpret: bool = True):
    """band (S, d_r); cos/sin (d_r/2,) for the fixed delta."""
    S, d_r = band.shape
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    return pl.pallas_call(
        _kernel,
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, d_r), lambda i: (i, 0)),
            pl.BlockSpec((1, d_r // 2), lambda i: (0, 0)),
            pl.BlockSpec((1, d_r // 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, d_r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, d_r), band.dtype),
        interpret=interpret,
    )(band, cos[None], sin[None])
