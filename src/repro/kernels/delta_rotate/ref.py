"""Pure-jnp oracle for the FETCH-splice delta-rotation (§2.2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import delta_rotate


def delta_rotate_ref(band: jax.Array, delta, head_dim: int,
                     theta: float = 10000.0) -> jax.Array:
    """band (S, d_r) rope-encoded at cached positions -> re-homed by delta.
    The per-layer splice hot-spot: launch-bound, token-count-flat (§7)."""
    return delta_rotate(band, delta, head_dim, theta)
