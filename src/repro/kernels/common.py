"""Shared kernel plumbing: interpret-mode selection (TPU target, CPU
validation — task spec) and tiling helpers."""

from __future__ import annotations

import jax

MXU_LANE = 128        # MXU matmul dims want multiples of 128


def use_interpret() -> bool:
    """pl.pallas_call(interpret=True) on CPU (validation); compiled path on
    real TPU."""
    return jax.default_backend() != "tpu"


def pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m
