"""Pure-jnp oracle: M-way online-softmax merge (== core.merge.merge_stacked).
"""

from __future__ import annotations

import jax

from repro.core.merge import Partial, merge_stacked


def softmax_merge_ref(o: jax.Array, m: jax.Array, l: jax.Array) -> Partial:
    """o (M, B, H, d_v); m/l (M, B, H) -> merged Partial (B, H, d_v)."""
    return merge_stacked(o, m, l)
