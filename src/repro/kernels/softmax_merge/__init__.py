from repro.kernels.softmax_merge.ops import softmax_merge
from repro.kernels.softmax_merge.ref import softmax_merge_ref
