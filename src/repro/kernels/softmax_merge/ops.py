"""jit'd public wrapper for the M-way merge kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.core.merge import Partial
from repro.kernels.common import use_interpret
from repro.kernels.softmax_merge.kernel import softmax_merge_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax_merge(o: jax.Array, m: jax.Array, l: jax.Array, *,
                  interpret: Optional[bool] = None) -> Partial:
    """Merge M routed partials exactly (§3.3): o (M,B,H,d_v), m/l (M,B,H)."""
    interp = use_interpret() if interpret is None else interpret
    oo, mo, lo = softmax_merge_pallas(o, m, l, interp)
    return Partial(o=oo, m=mo, l=lo)
