"""Pallas TPU kernel: M-way (o, m, l) online-softmax merge.

The requester-side recombination of ROUTE (<=25 us in the paper, §4.2).
One fused pass: m* = max_i m_i, w_i = l_i exp(m_i - m*), o* = sum w_i o_i /
sum w_i. Grid over B; the (M, H, d_v) partial stack for one requester batch
row fits VMEM for any realistic fan-in (M <= 16, §6.3 elbow).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(o_ref, m_ref, l_ref, oo_ref, mo_ref, lo_ref):
    o = o_ref[:, 0].astype(jnp.float32)               # (M, H, d_v)
    m = m_ref[:, 0].astype(jnp.float32)               # (M, H)
    l = l_ref[:, 0].astype(jnp.float32)
    m_star = jnp.max(m, axis=0)                       # (H,)
    safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
    w = l * jnp.exp(m - safe[None])                   # exp(-inf)=0: identity
    l_star = jnp.sum(w, axis=0)
    denom = jnp.where(l_star > 0, l_star, 1.0)
    oo_ref[0] = jnp.einsum("mh,mhd->hd", w / denom[None], o)
    mo_ref[0] = jnp.where(l_star > 0, m_star, NEG_INF)
    lo_ref[0] = l_star


def softmax_merge_pallas(o: jax.Array, m: jax.Array, l: jax.Array,
                         interpret: bool = True):
    """o (M, B, H, d_v); m/l (M, B, H)."""
    M, B, H, d_v = o.shape
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((M, 1, H, d_v), lambda b: (0, b, 0, 0)),
            pl.BlockSpec((M, 1, H), lambda b: (0, b, 0)),
            pl.BlockSpec((M, 1, H), lambda b: (0, b, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, H, d_v), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b: (b, 0)),
            pl.BlockSpec((1, H), lambda b: (b, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct((B, H, d_v), jnp.float32),
                   jax.ShapeDtypeStruct((B, H), jnp.float32),
                   jax.ShapeDtypeStruct((B, H), jnp.float32)),
        interpret=interpret,
    )(o, m, l)
