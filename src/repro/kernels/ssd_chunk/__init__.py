from repro.kernels.ssd_chunk.ops import ssd_intra_chunk
from repro.kernels.ssd_chunk.ref import ssd_intra_chunk_ref
