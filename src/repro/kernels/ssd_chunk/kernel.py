"""Pallas TPU kernel: fused SSD intra-chunk (Mamba2 hot-spot).

The jnp form (ssm.ssd_chunked) materializes the (Q,Q,H) decay/gate tensors
through HBM ~5x per chunk — the §Roofline table's dominant memory term for
the SSM/hybrid archs. This kernel keeps everything chunk-local in VMEM:

* grid (b, nc, H/HB): one (chunk x head-block) per step;
* loads x (Q, HB, P), dt (Q, HB), B/C (Q, N) tiles once;
* computes CB = C B^T on the MXU, the causal decay gate in VREGs, then a
  python-unrolled loop of HB (Q,Q)@(Q,P) gated matmuls for y_intra and
  (N,Q)@(Q,P) matmuls for the chunk output states;
* writes only y (Q, HB, P), states (HB, P, N), cum (Q, HB) — HBM traffic
  = inputs + outputs, no quadratic intermediates.

VMEM at Q=128, HB=8, P=64, N=128 (mamba2-370m geometry): x 256 KB +
(Q,Q) gate 64 KB + accumulators ~ 0.6 MB — comfortably resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, cum_ref,
            *, hb: int):
    x = x_ref[0, 0].astype(jnp.float32)          # (Q, HB, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q, HB)
    A = a_ref[...].astype(jnp.float32)           # (HB,)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Q = x.shape[0]

    da = dt * A[None, :]                         # (Q, HB)
    cum = jnp.cumsum(da, axis=0)
    cum_ref[0, 0] = cum

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    causal = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    seg = cum[-1]                                # (HB,)
    dtx = dt[:, :, None] * x                     # (Q, HB, P)

    for h in range(hb):                          # static head unroll
        expo = cum[:, None, h] - cum[None, :, h]
        expo = jnp.where(causal, expo, NEG_INF)
        G = CB * jnp.exp(expo)                   # (Q, Q) gated scores
        y_h = jax.lax.dot_general(G, dtx[:, h], (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        y_ref[0, 0, :, h] = y_h                  # (Q, P)
        # chunk output state: S_h = sum_k exp(seg-cum_k) dt_k B_k x_k^T
        w = jnp.exp(seg[h] - cum[:, h])          # (Q,)
        bw = Bm * w[:, None]                     # (Q, N)
        st = jax.lax.dot_general(dtx[:, h], bw, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        st_ref[0, 0, h] = st                     # (P, N)


def ssd_intra_chunk_pallas(x, dt, A, B, C, hb: int = 8,
                           interpret: bool = True):
    """x (b, nc, Q, H, P); dt (b, nc, Q, H); A (H,); B/C (b, nc, Q, N)."""
    b, nc, Q, H, P = x.shape
    N = B.shape[-1]
    hb = min(hb, H)
    assert H % hb == 0, (H, hb)
    grid = (b, nc, H // hb)
    kernel = functools.partial(_kernel, hb=hb)
    out_shape = (
        jax.ShapeDtypeStruct((b, nc, Q, H, P), jnp.float32),   # y_intra
        jax.ShapeDtypeStruct((b, nc, H, P, N), jnp.float32),   # states
        jax.ShapeDtypeStruct((b, nc, Q, H), jnp.float32),      # cum
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, hb, P), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, Q, hb), lambda i, j, k: (i, j, 0, k)),
            pl.BlockSpec((hb,), lambda i, j, k: (k,)),
            pl.BlockSpec((1, 1, Q, N), lambda i, j, k: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, Q, hb, P), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, hb, P, N), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, Q, hb), lambda i, j, k: (i, j, 0, k)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(x, dt, A, B, C)
