"""Pure-jnp oracle for the fused SSD intra-chunk kernel: the quadratic
("attention-like") term, the per-chunk output state, and the cumulative
decay — exactly the three quantities ssm.ssd_chunked materializes through
HBM (the mamba-cell memory bottleneck in the §Roofline table)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_intra_chunk_ref(x, dt, A, B, C):
    """x (b, nc, Q, H, P); dt (b, nc, Q, H) post-softplus; A (H,) negative;
    B, C (b, nc, Q, N).

    Returns (y_intra (b,nc,Q,H,P), states (b,nc,H,P,N), cum (b,nc,Q,H)),
    all f32 — matching ssm.ssd_chunked's internals."""
    Q = x.shape[2]
    da = dt.astype(jnp.float32) * A[None, None, None]
    cum = jnp.cumsum(da, axis=2)
    expo = cum[:, :, :, None] - cum[:, :, None]          # (b,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    expo = jnp.where(causal[None, None, :, :, None], expo, -jnp.inf)
    L = jnp.exp(expo)
    CB = jnp.einsum("bcqn,bckn->bcqk", C.astype(jnp.float32),
                    B.astype(jnp.float32))
    G = CB[..., None] * L
    y = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", G, dt.astype(jnp.float32),
                   x.astype(jnp.float32))
    seg = cum[:, :, -1]
    decay_out = jnp.exp(seg[:, :, None] - cum)
    states = jnp.einsum("bckh,bckh,bckn,bckhp->bchpn", decay_out,
                        dt.astype(jnp.float32), B.astype(jnp.float32),
                        x.astype(jnp.float32))
    return y, states, cum
