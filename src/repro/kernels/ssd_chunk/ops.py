"""jit'd public wrapper for the fused SSD intra-chunk kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import use_interpret
from repro.kernels.ssd_chunk.kernel import ssd_intra_chunk_pallas


@functools.partial(jax.jit, static_argnames=("hb", "interpret"))
def ssd_intra_chunk(x, dt, A, B, C, *, hb: int = 8,
                    interpret: Optional[bool] = None):
    """Fused SSD intra-chunk: (y_intra, chunk_states, cum) with no
    (Q,Q,H) HBM intermediates. Shapes as ssm.ssd_chunked's chunked
    tensors: x (b,nc,Q,H,P), dt (b,nc,Q,H), A (H,), B/C (b,nc,Q,N)."""
    interp = use_interpret() if interpret is None else interpret
    return ssd_intra_chunk_pallas(x, dt, A, B, C, hb, interp)
