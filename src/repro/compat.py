"""Version-gated JAX API shims shared by src/, tests/progs/ and benchmarks/.

The repo must run on the installed jax (0.4.x here) and on current releases:

* ``jax.shard_map`` was ``jax.experimental.shard_map.shard_map`` before 0.6;
* ``jax.make_mesh(..., axis_types=...)`` / ``jax.sharding.AxisType`` do not
  exist before 0.6 (explicit Auto axes are the 0.4 default anyway);
* ``Compiled.cost_analysis()`` returns a one-element list on older jaxlib
  and a plain dict on newer ones.

Keep every version branch HERE — callers import the symbol, never probe jax.
"""

from __future__ import annotations

from typing import Sequence

import jax

try:
    shard_map = jax.shard_map
except AttributeError:                      # jax < 0.6
    from jax.experimental.shard_map import shard_map  # noqa: F401


def axis_size(axis_name) -> int:
    """lax.axis_size (jax >= 0.6); psum(1, axis) on older releases."""
    try:
        return jax.lax.axis_size(axis_name)
    except AttributeError:
        return jax.lax.psum(1, axis_name)


def pvary(x, axes):
    """lax.pvary where it exists; identity before the vma type system (old
    shard_map does not distinguish varying from invariant carries)."""
    pv = getattr(jax.lax, "pvary", None)
    return pv(x, axes) if pv is not None else x


def shard_map_unchecked(f, **kw):
    """shard_map with the static replication checker off (the kwarg was
    renamed check_rep -> check_vma across jax versions). Needed for bodies
    old jax mis-types, e.g. a psum inside a scan carry."""
    try:
        return shard_map(f, check_rep=False, **kw)
    except TypeError:
        return shard_map(f, check_vma=False, **kw)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """jax.make_mesh with explicit Auto axis_types where the API has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def cost_analysis(compiled) -> dict:
    """Compiled.cost_analysis() normalized to a flat dict (may be empty)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
