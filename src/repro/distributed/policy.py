"""Activation-sharding policy context: the model code asks for constraints
at named points (residual stream, logits); the launcher installs a policy
for the active mesh. Keeps model code mesh-agnostic while enabling
sequence-parallel residuals (Megatron-SP style) on the wide archs."""

from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: contextvars.ContextVar = contextvars.ContextVar("policy", default=None)


class ShardingPolicy:
    """kind -> PartitionSpec map applied via with_sharding_constraint."""

    def __init__(self, mesh: Mesh, specs: dict):
        self.mesh = mesh
        self.specs = specs

    def constrain(self, x, kind: str):
        spec = self.specs.get(kind)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def sp_policy(mesh: Mesh, seq_shard: bool = True) -> ShardingPolicy:
    """Residual stream (B, S, D): batch over (pod,data); with seq_shard,
    sequence over model between blocks (SP)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    residual = P(dp_entry, "model" if seq_shard else None, None)
    return ShardingPolicy(mesh, {
        "residual": residual,
        "logits": P(dp_entry, None, "model"),
    })


def constrain(x, kind: str):
    pol = _POLICY.get()
    return pol.constrain(x, kind) if pol is not None else x


def current() -> Optional[ShardingPolicy]:
    return _POLICY.get()


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    tok = _POLICY.set(policy)
    try:
        yield
    finally:
        _POLICY.reset(tok)
