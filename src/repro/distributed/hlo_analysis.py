"""Collective-traffic analysis from lowered/compiled HLO text.

The roofline's collective term (task spec) is not in cost_analysis(): we
parse the HLO for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute and sum operand sizes. The same parser powers the
paper-validation benchmark that *measures* ROUTE vs FETCH wire bytes on our
own compiled programs (§2.1/§5.2) — the byte asymmetry read off real HLO.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Iterable, List, Optional

import numpy as np


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# e.g.  %all-gather.3 = bf16[16,128,576]{2,1,0} all-gather(...)
#       ROOT %r = (f32[8,4]{...}, f32[8]{...}) all-to-all(...)
_INSTR_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>" + "|".join(COLLECTIVE_OPS) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """'bf16[16,128]{1,0}' or '(f32[8], f32[8,4])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue          # e.g. token[] / opaque
        dims = m.group("dims")
        n = int(np.prod([int(d) for d in dims.split(",")])) if dims else 1
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Byte totals per collective kind, from one HLO module.

    result_bytes: sum of result-shape sizes (the task-spec "operand sizes" —
        for these ops result size == the redistributed payload size; for
        all-gather the result is the post-gather size).
    wire_bytes: ring-model bytes actually crossing links per device:
        all-gather / reduce-scatter / all-to-all: B * (n-1)/n
        all-reduce: 2B * (n-1)/n ;  collective-permute: B.
    """
    counts: Dict[str, int]
    result_bytes: Dict[str, int]
    wire_bytes: float

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str, n_devices: int = 1) -> CollectiveStats:
    counts: Dict[str, int] = defaultdict(int)
    rbytes: Dict[str, int] = defaultdict(int)
    wire = 0.0
    seen_start_ids = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        # skip -done halves of async pairs (the -start carries the shape)
        if re.search(r"(all-gather|all-reduce|collective-permute|all-to-all)"
                     r"-done", line):
            continue
        op = m.group("op")
        b = shape_bytes(m.group("shape"))
        counts[op] += 1
        rbytes[op] += b
        frac = (n_devices - 1) / max(1, n_devices)
        if op == "all-reduce":
            wire += 2 * b * frac
        elif op == "collective-permute":
            wire += b
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            wire += b * frac
    return CollectiveStats(dict(counts), dict(rbytes), wire)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def flops_and_bytes(cost_analysis: Optional[dict]) -> tuple:
    """Extract (flops, bytes accessed) from compiled.cost_analysis()."""
    if not cost_analysis:
        return 0.0, 0.0
    ca = cost_analysis
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))
