"""Trip-count-aware cost extraction from compiled (scheduled) HLO text.

XLA's compiled.cost_analysis() counts a while-loop body ONCE, so every
scan-over-layers model under-reports FLOPs/bytes/collectives by ~n_layers
(verified: an 8-step lax.scan reports 1/8 the unrolled flops). This parser
rebuilds the costs from the HLO itself:

* per computation, a symbol table name -> shape (from parameter decls and
  instruction results) supplies operand shapes (scheduled HLO does not
  print operand types inline);
* while-loops contribute body+condition costs x trip count (the loop-bound
  constant in the condition computation — jax scans lower to a 0..L LT
  compare);
* flops: dot = 2 * out_elems * contracted_elems; convolution = 2 * out *
  kernel_elems;
* traffic_bytes: result + operand bytes of non-trivial instructions (the
  HBM-traffic proxy cost_analysis uses per fusion);
* collectives: result bytes and ring-model wire bytes per device.

tests/test_hlo_costs.py validates against XLA's own numbers on unscanned
graphs and against trip-count scaling on (nested) scans.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.distributed.hlo_analysis import _DTYPE_BYTES, COLLECTIVE_OPS

_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((?P<params>.*)\)\s*->")
_INSTR_HEAD = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPNAME = re.compile(r"\s*([\w\-]+)\s*\(")
_PARAM_DECL = re.compile(r"([\w.\-]+)\s*:\s*([a-z][a-z0-9]*\[[0-9,]*\]|\([^)]*\))")
_WHILE_ATTRS = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_NAME = re.compile(r"%?([\w.\-]+)")
_SIGIL_NAME = re.compile(r"%([\w.\-]+)\s*$")
_SHAPE_PREFIX = re.compile(
    r"^\(?[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?\s*")


def _split_top_level(s: str) -> List[str]:
    """Split on commas not nested in ()/[]/{} (tuple-typed operands)."""
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(s[start:i])
            start = i + 1
    parts.append(s[start:])
    return parts


def operand_names(operands: str) -> List[str]:
    """Operand instruction names, robust to both HLO operand styles:
    bare (``dot(%a, %b)``) and inline-typed
    (``dot(f32[32,64]{1,0} %a, f32[64,64]{1,0} %b)``) — newer jaxlib
    prints the latter, where a naive identifier regex grabs ``f32``."""
    names = []
    for seg in _split_top_level(operands):
        seg = seg.strip()
        if not seg:
            continue
        m = _SIGIL_NAME.search(seg)
        if m:                      # `%name` sigil: unambiguous
            names.append(m.group(1))
            continue
        seg = _SHAPE_PREFIX.sub("", seg)     # drop a leading shape, if any
        m = _OPERAND_NAME.match(seg)
        if m:
            names.append(m.group(1))
    return names


def _balanced(s: str, start: int = 0):
    """Span of the balanced-paren group starting at s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return start, i
    return start, len(s) - 1


def parse_instr(line: str):
    """-> (name, result_shape, op, operands, attrs) or None. Handles nested
    tuple result shapes (scan carries) via balanced-paren scanning."""
    hm = _INSTR_HEAD.match(line)
    if not hm:
        return None
    name = hm.group(1)
    rest = line[hm.end():]
    if rest.startswith("("):
        a, b = _balanced(rest, 0)
        shape, rest2 = rest[a:b + 1], rest[b + 1:]
    else:
        sm = re.match(r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?", rest)
        if not sm:
            return None
        shape, rest2 = sm.group(0), rest[sm.end():]
    om = _OPNAME.match(rest2)
    if not om:
        return None
    op = om.group(1)
    a, b = _balanced(rest2, rest2.index("(", om.start(1)))
    operands = rest2[a + 1:b]
    attrs = rest2[b + 1:]
    return name, shape, op, operands, attrs

TRIVIAL_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
               "constant", "iota", "after-all", "copy-start", "copy-done",
               "while", "conditional", "call", "partition-id", "replica-id"}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = byts = 0
    for m in _SHAPE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = int(np.prod([int(d) for d in dims.split(",")])) if dims else 1
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    symbols: Dict[str, str]      # instruction/param name -> shape string


def split_computations(hlo: str) -> Tuple[Dict[str, "Computation"], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{"):
                m = _COMP_HEAD.match(line[:-1].strip())
                if m:
                    cur = Computation(m.group(2), [], {})
                    if m.group(1):
                        entry = m.group(2)
                    for pm in _PARAM_DECL.finditer(m.group("params") or ""):
                        cur.symbols[pm.group(1)] = pm.group(2)
        else:
            if line == "}":
                comps[cur.name] = cur
                cur = None
            elif line:
                cur.lines.append(line)
                pi = parse_instr(line)
                if pi:
                    cur.symbols[pi[0]] = pi[1]
    return comps, entry


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_result_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCosts", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        self.collective_result_bytes += other.collective_result_bytes * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult


def _trip_count(cond: Optional[Computation]) -> int:
    if cond is None:
        return 1
    consts = [int(m.group(1)) for line in cond.lines
              for m in _CONST_INT.finditer(line)]
    return max(consts) if consts else 1


def analyse_hlo(hlo: str, n_devices: int = 1) -> HloCosts:
    comps, entry = split_computations(hlo)
    if not entry:
        return HloCosts()
    memo: Dict[str, HloCosts] = {}

    def operand_bytes(comp: Computation, operands: str) -> int:
        total = 0
        for nm in operand_names(operands):
            shape = comp.symbols.get(nm)
            if shape:
                total += _shape_elems_bytes(shape)[1]
        return total

    _fusion_access_memo: Dict[str, tuple] = {}

    def fusion_param_access(name: str) -> tuple:
        """(per-parameter accessed bytes, result_bytes_override | None).

        * a param consumed only through dynamic-slice/gather counts its
          slice bytes (the layer-stack read of scan-over-layers);
        * a param consumed (possibly through dtype converts) as the BUFFER
          operand of a dynamic-update-slice whose shape matches the fusion
          result is an IN-PLACE update fusion — XLA:TPU aliases it, so the
          buffer read/write does not hit HBM: param access = 0 and the
          fusion result counts as 2x the update-slice bytes (§Perf P2: the
          scan-ys cache write was otherwise billed 59 full-cache passes);
        * dtype converts are transparent for this analysis (the TPU target
          computes bf16 natively; CPU float-normalization inserts them).
        """
        if name in _fusion_access_memo:
            return _fusion_access_memo[name]
        comp = comps.get(name)
        out: Dict[int, float] = {}
        if comp is None:
            return out, None
        param_idx: Dict[str, int] = {}
        full_bytes: Dict[str, int] = {}
        sliced: Dict[str, float] = {}
        used_whole: Dict[str, bool] = {}
        # alias: names reachable from a param via convert/bitcast/copy only
        alias: Dict[str, str] = {}
        root_shape = None
        dus_inplace: Dict[str, float] = {}      # param name -> update bytes
        for line in comp.lines:
            pi = parse_instr(line)
            if not pi:
                continue
            iname, shape, op, operands, _ = pi
            if line.startswith("ROOT"):
                root_shape = shape
            if op == "parameter":
                m = re.search(r"parameter\((\d+)\)", line)
                if m:
                    param_idx[iname] = int(m.group(1))
                    full_bytes[iname] = _shape_elems_bytes(shape)[1]
                continue
            names = operand_names(operands)
            src = [alias.get(nm, nm) for nm in names]
            if op in ("convert", "bitcast", "copy", "reshape") and src:
                if src[0] in param_idx or src[0] in alias.values():
                    alias[iname] = src[0]
                continue
            for pos, nm in enumerate(src):
                if nm not in param_idx:
                    continue
                if op in ("dynamic-slice", "gather") and pos == 0:
                    sliced[nm] = sliced.get(nm, 0.0) + \
                        _shape_elems_bytes(shape)[1]
                elif op == "dynamic-update-slice" and pos == 0:
                    upd_shape = comp.symbols.get(names[1], "") \
                        if len(names) > 1 else ""
                    dus_inplace[nm] = 2.0 * _shape_elems_bytes(upd_shape)[1]
                    # the DUS result aliases the param buffer
                    alias[iname] = nm
                else:
                    used_whole[nm] = True
        # pure dtype-conversion fusion (only convert/bitcast/copy/reshape):
        # a CPU float-normalization artifact — free on the bf16-native TPU
        # target
        pure_convert = all(
            (parse_instr(l) or (None,) * 5)[2] in
            ("parameter", "convert", "bitcast", "copy", "reshape", None)
            for l in comp.lines)
        result_override = None
        if pure_convert:
            for nm, idx in param_idx.items():
                out[idx] = 0.0
            _fusion_access_memo[name] = (out, 0.0)
            return out, 0.0
        for nm, idx in param_idx.items():
            if nm in dus_inplace and not used_whole.get(nm):
                out[idx] = 0.0
                result_override = dus_inplace[nm]
            elif used_whole.get(nm) or nm not in sliced:
                out[idx] = float(full_bytes.get(nm, 0))
            else:
                out[idx] = min(float(full_bytes.get(nm, 0)), sliced[nm])
        _fusion_access_memo[name] = (out, result_override)
        return out, result_override

    def comp_cost(name: str, depth: int = 0) -> HloCosts:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        total = HloCosts()
        if comp is None or depth > 60:
            return total
        memo[name] = total            # guard cycles
        for line in comp.lines:
            pi = parse_instr(line)
            if not pi:
                continue
            _, res_shape, op, operands, attrs = pi
            res_elems, res_bytes = _shape_elems_bytes(res_shape)

            if op == "while":
                cond = _WHILE_ATTRS.search(attrs)
                body = _WHILE_BODY.search(attrs)
                trips = _trip_count(comps.get(cond.group(1)) if cond else None)
                if body:
                    total.add(comp_cost(body.group(1), depth + 1), trips)
                if cond:
                    total.add(comp_cost(cond.group(1), depth + 1), trips)
                continue
            if op in ("call", "conditional"):
                for cm in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                      attrs):
                    total.add(comp_cost(cm.group(1), depth + 1), 1.0)
                bm = re.search(r"branch_computations=\{([^}]*)\}", attrs)
                if bm:
                    for b in _OPERAND_NAME.finditer(bm.group(1)):
                        total.add(comp_cost(b.group(1), depth + 1), 1.0)
                continue
            if op in TRIVIAL_OPS:
                continue

            local = HloCosts()
            if op == "dot":
                names = operand_names(operands)
                lhs_shape = comp.symbols.get(names[0], "") if names else ""
                lhs_dims = _shape_dims(lhs_shape)
                cm = _CONTRACT.search(attrs)
                k = 1
                if cm and lhs_dims:
                    cdims = [int(d) for d in cm.group(1).split(",") if d]
                    k = int(np.prod([lhs_dims[c] for c in cdims])) if cdims else 1
                local.flops = 2.0 * res_elems * k
            elif op == "convolution":
                names = operand_names(operands)
                ker = comp.symbols.get(names[1], "") if len(names) > 1 else ""
                kelems, _ = _shape_elems_bytes(ker)
                local.flops = 2.0 * res_elems * max(1, kelems // max(
                    1, (_shape_dims(ker) or [1])[0]))
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in COLLECTIVE_OPS and not op.endswith("-done"):
                coll_bytes = res_bytes
                if op.endswith("-start"):
                    # async form: result is an (operand, dest) tuple — the
                    # payload is the dest buffer (last component)
                    shapes = list(_SHAPE.finditer(res_shape))
                    if len(shapes) >= 2:
                        coll_bytes = _shape_elems_bytes(
                            shapes[-1].group(0))[1]
                local.collective_result_bytes = coll_bytes
                frac = (n_devices - 1) / max(1, n_devices)
                if base_op == "all-reduce":
                    local.collective_wire_bytes = 2 * coll_bytes * frac
                elif base_op == "collective-permute":
                    local.collective_wire_bytes = coll_bytes
                else:
                    local.collective_wire_bytes = coll_bytes * frac
                local.collective_counts[base_op] = 1.0

            if op == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", attrs)
                access, res_override = fusion_param_access(cm.group(1)) \
                    if cm else ({}, None)
                names = operand_names(operands)
                tb = float(res_bytes) if res_override is None \
                    else float(res_override)
                for pos, nm in enumerate(names):
                    shape = comp.symbols.get(nm)
                    fb = _shape_elems_bytes(shape)[1] if shape else 0
                    tb += access.get(pos, float(fb))
                local.traffic_bytes = tb
                if cm:       # fused dots still do flops
                    local.flops += comp_cost(cm.group(1), depth + 1).flops
            elif op in ("dynamic-slice", "gather"):
                local.traffic_bytes = 2.0 * res_bytes     # slice in + out
            elif op == "dynamic-update-slice":
                # reads+writes the update region, not the whole buffer
                names = operand_names(operands)
                upd = comp.symbols.get(names[1], "") if len(names) > 1 else ""
                ub = _shape_elems_bytes(upd)[1]
                local.traffic_bytes = 2.0 * ub
            elif op.endswith("-done"):
                local.traffic_bytes = 0.0     # counted at -start
            else:
                local.traffic_bytes = res_bytes + operand_bytes(comp,
                                                                operands)
            total.add(local, 1.0)
        memo[name] = total
        return total

    return comp_cost(entry)
