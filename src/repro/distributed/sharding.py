"""Logical-axis -> mesh sharding rules (MaxText-style), with divisibility
fallback: a dim whose size does not divide the mapped mesh axes is
replicated instead (e.g. 40 attention heads on a 16-wide model axis — the
Qwen-32B family), and GSPMD handles the resulting re-layout. The fallback
keeps every assigned arch compiling on the fixed production mesh; the perf
cost shows up in the roofline's collective term (hillclimb material,
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import Param, is_param


# logical axis -> mesh axes (tuple => combined). "fsdp" resolves to the
# data axis (+ pod when present).
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("fsdp",),          # FSDP: params sharded over data(+pod)
    "mlp": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "layer": (),                 # scan dim: never sharded
}


def _fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes or ()


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def resolve_rules(mesh: Mesh, rules: Optional[dict] = None) -> dict:
    out = {}
    for logical, axes in (rules or DEFAULT_RULES).items():
        resolved = []
        for a in axes:
            if a == "fsdp":
                resolved.extend(_fsdp_axes(mesh))
            elif a in mesh.axis_names:
                resolved.append(a)
        out[logical] = tuple(resolved)
    return out


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh, rules: Optional[dict] = None,
             no_fsdp_with: Sequence[str] = ()) -> P:
    """Logical axes + dim sizes -> PartitionSpec with divisibility fallback.
    Each mesh axis is used at most once per spec (GSPMD requirement).

    no_fsdp_with: if the param carries any of these logical axes, its
    fsdp-mapped dims are replicated instead (hillclimb H2: expert weights
    sharded over `model` only — removes the per-microbatch all-gather of
    expert stacks over the data axis, EXPERIMENTS.md §Perf)."""
    rr = resolve_rules(mesh, rules)
    fsdp = set(_fsdp_axes(mesh))
    suppress_fsdp = any(a in no_fsdp_with for a in axes if a)
    used = set()
    entries = []
    for name, dim in zip(axes, shape):
        target = rr.get(name, ()) if name else ()
        if suppress_fsdp:
            target = tuple(a for a in target if a not in fsdp)
        target = tuple(a for a in target if a not in used)
        if target and dim % _axis_size(mesh, target) == 0:
            entries.append(target if len(target) > 1 else target[0])
            used.update(target)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(param_tree, mesh: Mesh, rules: Optional[dict] = None,
                    no_fsdp_with: Sequence[str] = ()):
    """Tree of Param(value, axes) -> tree of NamedSharding (same structure
    as split(param_tree)[0])."""
    def one(p: Param):
        return NamedSharding(mesh, spec_for(p.axes, p.value.shape, mesh,
                                            rules, no_fsdp_with))
    return jax.tree.map(one, param_tree, is_leaf=is_param)


def state_shardings(param_tree, mesh: Mesh, rules: Optional[dict] = None,
                    no_fsdp_with: Sequence[str] = ()):
    """AdamW state shardings: m/v inherit the param sharding; step scalar
    replicated."""
    ps = param_shardings(param_tree, mesh, rules, no_fsdp_with)
    return {"m": ps, "v": ps,
            "step": NamedSharding(mesh, P())}


def batch_sharding(mesh: Mesh) -> NamedSharding:
    """Token batches: leading batch dim over (pod, data)."""
    dp = _fsdp_axes(mesh)
    return NamedSharding(mesh, P(dp if len(dp) > 1 else (dp[0] if dp else None)))


def cache_spec(shape: Sequence[int], mesh: Mesh, seq_dim: int = 2,
               batch_dim: int = 1) -> P:
    """Decode-cache sharding: batch over (pod,data), SEQUENCE over model —
    the cache is a partitioned canonical store along the sequence axis
    (context-parallel serving), which is exactly the paper's multi-holder
    residency; GSPMD's distributed softmax over the sharded axis realizes
    the route+merge (DESIGN.md §2).

    Falls back per-dim on divisibility (e.g. batch=1 long_500k: batch
    replicated, sequence sharded)."""
    dp = _fsdp_axes(mesh)
    entries: list = [None] * len(shape)
    if dp and shape[batch_dim] % _axis_size(mesh, dp) == 0:
        entries[batch_dim] = dp if len(dp) > 1 else dp[0]
    if "model" in mesh.axis_names and shape[seq_dim] % mesh.shape["model"] == 0:
        entries[seq_dim] = "model"
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)
