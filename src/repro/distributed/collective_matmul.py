"""Pipelined all-gather matmul (collective matmul) — compute/comm overlap.

Y = X @ W with X row-sharded (m/P, d) and W column-sharded as P stacked
blocks (d, n/P): instead of all-gathering W then multiplying (a barrier),
each rank multiplies the W block it currently holds while ppermuting it to
the next rank — P steps, transfer hidden behind the matmul. This is the
standard Megatron-style TP overlap, here as a shard_map building block
(DESIGN.md §5 distributed-optimization tricks; used as a hillclimb lever
in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def allgather_matmul_overlapped(x_shard: jax.Array, w_block: jax.Array,
                                axis: str) -> jax.Array:
    """Inside shard_map over `axis` (size P):

    x_shard (m_local, d) — this rank's rows of X;
    w_block (d, n_block) — this rank's column block r of W.
    Returns y_local (m_local, P * n_block) = x_shard @ W (all columns).
    """
    p = compat.axis_size(axis)
    r = lax.axis_index(axis)
    n_block = w_block.shape[1]
    perm = [(j, (j + 1) % p) for j in range(p)]

    def body(i, carry):
        acc, blk = carry
        # rank r holds column block (r - i) mod p at step i
        src = (r - i) % p
        y = x_shard @ blk
        acc = lax.dynamic_update_slice(acc, y.astype(acc.dtype),
                                       (0, src * n_block))
        blk = lax.ppermute(blk, axis, perm)     # overlaps with next matmul
        return acc, blk

    acc0 = jnp.zeros((x_shard.shape[0], p * n_block), jnp.float32)
    # the zero init is device-invariant; mark it varying over the ring axis
    # so the fori_loop carry types match under shard_map
    acc0 = compat.pvary(acc0, (axis,))
    acc, _ = lax.fori_loop(0, p, body, (acc0, w_block))
    return acc


def allgather_matmul_barrier(x_shard: jax.Array, w_block: jax.Array,
                             axis: str) -> jax.Array:
    """Baseline: all-gather W fully, then one matmul (the barrier the
    overlapped form removes)."""
    w_all = lax.all_gather(w_block, axis, axis=1, tiled=True)  # (d, n)
    return (x_shard @ w_all).astype(jnp.float32)
