"""ShapeDtypeStruct stand-ins for every model input per (arch, shape) cell
(task spec: weak-type-correct, shardable, no device allocation) + the
matching sharding trees."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.distributed.sharding import _axis_size, _fsdp_axes
from repro.models import model as MD


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dp_entry(mesh: Mesh):
    dp = _fsdp_axes(mesh)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def train_batch_specs(cfg: MD.ModelConfig, shape: ShapeSpec):
    """Token batch ShapeDtypeStructs for a train/prefill shape."""
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        text = S - cfg.vlm_patches
        batch["tokens"] = _sds((B, text), jnp.int32)
        batch["targets"] = _sds((B, text), jnp.int32)
        batch["patch_embeds"] = _sds((B, cfg.vlm_patches, cfg.d_model),
                                     jnp.bfloat16)
    elif cfg.family == "audio":
        batch["tokens"] = _sds((B, S), jnp.int32)
        batch["targets"] = _sds((B, S), jnp.int32)
        batch["frame_embeds"] = _sds((B, cfg.enc_seq, cfg.d_model),
                                     jnp.bfloat16)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        batch["targets"] = _sds((B, S), jnp.int32)
    return batch


def train_batch_shardings(batch_specs, mesh: Mesh):
    dp = _dp_entry(mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, P(dp, *([None] * (len(s.shape) - 1)))),
        batch_specs)


# ---------------------------------------------------------------------------
# Decode state: abstract caches + shardings per family.
# ---------------------------------------------------------------------------

def decode_state_specs(cfg: MD.ModelConfig, shape: ShapeSpec):
    return MD.init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                abstract=True)


def _seq_axes(mesh: Mesh, batch: int, seq: int):
    """Sequence-dim sharding for caches: 'model', plus any dp axes the batch
    cannot use (long_500k batch=1 => the whole mesh shards the sequence —
    the paper's partitioned canonical store)."""
    dp = _fsdp_axes(mesh)
    batch_ok = dp and batch % _axis_size(mesh, dp) == 0
    axes = tuple() if batch_ok else dp
    if "model" in mesh.axis_names:
        axes = axes + ("model",)
    if axes and seq % _axis_size(mesh, axes) == 0:
        batch_entry = _dp_entry(mesh) if batch_ok else None
        return batch_entry, (axes if len(axes) > 1 else axes[0])
    return (_dp_entry(mesh) if batch_ok else None), None


def decode_state_shardings(cfg: MD.ModelConfig, shape: ShapeSpec, mesh: Mesh):
    B, S = shape.global_batch, shape.seq_len
    b_entry, s_entry = _seq_axes(mesh, B, S)

    def _entry_size(entry):
        if not entry:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        return _axis_size(mesh, axes)

    def kv_shard(spec):   # (L, B, S', Hkv, hd) — S' may be enc_seq (1500)
        se = s_entry if (s_entry and
                         spec.shape[2] % _entry_size(s_entry) == 0) else None
        return NamedSharding(mesh, P(None, b_entry, se))

    def mla_shard(spec):  # (L, B, S, d_qk)
        return NamedSharding(mesh, P(None, b_entry, s_entry))

    def ssm_h_shard(spec):   # (..., B, H, Phd, N)
        nd = len(spec.shape)
        lead = [None] * (nd - 4)
        h_entry = ("model" if "model" in mesh.axis_names
                   and spec.shape[-3] % mesh.shape["model"] == 0 else None)
        return NamedSharding(mesh, P(*lead, b_entry, h_entry, None, None))

    def conv_shard(spec):    # (..., B, K-1, C)
        nd = len(spec.shape)
        lead = [None] * (nd - 3)
        c_entry = ("model" if "model" in mesh.axis_names
                   and spec.shape[-1] % mesh.shape["model"] == 0 else None)
        return NamedSharding(mesh, P(*lead, b_entry, None, c_entry))

    def classify(spec):
        shp = spec.shape
        if cfg.attn_type == "mla" and len(shp) == 4 and shp[-1] == cfg.mla.d_qk:
            return mla_shard(spec)
        if cfg.ssm is not None and \
                shp[-1] == cfg.ssm.d_inner + 2 * cfg.ssm.d_state:
            return conv_shard(spec)               # mamba conv left-context
        if len(shp) >= 4 and cfg.ssm is not None \
                and shp[-1] == cfg.ssm.d_state \
                and shp[-2] == cfg.ssm.head_dim:
            return ssm_h_shard(spec)              # mamba recurrent state
        acfg = cfg.attn_cfg
        if len(shp) == 5 and shp[-1] == acfg.hd \
                and shp[-2] == acfg.n_kv_heads:   # gqa kv cache
            return kv_shard(spec)
        return NamedSharding(mesh, P())

    state = decode_state_specs(cfg, shape)
    return jax.tree.map(classify, state)


def decode_input_specs(cfg: MD.ModelConfig, shape: ShapeSpec):
    B = shape.global_batch
    return (_sds((B, 1), jnp.int32),           # token
            _sds((B, 1), jnp.int32),           # pos
            _sds((), jnp.int32))               # widx


def decode_input_shardings(mesh: Mesh, batch: int = 0):
    dp = _dp_entry(mesh)
    dp_axes = _fsdp_axes(mesh)
    if dp_axes and batch % _axis_size(mesh, dp_axes) != 0:
        dp = None                              # long_500k: batch=1 replicated
    return (NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P()))
