"""Serving launcher: a partitioned canonical c^KV store driven by the
ROUTE/FETCH/LOCAL predicate (the paper's artifact end-to-end), with a
pluggable execution backend (ISSUE 3):

    # plan + analytic timeline only (default)
    PYTHONPATH=src python -m repro.launch.serve --instances 8 --pods 2 \
        --chunks 16 --agents 12 --steps 5

    # plan AND execute on real c^KV arrays, verifying §3.3 exactness
    PYTHONPATH=src python -m repro.launch.serve --backend exec --verify

    # the §5.4 selection regime end-to-end (ISSUE 4): the distributed
    # indexer scores/selects per step, the backends scatter-attend the
    # masks, selection requests verify against the selection_k oracle
    PYTHONPATH=src python -m repro.launch.serve --selection \
        --selection-k 128 --backend exec --verify \
        --save-selection-trace /tmp/sel.json
    # ... and a recorded selection trace replays through the planner
    # (numpy-only: no jax needed to PRICE the regime from a trace)
    PYTHONPATH=src python -m repro.launch.serve \
        --selection-trace /tmp/sel.json --selection-k 128

    # replay a saved trace (the SAME trace drives both backends)
    PYTHONPATH=src python -m repro.launch.serve --save-trace /tmp/t.json
    PYTHONPATH=src python -m repro.launch.serve --trace /tmp/t.json \
        --backend exec

    # run on measured fabric constants (benchmarks/calibrate_fabric.py)
    PYTHONPATH=src python -m repro.launch.serve \
        --fabric-table benchmarks/results/fabric_table.json \
        --intra-fabric tpu_ici_fit --cross-fabric tpu_dcn_fit

The workload comes from repro.serving.workload (agentic sessions with
Zipf-popular working sets and session lifetimes), NOT an inline RNG loop:
session lifetimes are the FETCH amortisation horizon (§5.5 rule 2), so
the CLI path exercises fetch persistence and replica spawning like the
benchmarks do.
"""

import argparse

import numpy as np

from repro.core.constants import Fabric, register_fabrics
from repro.serving.engine import (EngineConfig, ServingEngine,
                                  transport_latencies)
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    materialize_trace, read_trace,
                                    register_corpus, save_trace)

# args whose values define the WORLD a trace was recorded against; a replay
# must reconstruct them from the trace's meta header, not trust the flags
TRACE_META_ARGS = ("instances", "pods", "chunks", "chunk_tokens",
                   "agents", "steps", "seed")
# a SELECTION trace additionally depends on the workload's selection knobs:
# k_selected flows into every selection dispatch's pricing (kb_wire, the
# predicate's k column) and selection_frac decides WHICH sessions select —
# replaying with different values would silently produce different StepStats
SELECTION_META_ARGS = TRACE_META_ARGS + ("selection_k", "selection_frac")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="predicate-driven serving engine (plan/execute/account)")
    ap.add_argument("--instances", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--chunk-tokens", type=int, default=2048)
    ap.add_argument("--agents", type=int, default=12,
                    help="concurrent agent sessions (fan-in N)")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--pool-tokens", type=int, default=10_000_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("analytic", "exec", "shard_map"),
                    default="analytic")
    ap.add_argument("--serial-exec", action="store_true",
                    help="shard_map backend: run dispatch groups through "
                         "the PR-7 serial staged_call chain instead of the "
                         "fused/overlapped path (A/B debug knob, ISSUE 8)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="steps in flight between submit and account "
                         "(ISSUE 10): 1 = lockstep plan/execute/account "
                         "(the kill switch), >= 2 plans step N+1 "
                         "speculatively while step N's device work runs")
    ap.add_argument("--trace", default="",
                    help="replay a save_trace() JSON instead of generating")
    ap.add_argument("--save-trace", default="",
                    help="write the generated trace as JSON and run it")
    ap.add_argument("--verify", action="store_true",
                    help="exec backend: check outputs against the "
                         "single-instance attention oracle (§3.3)")
    ap.add_argument("--fabric-table", default="",
                    help="JSON fabric table (calibrate_fabric output) to "
                         "register before building the engine")
    ap.add_argument("--intra-fabric", default="tpu_ici")
    ap.add_argument("--cross-fabric", default="tpu_dcn")
    # §5.4 selection regime (ISSUE 4)
    ap.add_argument("--selection", action="store_true",
                    help="run the distributed indexer service: score -> "
                         "select -> scatter-attend through the scheduler")
    ap.add_argument("--selection-k", type=int, default=2048,
                    help="per-request selection budget in tokens (the "
                         "workload's k_selected)")
    ap.add_argument("--selection-frac", type=float, default=0.1,
                    help="fraction of agent sessions in the selection "
                         "regime (workload generator)")
    ap.add_argument("--block-tokens", type=int, default=64,
                    help="NSA selection granularity (indexer block size)")
    ap.add_argument("--selection-trace", default="",
                    help="replay a recorded selection trace through the "
                         "planner (numpy-only) instead of live scoring")
    ap.add_argument("--save-selection-trace", default="",
                    help="with --selection: record the indexer's per-step "
                         "verdicts as JSON")
    # flight recorder (ISSUE 9)
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event / Perfetto JSON of the "
                         "run: engine wall spans + planned (and, under "
                         "--backend shard_map, measured) timeline track "
                         "groups per step. Load at https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", default="",
                    help="write the obs metrics registry snapshot "
                         "(counters/gauges/histograms) as JSON at exit")
    ap.add_argument("--drift-threshold", type=float, default=None,
                    help="enable the model-vs-measured drift monitor with "
                         "this |EWMA| envelope (the paper's §7 claim is "
                         "~0.07 on calibrated fabrics; forced host devices "
                         "need a very loose value). Exits non-zero when a "
                         "(primitive, fabric, stage) cell trips. Requires a "
                         "measuring backend (shard_map)")
    return ap


def build_obs(args):
    """The flight recorder, from the CLI flags: None when every obs flag
    is off (the engine then keeps its inert NULL_OBS and the planner hot
    path pays nothing)."""
    if not (args.trace_out or args.metrics_out
            or args.drift_threshold is not None):
        return None
    from repro.obs import DriftConfig, DriftMonitor, Obs, Tracer
    tracer = Tracer() if args.trace_out else None
    drift = (DriftMonitor(DriftConfig(threshold=args.drift_threshold))
             if args.drift_threshold is not None else None)
    return Obs(tracer=tracer, drift=drift)


def build_selector(args):
    """The engine's selection seam: live indexer (--selection), recorded
    trace (--selection-trace, numpy-only), or None (selection requests are
    priced but executed dense — the engine warns once and counts them)."""
    if args.selection:
        from repro.serving.selection import (IndexerService, SelectionConfig,
                                             ShardMapIndexerService)
        svc = (ShardMapIndexerService if args.backend == "shard_map"
               else IndexerService)
        return svc(SelectionConfig(block_tokens=args.block_tokens))
    if args.selection_trace:
        from repro.serving.selection import ReplaySelector
        return ReplaySelector(args.selection_trace)
    return None


def build_engine(args) -> ServingEngine:
    if args.fabric_table:
        register_fabrics(Fabric.load_table(args.fabric_table))
    if args.backend == "exec":
        from repro.serving.backends import JaxExecBackend
        backend = JaxExecBackend()
    elif args.backend == "shard_map":
        from repro.serving.backends import ShardMapExecBackend
        backend = ShardMapExecBackend(fused=not args.serial_exec)
    else:
        backend = None
    return ServingEngine(
        args.instances, pool_tokens=args.pool_tokens,
        cfg=EngineConfig(intra_pod_fabric=args.intra_fabric,
                         cross_pod_fabric=args.cross_fabric,
                         pipeline_depth=args.pipeline_depth),
        instances_per_pod=max(1, args.instances // args.pods),
        backend=backend, selector=build_selector(args),
        obs=build_obs(args))


def apply_trace_meta(args, meta: dict, keys=TRACE_META_ARGS,
                     source: str = "--trace") -> None:
    """A replayed trace's chunk ids, homes and seeds only mean anything in
    the world they were recorded against: override the world-defining args
    from the trace's meta header (flag mismatches would otherwise silently
    change every decision — or crash on unknown chunk ids)."""
    for key in keys:
        if key in meta and meta[key] != getattr(args, key):
            print(f"[serve] {source} meta overrides "
                  f"--{key.replace('_', '-')}"
                  f": {getattr(args, key)} -> {meta[key]}")
            setattr(args, key, meta[key])


def build_trace(args, eng: ServingEngine, replay=None):
    """The per-step request lists: the pre-parsed --trace replay if given,
    else generated by the agentic workload (sessions, lifetimes, Zipf
    corpus — §1, §6.3). Either way the corpus registers from the (possibly
    meta-overridden) geometry args."""
    wl = WorkloadConfig(n_steps=args.steps, agents=args.agents,
                        n_corpus_chunks=args.chunks,
                        chunk_tokens=args.chunk_tokens, seed=args.seed,
                        selection_frac=args.selection_frac,
                        k_selected=args.selection_k)
    cids = register_corpus(eng, wl)
    if replay is not None:
        return replay
    gen = agentic_trace(wl, eng, cids)
    if args.save_trace:
        meta = {key: getattr(args, key) for key in TRACE_META_ARGS}
        return save_trace(args.save_trace, gen, meta=meta)
    return materialize_trace(gen)


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    if args.verify and args.backend not in ("exec", "shard_map"):
        raise SystemExit("--verify checks exec outputs against the §3.3 "
                         "oracle: it requires --backend exec or shard_map")
    if args.trace and args.save_trace:
        raise SystemExit("--save-trace records a GENERATED trace; it cannot "
                         "be combined with --trace (replay)")
    if args.selection and args.selection_trace:
        raise SystemExit("--selection scores live; it cannot be combined "
                         "with --selection-trace (replay)")
    if args.save_selection_trace and not args.selection:
        raise SystemExit("--save-selection-trace records the live "
                         "indexer's verdicts: it requires --selection")
    replay = None
    if args.trace:
        meta, replay = read_trace(args.trace)
        apply_trace_meta(args, meta)
    if args.selection_trace:
        # the selection trace defines its world too — including the
        # selection knobs, which flow into pricing (bit-identical replay
        # requires the recorded k/frac, not whatever the flags say)
        from repro.serving.selection import load_selection_trace
        sel_meta, _ = load_selection_trace(args.selection_trace)
        apply_trace_meta(args, sel_meta, keys=SELECTION_META_ARGS,
                         source="--selection-trace")
    eng = build_engine(args)
    steps = build_trace(args, eng, replay)

    # reporting trails accounting: at --pipeline-depth >= 2 a scheduled
    # step may still be in flight when the loop moves on, so per-step
    # lines print from the accounted prefix of eng.stats, not from the
    # just-scheduled step (at depth 1 the cursor stays caught up and the
    # output is identical to the historical lockstep loop)
    reported = [0]

    def report_accounted():
        while reported[0] < len(eng.stats):
            s = eng.stats[reported[0]]
            reqs = steps[reported[0]]
            recs = eng.plans[reported[0]].records
            line = (f"[serve] step {s.step}: {len(recs)} dispatches "
                    f"{s.primitives}, {s.n_resident}/{s.n_pairs} resident, "
                    f"makespan {s.latency_s*1e6:.0f}us")
            if eng.selector is not None:
                line += f", {s.n_selected} selected pairs"
            if args.verify:
                from repro.serving.backends.jax_exec import max_oracle_err
                line += f", max|err| {max_oracle_err(eng, reqs, s.step):.2e}"
            print(line)
            report = eng.measured_reports[reported[0]]
            if report is not None:
                # the shard_map backend's measured-vs-analytic loop (§7)
                print("\n".join("[serve]   " + ln
                                for ln in report.summary().splitlines()))
            reported[0] += 1

    depth = max(1, args.pipeline_depth)
    for i, reqs in enumerate(steps):
        eng.schedule_step(reqs)
        if depth >= 2 and i + 1 < len(steps):
            eng.speculate_step(steps[i + 1])
        report_accounted()
    eng.flush()
    report_accounted()
    if depth > 1:
        print(f"[serve] pipeline: depth {depth}, planner overlap hidden "
              f"{eng.planner_overlap_s*1e3:.2f}ms, "
              f"{eng.misspeculation_replans} replans")

    if args.save_selection_trace:
        from repro.serving.selection import save_selection_trace
        save_selection_trace(args.save_selection_trace, eng.selector.log,
                             eng.selector.block_tokens, eng.selector.d_index,
                             meta={key: getattr(args, key)
                                   for key in SELECTION_META_ARGS})
        print(f"[serve] selection trace -> {args.save_selection_trace} "
              f"({len(eng.selector.log)} steps)")
    if eng.selector is not None:
        index_s = sum(s.stage_totals.get("index", 0.0) for s in eng.stats)
        mk = sum(s.latency_s for s in eng.stats)
        print(f"[serve] selection: selector={eng.selector.name}, "
              f"{sum(s.n_selected for s in eng.stats)} selected pairs, "
              f"indexer-stage share of makespan "
              f"{index_s / mk if mk else 0.0:.3f}")

    overview = eng.measured_overview()
    if overview is not None:
        print(f"[serve] exec: {overview}")
    lat = transport_latencies(eng.stats)
    n_route = sum(1 for r in eng.log if r.primitive == "route")
    print(f"[serve] backend={eng.backend.name}; total dispatches "
          f"{len(eng.log)}; route fraction "
          f"{n_route/max(1, len(eng.log)):.2f} (decode defaults to ROUTE, "
          f"§5.5); replicas spawned "
          f"{sum(s.replicas_spawned for s in eng.stats)}")
    if len(lat):
        print(f"[serve] p50 step latency {np.percentile(lat, 50)*1e6:.0f}us, "
              f"p99 {np.percentile(lat, 99)*1e6:.0f}us over {len(lat)} "
              "transporting steps")

    # -- flight recorder exports + drift verdict (ISSUE 9) -------------------
    obs = eng.obs
    if obs.enabled:
        if args.trace_out and obs.tracer is not None:
            doc = obs.tracer.export(args.trace_out)
            print(f"[serve] trace -> {args.trace_out} "
                  f"({len(doc['traceEvents'])} events, "
                  f"{obs.tracer.n_steps} steps)")
        if args.metrics_out and obs.metrics is not None:
            obs.metrics.to_json(args.metrics_out)
            snap = obs.metrics.snapshot()
            print(f"[serve] metrics -> {args.metrics_out} "
                  f"({len(snap['counters'])} counters, "
                  f"{len(snap['gauges'])} gauges, "
                  f"{len(snap['histograms'])} histograms)")
        if obs.drift is not None:
            for ln in obs.drift.summary_lines():
                print(f"[serve] {ln}")
            if obs.drift.n_reports == 0:
                print("[serve] drift: no measured reports — the monitor "
                      "needs --backend shard_map")
            tripped = obs.drift.tripped()
            if tripped:
                raise SystemExit(
                    f"[serve] drift monitor TRIPPED: {len(tripped)} "
                    f"cell(s) past |ewma| > "
                    f"{obs.drift.config.threshold:g} — the fabric table "
                    f"no longer tracks measured walls (recalibrate via "
                    f"benchmarks/calibrate_fabric.py)")
            print(f"[serve] drift: OK ({len(obs.drift.cells)} cells within "
                  f"|ewma| <= {obs.drift.config.threshold:g})")


if __name__ == "__main__":
    main()
