"""Serving launcher: a partitioned canonical c^KV store driven by the
ROUTE/FETCH/LOCAL predicate (the paper's artifact end-to-end).

    PYTHONPATH=src python -m repro.launch.serve --instances 8 --pods 2 \
        --chunks 16 --tenants 12 --steps 5
"""

import argparse

import numpy as np

from repro.serving.engine import EngineConfig, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=8)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--chunk-tokens", type=int, default=2048)
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--m-q", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.RandomState(args.seed)
    eng = ServingEngine(args.instances, pool_tokens=10_000_000,
                        instances_per_pod=args.instances // args.pods)
    ids = []
    for i in range(args.chunks):
        cid = f"chunk_{i:04d}"
        eng.register_chunk(cid, holder=i % args.instances,
                           length=args.chunk_tokens)
        ids.append(cid)

    for step in range(args.steps):
        reqs = [Request(req_id=t, home=rng.randint(args.instances),
                        chunk_ids=list(rng.choice(ids, 2, replace=False)),
                        m_q=args.m_q)
                for t in range(args.tenants)]
        recs = eng.schedule_step(reqs)
        kinds = {}
        for r in recs:
            kinds[r.primitive] = kinds.get(r.primitive, 0) + 1
        print(f"[serve] step {step}: {kinds}, makespan "
              f"{eng.step_latency(eng.step_idx)*1e6:.0f}us")
    n_route = sum(1 for r in eng.log if r.primitive == "route")
    print(f"[serve] total dispatches {len(eng.log)}; "
          f"route fraction {n_route/max(1,len(eng.log)):.2f} "
          f"(decode defaults to ROUTE, §5.5)")


if __name__ == "__main__":
    main()
