"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-v2-236b \
        --smoke --steps 50 [--seq 128 --batch 4 --ckpt-dir /tmp/ckpt]

--smoke runs the arch's reduced config end-to-end on this host (data
pipeline -> grad-accum step -> AdamW -> async checkpoints -> fault-
tolerant loop). Without --smoke it builds the FULL config's train step for
the production mesh and compiles it (the dry-run path) — on real TPU
slices this is where the real run would start.
"""

import argparse
import tempfile
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import SyntheticPipeline
from repro.models import model as MD
from repro.models.module import count_params, split
from repro.optim.adamw import AdamWConfig, adamw_init, cosine_schedule
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if not args.smoke:
        # full config: compile the production-mesh train step (dry-run)
        from repro.launch.dryrun import run_cell, RESULTS_DIR
        import pathlib
        rec = run_cell(args.arch, "train_4k", False,
                       pathlib.Path(RESULTS_DIR), force=True)
        raise SystemExit(0 if rec.get("ok") else 1)

    cfg = get_smoke_config(args.arch)
    params, _ = split(MD.init_model(cfg, jax.random.PRNGKey(0)))
    print(f"[train] {cfg.name}: {count_params(params)/1e6:.2f}M params")
    ocfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(
        cfg, ocfg, TrainConfig(n_micro=args.n_micro),
        cosine_schedule(args.lr, warmup=args.steps // 10 + 1,
                        total=args.steps)))
    pipe = SyntheticPipeline.for_model(cfg, args.seq, args.batch)
    ckpt = CheckpointManager(args.ckpt_dir or
                             tempfile.mkdtemp(prefix=f"{cfg.name}_"))
    t0 = time.time()
    params, opt_state, log = train_loop(
        step, params, opt_state, pipe, ckpt,
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   log_every=max(1, args.steps // 10)))
    losses = [e for e in log if "loss" in e]
    print(f"[train] {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]['loss']:.3f} -> {losses[-1]['loss']:.3f}; "
          f"checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
