import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (task spec): for every (arch x shape x mesh) cell,
jit(step).lower(**ShapeDtypeStructs).compile() must succeed on the
production meshes — (16,16)=(data,model) single-pod and (2,16,16)=
(pod,data,model) multi-pod — and we record memory_analysis, cost_analysis
and the HLO collective schedule for the roofline (EXPERIMENTS.md).

Usage:
    python -m repro.launch.dryrun --arch deepseek-v2-236b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import dataclasses
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ALIASES, ARCH_IDS, SHAPES, all_cells, get_config,
                           supported_shapes)
from repro.core import constants as C
from repro.distributed import policy as POL
from repro.distributed.hlo_analysis import flops_and_bytes, parse_collectives
from repro.distributed.hlo_costs import analyse_hlo
from repro.distributed.sharding import param_shardings, state_shardings
from repro.launch import input_specs as IS
from repro.launch.mesh import make_production_mesh
from repro.models import model as MD
from repro.models.module import split
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import TrainConfig, make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"

# per-arch grad-accumulation microbatches for train_4k (activation memory
# knob; see EXPERIMENTS.md §Perf for the tuning trail)
N_MICRO = {
    "nemotron_4_340b": 16,
    "deepseek_v2_236b": 4,
    "qwen3_moe_235b": 4,
    "qwen1_5_32b": 2,
    "qwen2_5_32b": 2,
    "qwen3_32b": 2,
}

OPT = AdamWConfig()
OPT_BF16 = dataclasses.replace(OPT, state_dtype=jnp.bfloat16)


def _arch_cfg(arch: str, shape_name: str) -> MD.ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.attn_type == "mla":
        # DSA-style selection at the V3.2/GLM-5.1 budget (paper §5.4)
        cfg = dataclasses.replace(cfg, selection_k=2048)
    return cfg


def _opt_cfg(arch: str) -> AdamWConfig:
    # bf16 optimizer states for the 340B config (memory posture, DESIGN §5)
    return OPT_BF16 if ALIASES.get(arch, arch) == "nemotron_4_340b" else OPT


def build_lowered(arch: str, shape_name: str, mesh, sp_residual: bool = True,
                  n_micro: int = 0, no_expert_fsdp: bool = False,
                  no_remat: bool = False):
    """Build and lower the step for one cell. Returns (lowered, meta).

    Hillclimb knobs (EXPERIMENTS.md §Perf): n_micro overrides the grad-
    accumulation depth; no_expert_fsdp shards expert stacks over `model`
    only (no per-microbatch all-gather of experts over `data`)."""
    cfg = _arch_cfg(arch, shape_name)
    if no_remat:
        # hillclimb B3: with SP residuals the per-layer boundary is small;
        # dropping remat removes the fwd-in-bwd recompute pass and with it
        # one full round of FSDP weight re-gathers
        cfg = dataclasses.replace(cfg, remat=False)
    shape = SHAPES[shape_name]
    params_abs = jax.eval_shape(
        functools.partial(MD.init_model, cfg), jax.random.PRNGKey(0))
    p_vals, _ = split(params_abs)
    no_fsdp = ("expert",) if no_expert_fsdp else ()
    # param_shardings replaces Param leaves with NamedSharding — same
    # container structure as the split value tree.
    p_shard = param_shardings(params_abs, mesh, no_fsdp_with=no_fsdp)

    policy = POL.sp_policy(mesh, seq_shard=sp_residual)

    if shape.kind == "train":
        tcfg = TrainConfig(n_micro=n_micro or
                           N_MICRO.get(ALIASES.get(arch, arch), 1))
        step = make_train_step(cfg, _opt_cfg(arch), tcfg,
                               param_shardings=p_shard)
        opt_abs = jax.eval_shape(
            functools.partial(adamw_init, cfg=_opt_cfg(arch)), p_vals)
        o_shard = state_shardings(params_abs, mesh, no_fsdp_with=no_fsdp)
        batch_abs = IS.train_batch_specs(cfg, shape)
        b_shard = IS.train_batch_shardings(batch_abs, mesh)
        with POL.use_policy(policy):
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_vals, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return MD.prefill(params, cfg, batch)
        batch_abs = IS.train_batch_specs(cfg, shape)
        b_shard = IS.train_batch_shardings(batch_abs, mesh)
        with POL.use_policy(policy):
            jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_vals, batch_abs)
    else:  # decode
        def serve_step(params, state, token, pos, widx):
            return MD.decode_step(params, cfg, state, token, pos, widx)
        state_abs = IS.decode_state_specs(cfg, shape)
        st_shard = IS.decode_state_shardings(cfg, shape, mesh)
        tok_abs = IS.decode_input_specs(cfg, shape)
        tok_shard = IS.decode_input_shardings(mesh, shape.global_batch)
        jitted = jax.jit(serve_step,
                         in_shardings=(p_shard, st_shard) + tok_shard,
                         donate_argnums=(1,))
        lowered = jitted.lower(p_vals, state_abs, *tok_abs)

    meta = {"arch": ALIASES.get(arch, arch), "shape": shape_name,
            "kind": shape.kind,
            "n_params": int(sum(np.prod(l.shape)
                                for l in jax.tree.leaves(p_vals)))}
    return lowered, meta


def analyse(lowered, compiled, mesh, meta) -> dict:
    n_dev = int(np.prod(list(mesh.shape.values())))
    out = dict(meta)
    out["n_devices"] = n_dev
    out["mesh"] = dict(mesh.shape)
    # --- memory ---
    try:
        ma = compiled.memory_analysis()
        out["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:                                   # noqa: BLE001
        out["memory_analysis"] = {"error": str(e)}
    # --- cost: XLA's own numbers (while-bodies counted ONCE — kept as a
    # diagnostic) + our trip-count-corrected HLO walk (the roofline input;
    # see distributed/hlo_costs.py) ---
    try:
        ca = compiled.cost_analysis()
        flops, bytes_acc = flops_and_bytes(ca)
        out["xla_flops_unscaled"] = flops
        out["xla_bytes_unscaled"] = bytes_acc
    except Exception as e:                                   # noqa: BLE001
        out["cost_error"] = str(e)
    try:
        txt = compiled.as_text()
        costs = analyse_hlo(txt, n_dev)
        out["hlo_flops"] = costs.flops
        out["hlo_bytes"] = costs.traffic_bytes
        out["collectives"] = {
            "counts": dict(costs.collective_counts),
            "result_bytes": costs.collective_result_bytes,
            "wire_bytes": costs.collective_wire_bytes}
        st = parse_collectives(txt, n_dev)      # static (per-text) counts
        out["collectives"]["static_counts"] = st.counts
    except Exception as e:                                   # noqa: BLE001
        out["hlo_flops"] = out["hlo_bytes"] = None
        out["collectives"] = {"error": str(e)}
    return out


def roofline_terms(rec: dict) -> dict:
    """The three roofline terms, seconds (task spec)."""
    flops, byts = rec.get("hlo_flops"), rec.get("hlo_bytes")
    wire = rec.get("collectives", {}).get("wire_bytes")
    terms = {}
    # cost_analysis is per-device under SPMD; the roofline divides global
    # quantities by chips — per-device numbers are already that quotient.
    terms["compute_s"] = flops / C.TPU_PEAK_FLOPS_BF16 if flops else None
    terms["memory_s"] = byts / C.TPU_HBM_BW if byts else None
    terms["collective_s"] = wire / C.TPU_ICI_BW if wire is not None else None
    vals = {k: v for k, v in terms.items() if v}
    terms["dominant"] = max(vals, key=vals.get) if vals else None
    return terms


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, force: bool = False,
             sp_residual: bool = True, tag: str = "",
             n_micro: int = 0, no_expert_fsdp: bool = False,
             no_remat: bool = False) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    name = f"{ALIASES.get(arch, arch)}__{shape_name}__{mesh_tag}{tag}"
    out_path = out_dir / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        lowered, meta = build_lowered(arch, shape_name, mesh, sp_residual,
                                      n_micro, no_expert_fsdp, no_remat)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec = analyse(lowered, compiled, mesh, meta)
        rec["ok"] = True
        rec["t_lower_s"] = round(t_lower, 2)
        rec["t_compile_s"] = round(t_compile, 2)
        rec["roofline"] = roofline_terms(rec)
    except Exception as e:                                   # noqa: BLE001
        rec = {"arch": ALIASES.get(arch, arch), "shape": shape_name,
               "mesh": mesh_tag, "ok": False, "error": str(e),
               "traceback": traceback.format_exc()[-4000:]}
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    status = "OK" if rec.get("ok") else "FAIL"
    print(f"[dryrun] {name}: {status} ({time.time()-t0:.1f}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel residuals (baseline)")
    ap.add_argument("--n-micro", type=int, default=0,
                    help="override grad-accumulation microbatches")
    ap.add_argument("--no-expert-fsdp", action="store_true",
                    help="shard expert stacks over model only (H2)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation remat (B3)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    cells = []
    if args.all:
        for a, s in all_cells():
            cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_fail = 0
    for a, s in cells:
        for mp in meshes:
            rec = run_cell(a, s, mp, out_dir, force=args.force,
                           sp_residual=not args.no_sp, tag=args.tag,
                           n_micro=args.n_micro,
                           no_expert_fsdp=args.no_expert_fsdp,
                           no_remat=args.no_remat)
            n_fail += 0 if rec.get("ok") else 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
