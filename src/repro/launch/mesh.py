"""Production meshes (task spec). A FUNCTION, not a module constant, so
importing never touches jax device state."""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Smaller meshes for tests/examples."""
    return compat.make_mesh(shape, axes)
