"""Partitioned canonical c^KV store (§1): provider-curated canonical chunks,
discoverable by canonical id across instances, forked copy-on-write by
concurrent sub-agents.

This is host-side control plane (replicated metadata); the cache bytes live
device-side, sharded over the instance axis. The serving engine consults the
store for residency, then the predicate for transport.

Since ISSUE 3 chunks can BEAR their arrays: the exec-mode backend
(repro.serving.backends.jax_exec) materializes each chunk's canonical c^KV
entries as a real (length, d_qk) jax array in `Chunk.data`, and the spliced
copies its FETCH path produces in `Chunk.replica_data`. The control plane
stays array-free by default (the analytic backend never touches these), so
the store is importable — and the planner runnable — without jax arrays in
play; `data` is typed loosely for exactly that reason.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import constants as C

# pool_tokens is denominated in KV-cache tokens; sidecar arrays (index
# keys, ISSUE 4) are charged in token-equivalents at the all-layer c^KV
# byte rate so eviction pressure sees them (ISSUE 6 satellite — they used
# to ride free)
KV_TOKEN_BYTES = C.B_KV_TOKEN_LAYER * C.V2_LITE_LAYERS


def _sidecar_tokens(array: Any) -> int:
    nbytes = getattr(array, "nbytes", None)
    if nbytes is None:
        return 0
    return -(-int(nbytes) // KV_TOKEN_BYTES)          # ceil


@dataclasses.dataclass
class Chunk:
    chunk_id: str
    holder: int                 # instance index owning the canonical copy
    offset: int                 # offset in the holder's pool
    length: int                 # tokens
    position_base: int          # canonical position of token 0
    refcount: int = 0           # concurrent readers (agent fan-in, §6.3)
    replicas: List[int] = dataclasses.field(default_factory=list)
    immutable: bool = True
    last_access: int = 0        # engine step of last read (replica LRU)
    # exec mode: canonical c^KV entries (length, d_qk) and the per-instance
    # spliced copies backing the replicas; None / absent in analytic mode
    data: Optional[Any] = None
    replica_data: Dict[int, Any] = dataclasses.field(default_factory=dict)
    # selection regime (ISSUE 4): the index SIDECAR — per-token index keys
    # (length, d_index) materialized alongside c^KV (core.selection
    # latent_index_keys), with the same replica/eviction lifecycle as the
    # cache bytes; a holder scores its RESIDENT keys, never remote ones
    index_keys: Optional[Any] = None
    replica_index_keys: Dict[int, Any] = dataclasses.field(
        default_factory=dict)
    # token-equivalents charged against the owning pool for the sidecars
    # above (0 while no keys are attached)
    sidecar_tokens: int = 0
    replica_sidecar_tokens: Dict[int, int] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class Fork:
    """A sub-agent's copy-on-write view: shared immutable prefix + private
    suffix (the agentic workload of §1)."""
    fork_id: str
    base_chunk: str
    suffix_holder: int
    suffix_offset: int
    suffix_length: int = 0


class ChunkStore:
    """Canonical-id -> residency map. Replicated on every host (control
    plane); mutations are tiny and idempotent, so replication is by
    broadcast of the op log in a real deployment (single-process here)."""

    def __init__(self, n_instances: int, pool_tokens: int):
        self.n_instances = n_instances
        self.pool_tokens = pool_tokens
        self._chunks: Dict[str, Chunk] = {}
        self._forks: Dict[str, Fork] = {}
        self._alloc = [0] * n_instances          # bump allocator per instance
        self._fork_ids = itertools.count()
        # bumped on every residency mutation (register / replicate / evict /
        # fail-over / re-home) so readers can cache columnar snapshots
        self.version = 0
        # copy-retirement listeners (ISSUE 8): callables (chunk_id, instance)
        # notified when a copy on `instance` stops being attendable — replica
        # LRU eviction or holder death — so device-side caches keyed on the
        # (chunk, instance) pair (the shard_map backend's committed-copy
        # pool) retire in lockstep with the control plane
        self._evict_listeners: List = []
        # lifetime replica-promotion count (drop_holder fail-over); read by
        # the obs metrics registry alongside the evict-listener counters
        self.promotions = 0

    # -- allocation ---------------------------------------------------------
    # _alloc[i] tracks tokens in use on instance i. Offsets handed out are
    # the in-use watermark at allocation time — with free() they are logical
    # labels, not byte addresses (this is the control plane; the device-side
    # pool does its own placement).

    def allocate(self, instance: int, length: int) -> int:
        off = self._alloc[instance]
        if off + length > self.pool_tokens:
            raise MemoryError(
                f"instance {instance} pool exhausted "
                f"({off}+{length} > {self.pool_tokens})")
        self._alloc[instance] = off + length
        return off

    def free(self, instance: int, length: int) -> None:
        self._alloc[instance] = max(0, self._alloc[instance] - length)

    def used(self, instance: int) -> int:
        return self._alloc[instance]

    def capacity_left(self, instance: int) -> int:
        return self.pool_tokens - self._alloc[instance]

    def sidecar_tokens_used(self, instance: int) -> int:
        """Token-equivalents the index-key sidecars occupy on `instance`
        (canonical charge on the holder, replica charges where they ride).
        O(n_chunks) — an observability read, not a hot-path accessor."""
        total = 0
        for c in self._chunks.values():
            if c.holder == instance:
                total += c.sidecar_tokens
            total += c.replica_sidecar_tokens.get(instance, 0)
        return total

    def register(self, chunk_id: str, holder: int, length: int,
                 position_base: int = 0, data: Optional[Any] = None) -> Chunk:
        if chunk_id in self._chunks:
            raise KeyError(f"chunk {chunk_id} already registered")
        off = self.allocate(holder, length)
        c = Chunk(chunk_id, holder, off, length, position_base)
        self._chunks[chunk_id] = c
        if data is not None:
            try:
                self.attach_data(chunk_id, data)   # same length validation
            except ValueError:
                del self._chunks[chunk_id]        # no half-registered chunk
                self.free(holder, length)
                raise
        self.version += 1
        return c

    # -- array payloads (exec mode; ISSUE 3) --------------------------------

    def attach_data(self, chunk_id: str, array: Any) -> Chunk:
        """Bind the canonical c^KV array to a registered chunk. The leading
        axis must match the registered token length — the control plane's
        accounting and the device bytes must agree."""
        c = self._chunks[chunk_id]
        n = getattr(array, "shape", (c.length,))[0]
        if n != c.length:
            raise ValueError(
                f"{chunk_id}: array has {n} tokens, registered {c.length}")
        c.data = array
        return c

    def set_replica_data(self, chunk_id: str, instance: int,
                         array: Any) -> None:
        """Record the spliced copy backing a replica. Ignored for the
        canonical holder (its `data` is authoritative) and for instances
        the control plane does not list as replicas."""
        c = self._chunks[chunk_id]
        if instance in c.replicas:
            c.replica_data[instance] = array

    def array_on(self, chunk_id: str, instance: int) -> Optional[Any]:
        """The array `instance` would attend locally: its spliced replica
        copy if one was produced, else the canonical array when the chunk
        is resident there. None when nothing is materialized (analytic
        mode, or a replica whose bytes never moved through exec)."""
        c = self._chunks[chunk_id]
        if instance in c.replica_data:
            return c.replica_data[instance]
        if instance == c.holder:
            return c.data
        return None

    # -- index sidecar (selection regime, ISSUE 4) --------------------------

    def attach_index_keys(self, chunk_id: str, array: Any) -> Chunk:
        """Bind the per-token index keys to a registered chunk — same
        leading-axis validation as attach_data (one key per cached token)."""
        c = self._chunks[chunk_id]
        n = getattr(array, "shape", (c.length,))[0]
        if n != c.length:
            raise ValueError(
                f"{chunk_id}: {n} index keys, registered {c.length} tokens")
        # the sidecar occupies real pool bytes on the holder — charge (and
        # re-charge on replacement) so eviction pressure sees it
        tokens = _sidecar_tokens(array)
        self.free(c.holder, c.sidecar_tokens)
        self.allocate(c.holder, tokens)
        c.sidecar_tokens = tokens
        c.index_keys = array
        return c

    def set_replica_index_keys(self, chunk_id: str, instance: int,
                               array: Any) -> None:
        """Record the index keys riding along a replica (the sidecar moves
        with the cache bytes). Same guards as set_replica_data."""
        c = self._chunks[chunk_id]
        if instance in c.replicas:
            tokens = _sidecar_tokens(array)
            self.free(instance, c.replica_sidecar_tokens.get(instance, 0))
            self.allocate(instance, tokens)
            c.replica_sidecar_tokens[instance] = tokens
            c.replica_index_keys[instance] = array

    def index_keys_on(self, chunk_id: str, instance: int) -> Optional[Any]:
        """The index keys `instance` would score locally — replica sidecar
        first, canonical keys on the holder, None when nothing is
        materialized there (mirrors array_on)."""
        c = self._chunks[chunk_id]
        if instance in c.replica_index_keys:
            return c.replica_index_keys[instance]
        if instance == c.holder:
            return c.index_keys
        return None

    # -- discovery (cross-instance, by canonical id — §1: reuse that a local
    #    prefix tree cannot capture) --------------------------------------

    def lookup(self, chunk_id: str) -> Chunk:
        return self._chunks[chunk_id]

    def holders_of(self, chunk_id: str) -> List[int]:
        c = self._chunks[chunk_id]
        return [c.holder] + list(c.replicas)

    def resident_on(self, chunk_id: str, instance: int) -> bool:
        return instance in self.holders_of(chunk_id)

    def touch(self, chunk_id: str, step: int) -> None:
        """Record a read at engine step `step` (drives replica LRU)."""
        c = self._chunks[chunk_id]
        if step > c.last_access:
            c.last_access = step

    # -- replication (the amortised FETCH beyond the N~8 elbow, §6.3) -------

    def add_replica(self, chunk_id: str, instance: int) -> Chunk:
        c = self._chunks[chunk_id]
        if instance not in c.replicas and instance != c.holder:
            self.allocate(instance, c.length)
            c.replicas.append(instance)
            self.version += 1
        return c

    def replicas_on(self, instance: int) -> List[str]:
        """Chunk ids with a NON-canonical copy on `instance` — the retirable
        set under pool pressure (canonical copies never retire)."""
        return [c.chunk_id for c in self._chunks.values()
                if instance in c.replicas]

    def add_evict_listener(self, fn) -> None:
        """Register fn(chunk_id, instance), called whenever a copy on
        `instance` is retired (LRU replica eviction, holder death).
        Idempotent per callable."""
        if fn not in self._evict_listeners:
            self._evict_listeners.append(fn)

    def _notify_evicted(self, chunk_id: str, instance: int) -> None:
        for fn in self._evict_listeners:
            fn(chunk_id, instance)

    def evict_replica(self, chunk_id: str, instance: int) -> None:
        """Retire a replica and return its tokens to the pool. The canonical
        copy is not evictable this way."""
        c = self._chunks[chunk_id]
        if instance == c.holder:
            raise ValueError(
                f"{chunk_id}: instance {instance} holds the canonical copy")
        if instance in c.replicas:
            c.replicas.remove(instance)
            c.replica_data.pop(instance, None)
            c.replica_index_keys.pop(instance, None)
            # cache bytes AND the index-key sidecar return to the pool
            self.free(instance,
                      c.length + c.replica_sidecar_tokens.pop(instance, 0))
            self.version += 1
            self._notify_evicted(chunk_id, instance)

    def drop_holder(self, instance: int) -> List[str]:
        """Fault handling: instance died. Chunks whose only copy lived there
        must be re-prefilled (LOCAL) or restored from checkpoint; chunks with
        replicas promote one. Returns orphaned ids."""
        orphaned = []
        for c in self._chunks.values():
            if instance in c.replicas or c.holder == instance:
                # whichever copy lived on the dead instance is gone
                self._notify_evicted(c.chunk_id, instance)
            if c.holder == instance:
                if c.replicas:
                    c.holder = c.replicas.pop(0)
                    self.promotions += 1
                    # the promoted replica's spliced copy becomes canonical
                    # (the dead instance's array is unreachable) — index
                    # sidecar promotes with it, and its token charge stays
                    # on the promoted instance as the canonical charge
                    if c.holder in c.replica_data:
                        c.data = c.replica_data.pop(c.holder)
                    if c.holder in c.replica_index_keys:
                        c.index_keys = c.replica_index_keys.pop(c.holder)
                    c.sidecar_tokens = c.replica_sidecar_tokens.pop(
                        c.holder, 0)
                else:
                    orphaned.append(c.chunk_id)
        for f in self._forks.values():
            if f.suffix_holder == instance:
                orphaned.append(f.fork_id)
        self.version += 1
        return orphaned

    def rehome(self, chunk_id: str, instance: int) -> bool:
        """Move the canonical copy of an orphaned chunk to `instance` if it
        has pool room (the engine's LOCAL re-prefill path). Returns False
        when the pool cannot take it."""
        c = self._chunks[chunk_id]
        if self.capacity_left(instance) < c.length:
            return False
        self.allocate(instance, c.length)
        c.holder = instance
        self.version += 1
        return True

    # -- columnar residency snapshot (ISSUE 6 array planner) ----------------

    def residency_columns(self):
        """One columnar pass over the residency map: chunk ids in insertion
        order, their lengths, and a (n_chunks, 1 + max_replicas) holder
        matrix in [canonical] + replicas order, -1 padded. Consumers key
        their caches on `version`."""
        ids = tuple(self._chunks)
        chunks = [self._chunks[cid] for cid in ids]
        n = len(ids)
        width = 1 + max((len(c.replicas) for c in chunks), default=0)
        holders = np.full((n, width), -1, dtype=np.int64)
        length = np.zeros(n, dtype=np.int64)
        for i, c in enumerate(chunks):
            holders[i, 0] = c.holder
            if c.replicas:
                holders[i, 1:1 + len(c.replicas)] = c.replicas
            length[i] = c.length
        return ids, length, holders, chunks

    def residency_snapshot(self):
        """Canonical, order-independent view of where every chunk lives:
        ``{chunk_id: (holder, sorted replica tuple, length)}``. Bit-
        identity tests (pipelined vs lockstep, ISSUE 10) compare two
        engines' snapshots after identical workloads."""
        return {cid: (c.holder, tuple(sorted(c.replicas)), c.length)
                for cid, c in self._chunks.items()}

    # -- agentic CoW forks (§1, §6.3) ---------------------------------------

    def fork(self, chunk_id: str, agent_instance: int) -> Fork:
        c = self._chunks[chunk_id]
        c.refcount += 1
        f = Fork(f"fork{next(self._fork_ids)}", chunk_id, agent_instance,
                 self._alloc[agent_instance])
        self._forks[f.fork_id] = f
        return f

    def append_suffix(self, fork_id: str, n_tokens: int) -> Fork:
        f = self._forks[fork_id]
        self.allocate(f.suffix_holder, n_tokens)
        f.suffix_length += n_tokens
        return f

    def release(self, fork_id: str):
        f = self._forks.pop(fork_id)
        self._chunks[f.base_chunk].refcount -= 1

    def fan_in(self, chunk_id: str) -> int:
        """Concurrent readers of a chunk — the N of the §6.3 elbow."""
        return self._chunks[chunk_id].refcount
