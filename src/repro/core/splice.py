"""FETCH — move-the-cache: bulk pull + delta-rotation splice (§2.2, §7).

The splice re-homes a contiguous chunk cached at canonical offset p0 to the
requester's offset p0 + delta: a *purely positional* rotation of the
64-wide decoupled-RoPE band of every entry (the latent 512 columns are
position-invariant — that is what lets a chunk be reused across sessions at
all). The rotation angle per entry depends only on delta, not on the entry's
own position, which is why the splice is flat in chunk size (§7).

Under sparse *selection* the chosen entries are attended at their canonical
positions, so no rotation is admissible: applying it anyway diverges 25-56%
from the reference (§3.3) — tests/test_fetch_splice.py reproduces this.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.mla import MLAConfig


def splice_delta_rotate(ckv_chunk: jax.Array, delta, cfg: MLAConfig,
                        rotate_fn=None) -> jax.Array:
    """Re-home a fetched chunk: rotate the rope band by delta positions.

    ckv_chunk (..., S, d_qk) -> same shape. rotate_fn overrides the inner op
    (e.g. the Pallas delta_rotate kernel)."""
    d_c = cfg.kv_lora_rank
    latent, band = ckv_chunk[..., :d_c], ckv_chunk[..., d_c:]
    if rotate_fn is not None:
        band = rotate_fn(band, delta)
    else:
        band = L.delta_rotate(band, delta, cfg.qk_rope_head_dim,
                              cfg.rope_theta)
    return jnp.concatenate([latent, band], axis=-1)


def fetch_chunk(local_pool: jax.Array, remote_ckv: jax.Array, delta,
                dst_offset: int, cfg: MLAConfig, holder: int, requester: int,
                axis: str = "instance", rotate_fn=None) -> jax.Array:
    """The full FETCH primitive inside shard_map: pull the chunk across the
    instance axis (one bulk ppermute — coalesced, sees link peak §8), apply
    the delta-rotation splice, scatter into the requester's pool.

    delta == 0 (true-prefix re-home, §6.3) elides the rotation — pass
    delta=None to express that statically."""
    pulled = lax.ppermute(remote_ckv, axis, [(holder, requester)])
    if delta is not None:
        pulled = splice_delta_rotate(pulled, delta, cfg, rotate_fn)
    return lax.dynamic_update_slice_in_dim(local_pool, pulled, dst_offset,
                                           axis=local_pool.ndim - 2)


def fetch_scattered_gather(local_pool: jax.Array, remote_ckv: jax.Array,
                           indices: jax.Array, dst_offset: int,
                           cfg: MLAConfig, holder: int, requester: int,
                           axis: str = "instance") -> jax.Array:
    """The selection-regime FETCH (§5.4): gather k scattered entries from the
    holder and pull them. NO splice — the entries stay at canonical positions
    (the requester must carry their position metadata). The gather defeats
    bulk coalescing: per-entry indexing on the holder side, one transfer per
    holder — the cost shape Fig 4a measures."""
    gathered = jnp.take(remote_ckv, indices, axis=0)
    pulled = lax.ppermute(gathered, axis, [(holder, requester)])
    return lax.dynamic_update_slice_in_dim(local_pool, pulled, dst_offset,
                                           axis=local_pool.ndim - 2)
