"""Sparse selection — the indexer regime (§5.4): DSA/NSA-style top-k.

A lightweight indexer scores every cached entry per query and keeps the
top-k. On TPU we select at *block* granularity (64-token blocks, NSA-style):
MXU/VMEM want block gathers, not row gathers — this is the DESIGN.md §6
hardware adaptation of the token-level Lightning Indexer. Both granularities
are provided; the block form is what kernels/sparse_select consumes.

ROUTE under selection is "the indexer's choice made distributed" (§5.4): the
selected set is scattered across holders; each holder attends its resident
subset of the selection in place (mask = selected & resident) and the
partials merge — no gather, no re-rotation.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.models.module import KeyGen, param


@dataclasses.dataclass(frozen=True)
class IndexerConfig:
    d_model: int = 2048
    d_index: int = 64          # lightweight score-projection width
    k_tokens: int = 2048       # selection budget (V3.2/GLM-5.1 default)
    block_tokens: int = C.NSA_BLOCK_TOKENS   # 64


def init_indexer(kg: KeyGen, cfg: IndexerConfig, dtype=jnp.bfloat16):
    return {
        "q_proj": param(kg(), (cfg.d_model, cfg.d_index), ("embed", None), dtype),
        "k_proj": param(kg(), (cfg.d_model, cfg.d_index), ("embed", None), dtype),
    }


def index_scores(p, x_q: jax.Array, keys_idx: jax.Array) -> jax.Array:
    """x_q (..., D) query hidden state; keys_idx (S, d_index) precomputed
    index keys for the cache. Returns (..., S) relevance scores."""
    q = x_q @ p["q_proj"]
    return jnp.einsum("...d,sd->...s", q.astype(jnp.float32),
                      keys_idx.astype(jnp.float32))


def index_keys(p, x_ctx: jax.Array) -> jax.Array:
    """Precompute per-token index keys at prefill (cached alongside c^KV)."""
    return x_ctx @ p["k_proj"]


def topk_tokens(scores: jax.Array, k: int) -> jax.Array:
    """(.., S) -> (.., k) selected token indices (DSA index_topk)."""
    _, idx = jax.lax.top_k(scores, k)
    return idx


def topk_blocks(scores: jax.Array, block_tokens: int, k_blocks: int):
    """Block-granular selection (NSA / TPU-native): aggregate token scores per
    64-token block, keep the top-k_blocks blocks. Returns block_idx
    (.., k_blocks) — k_blocks clamped to the block count.

    The tail is PADDED to the block boundary with -inf, so a partial last
    block competes on its real token scores (truncating it instead would make
    the score tail unselectable no matter how relevant — the S % block_tokens
    bug ISSUE 4 fixes). block_mask_to_tokens agrees on the padded length."""
    s = scores.shape[-1]
    n_blocks = -(-s // block_tokens)                    # ceil: tail counts
    pad = n_blocks * block_tokens - s
    if pad:
        scores = jnp.pad(scores,
                         [(0, 0)] * (scores.ndim - 1) + [(0, pad)],
                         constant_values=-jnp.inf)
    blocked = scores.reshape(scores.shape[:-1] + (n_blocks, block_tokens))
    block_scores = jnp.max(blocked, axis=-1)
    _, idx = jax.lax.top_k(block_scores, min(k_blocks, n_blocks))
    return idx


def selection_mask(idx_tokens: jax.Array, seq_len: int) -> jax.Array:
    """(.., k) indices -> (.., S) boolean mask (for masked partial attention:
    the holder attends selected & resident in place)."""
    onehot = jax.nn.one_hot(idx_tokens, seq_len, dtype=jnp.bool_)
    return jnp.any(onehot, axis=-2)


def block_mask_to_tokens(block_idx: jax.Array, block_tokens: int,
                         seq_len: int) -> jax.Array:
    """(.., kb) block indices -> (.., S) token mask. Counts blocks on the
    same padded length topk_blocks selects over (ceil, so the tail block is
    addressable), then truncates the mask back to seq_len."""
    n_blocks = -(-seq_len // block_tokens)
    onehot = jax.nn.one_hot(block_idx, n_blocks, dtype=jnp.bool_)
    blocks = jnp.any(onehot, axis=-2)                       # (.., n_blocks)
    return jnp.repeat(blocks, block_tokens, axis=-1)[..., :seq_len]


def latent_index_keys(ckv, d_index: int):
    """The parameter-free DSA index-key rule the decode path of
    models/model.py scores with: a token's index key IS the leading d_index
    latent columns of its c^KV entry (the position-invariant band — k_rope
    never enters the score, so keys need no re-rotation when a chunk moves).
    This is what the chunk store materializes as the index SIDECAR
    (Chunk.index_keys) next to the cache bytes; works on jax or numpy
    arrays (it is just a slice)."""
    return ckv[..., :d_index]


def block_scores(scores, block_tokens: int):
    """numpy mirror of topk_blocks' padded block aggregation, for the
    host-side serving indexer (repro.serving.selection): per-block max of
    token scores, tail padded to the boundary with -inf so a partial last
    block competes on its real scores. (.., S) -> (.., ceil(S/bt))."""
    import numpy as np
    s = np.asarray(scores)
    n = s.shape[-1]
    n_blocks = -(-n // block_tokens)
    pad = n_blocks * block_tokens - n
    if pad:
        s = np.concatenate(
            [s, np.full(s.shape[:-1] + (pad,), -np.inf, s.dtype)], axis=-1)
    return s.reshape(s.shape[:-1] + (n_blocks, block_tokens)).max(axis=-1)


def residency_split(idx_tokens: jax.Array, shard_bounds) -> list:
    """Partition selected canonical indices by holder: holder j owns
    [bounds[j], bounds[j+1]). Returns per-holder *local* masks — the
    distributed form of the selection (§5.4). Host-side helper for the
    serving engine (numpy semantics, small arrays)."""
    import numpy as np
    idx = np.asarray(idx_tokens)
    out = []
    for j in range(len(shard_bounds) - 1):
        lo, hi = shard_bounds[j], shard_bounds[j + 1]
        local = idx[(idx >= lo) & (idx < hi)] - lo
        mask = np.zeros(hi - lo, bool)
        mask[local] = True
        out.append(mask)
    return out
