"""Measured constants from the paper, plus TPU-target hardware constants.

Every number here is traceable to a specific table/figure/section of
"Move the Query, Not the Cache" (Ma et al., 2026); paper section given inline.
The cost model (cost_model.py) and predicate (predicate.py) consume these; the
benchmark suite validates the model against the paper's headline claims.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Mapping, Optional, Union

# ---------------------------------------------------------------------------
# MLA wire payload (§3.2), DeepSeek-V2(-Lite) geometry.
# ---------------------------------------------------------------------------

D_QK = 576          # absorbed query row width = kv_lora_rank(512) + rope(64)
D_V = 512           # latent value width (kv_lora_rank)
BF16 = 2            # bytes
FP32 = 4

Q_ROW_BYTES = D_QK * BF16                  # 1152 B per routed query row
P_ROW_BYTES = D_V * BF16 + 2 * FP32        # 1032 B per returned partial (o, m, l)
QP_BYTES = Q_ROW_BYTES + P_ROW_BYTES       # 2184 B round-trip per row

# Per-token, per-layer latent cache entry ("the same d_qk-wide object", §2.1).
B_KV_TOKEN_LAYER = D_QK * BF16             # 1152 B
V2_LITE_LAYERS = 27                        # DeepSeek-V2-Lite, §2.2
B_KV_TOKEN_ALL_LAYERS = B_KV_TOKEN_LAYER * V2_LITE_LAYERS   # ~31 KB/token


# ---------------------------------------------------------------------------
# Fabric table (Table 2 + §8 + TPU extension).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Fabric:
    """One row of the paper's fabric table: the affine model's two constants.

    t_probe_s  : payload-free signal round trip (sig_rt), seconds.
    bw_Bps     : effective single-dispatch bandwidth, bytes/second. The paper's
                 point (§8): this is a *dispatch* ceiling (~18-25 GB/s on every
                 GPU fabric), not the link peak.
    link_peak_Bps : the wire's true peak (multi-block benchmark / spec sheet);
                 what FETCH's coalesced bulk pull sees.
    t_launch_s : fixed kernel-turnaround beyond the probe (~9 us on IBGDA,
                 §4.3); explains the small-M_q residual.
    """
    name: str
    t_probe_s: float
    bw_Bps: float
    link_peak_Bps: float
    t_launch_s: float = 9e-6
    notes: str = ""

    # -- JSON fabric tables (ISSUE 3 satellite): engines and benchmarks can
    # run on MEASURED constants (benchmarks/calibrate_fabric.py writes
    # them) instead of the paper's Table 2 rows. -------------------------

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: Mapping, name: Optional[str] = None) -> "Fabric":
        """One fabric row from a JSON mapping; unknown keys are ignored so
        tables may carry fit diagnostics (mape, sweep size) alongside."""
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in obj.items() if k in fields}
        if name is not None:
            kw["name"] = name
        if "name" not in kw:
            raise ValueError("fabric row needs a name (key or argument)")
        return cls(**kw)

    @staticmethod
    def load_table(path: Union[str, pathlib.Path]) -> "Dict[str, Fabric]":
        """Read a {name: row} JSON fabric table (calibrate_fabric's output
        format) into Fabric objects keyed by name."""
        raw = json.loads(pathlib.Path(path).read_text())
        return {name: Fabric.from_json(row, name=name)
                for name, row in raw.items()}


# Paper-measured fabrics (Table 2; link peaks from §8).
FABRICS = {
    "h100_ibgda": Fabric("h100_ibgda", 16e-6, 25e9, 25e9,
                         notes="cross-node NDR-200, legacy driver (conservative)"),
    "h100_nvlink4": Fabric("h100_nvlink4", 1.2e-6, 21e9, 125e9,
                           notes="intra-node NV6 direct; per-GPU-pair peak ~125 GB/s"),
    "a100_nvlink3": Fabric("a100_nvlink3", 1.6e-6, 18e9, 235e9,
                           notes="NVSwitch"),
    "rtx6000_pcie5": Fabric("rtx6000_pcie5", 4.8e-6, 22e9, 41e9),
    "a40_pcie4": Fabric("a40_pcie4", 8.7e-6, 19e9, 19e9,
                        notes="same-socket; wire-bound (single-block rate = peak)"),
    # --- TPU extension rows (engineering estimates; DESIGN.md §2). The
    # predicate is invariant to the absolutes (paper §3.1 caveat). ---
    "tpu_ici": Fabric("tpu_ici", 1e-6, 45e9, 50e9, t_launch_s=0.0,
                      notes="v5e ICI one hop; compiler-scheduled, no launch gap"),
    "tpu_dcn": Fabric("tpu_dcn", 25e-6, 6e9, 25e9, t_launch_s=0.0,
                      notes="cross-pod data-center network, per host"),
}


# ---------------------------------------------------------------------------
# FETCH-side constants (§2.2, §7).
# ---------------------------------------------------------------------------

# Splice (position re-adaptation): flat ~3 ms, launch-bound. Affine fit of the
# paper's 2.77/2.78/2.91/3.06 ms at c_t = 55/1024/2048/4096:
SPLICE_BASE_S = 2.76e-3
SPLICE_PER_TOKEN_S = 7.1e-8      # ~10% growth over a 74x token range (§7)

# LOCAL re-prefill cost band (§5.1): c in [0.5, 1.5] us per token-layer.
PREFILL_PER_TOKEN_LAYER_S = (0.5e-6, 1.5e-6)
PREFILL_PER_TOKEN_LAYER_MID_S = 1.0e-6


# ---------------------------------------------------------------------------
# Host-overhead prototype constants (§5.3): TTFT ~= 3.5 ms + 12.5 us * M_q.
# Our in-graph TPU transport has no host path; keep as an optional term.
# ---------------------------------------------------------------------------

HOST_OVERHEAD_BASE_S = 3.5e-3
HOST_OVERHEAD_PER_ROW_S = 12.5e-6


# ---------------------------------------------------------------------------
# Holder-side constants (§6).
# ---------------------------------------------------------------------------

HOLDER_COMPUTE_ELBOW_N = 8        # batched partial ~free up to N~8 requesters
HOLDER_COMPUTE_DECODE_S = (15e-6, 37e-6)   # N <= 16, c_t = 2048
HOLDER_COMPUTE_SATURATED_S = 0.4e-3        # N = 256 upper bound
STAGING_STREAMS_ELBOW_K = 8       # K-stream staging pool elbow (§6.2)
MERGE_COST_S = 25e-6              # online-softmax merge upper bound (§4.2)

# Sparse-kernel premium over dense decode at matched k (§6.3).
SPARSE_PREMIUM = {512: 1.1, 1024: 1.75, 2048: 2.5}   # 1.1x .. 2-3x


# ---------------------------------------------------------------------------
# Congestion (§8): flat through K<=2 flows, rises at full subscription K=3.
# Multipliers on (probe, transfer) at K concurrent flows sharing one link.
# ---------------------------------------------------------------------------

CONGESTION_PROBE_MULT = {0: 1.0, 1: 1.0, 2: 1.0, 3: 39.5 / 14.5}
CONGESTION_RT_MULT_MQ1024 = {0: 1.0, 1: 1.0, 2: 1.0, 3: 250.0 / 114.0}


# ---------------------------------------------------------------------------
# Selection budgets (§5.4) — released-config index_topk values.
# ---------------------------------------------------------------------------

SELECTION_BUDGETS = {
    "deepseek_v32_dsa": 2048,
    "glm51_dsa": 2048,
    "deepseek_v4_pro": 1024,
    "deepseek_v4_flash": 512,
    "nsa": 1024,                 # ~16 blocks x 64 (+512 window)
}
NSA_BLOCK_TOKENS = 64


# ---------------------------------------------------------------------------
# TPU v5e roofline constants (task-given).
# ---------------------------------------------------------------------------

TPU_PEAK_FLOPS_BF16 = 197e12      # per chip
TPU_HBM_BW = 819e9                # bytes/s per chip
TPU_ICI_BW = 50e9                 # bytes/s per link
TPU_HBM_BYTES = 16 * 2**30        # v5e HBM capacity


def fabric(name: str) -> Fabric:
    try:
        return FABRICS[name]
    except KeyError:
        raise KeyError(f"unknown fabric {name!r}; known: {sorted(FABRICS)}")


def register_fabrics(table: "Dict[str, Fabric]",
                     overwrite: bool = True) -> None:
    """Install fabric rows (e.g. a measured Fabric.load_table) into the
    process-wide FABRICS registry so fabric() — and therefore EngineConfig
    fabric names — resolves them. With overwrite=False an existing paper
    row wins and the measured row is skipped."""
    for name, fab in table.items():
        if overwrite or name not in FABRICS:
            FABRICS[name] = fab
