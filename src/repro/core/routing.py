"""Cross-instance query routing — "move the query, not the cache" (§2, §3.3).

The paper's ROUTE primitive, adapted to TPU (DESIGN.md §2): instances are
shards along a mesh axis; the device-initiated put becomes a compiler-issued
collective inside shard_map. Three transport schedules are provided:

* fanout  : all_gather(q) -> per-holder partial -> all_to_all(partials) ->
            local M-way merge. The scattered-selection regime (§5.4); one
            barrier-free round, matches the paper's "ship the query once,
            merge M partials".
* pairwise: ppermute to a single holder and back — the §4 microbenchmark
            shape (one requester, one holder), minimal wire bytes.
* ring    : the query + merge accumulator circulate the ring; each hop
            overlaps the next hop's transfer with the current partial's
            compute (beyond-paper optimization; decode-form ring attention).

All three reproduce single-instance attention exactly (to float round-off):
the online-softmax merge is associative + commutative with an identity
(core/merge.py), so the result is invariant to how the cache is partitioned
— the paper's §3.3 exactness claim, which tests/test_routing.py verifies.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.merge import Partial, merge2, merge_stacked, merge_tree
from repro.models.mla import MLAConfig, absorbed_partial


# ---------------------------------------------------------------------------
# Single-process simulation (oracle semantics; used by unit tests and the
# serving engine's single-host mode).
# ---------------------------------------------------------------------------

def route_simulated(cfg: MLAConfig, q_abs: jax.Array,
                    shards: Sequence[jax.Array],
                    masks: Optional[Sequence[jax.Array]] = None) -> Partial:
    """Merge partial attention over an arbitrary partition of the cache.

    q_abs (..., H, d_qk); shards: list of (S_i, d_qk) resident subsets.
    Equivalent to attention over concat(shards) regardless of partitioning.
    """
    parts = []
    for i, shard in enumerate(shards):
        mask = None if masks is None else masks[i]
        parts.append(absorbed_partial(cfg, q_abs, shard, mask))
    return merge_tree(parts)


def route_batched(cfg: MLAConfig, queries: Sequence[jax.Array],
                  holder_shards: Sequence[Sequence[jax.Array]],
                  masks: Optional[Sequence[Sequence[jax.Array]]] = None
                  ) -> "list[Partial]":
    """Batched multi-holder routing, keyed by a dispatch plan: group g ships
    queries[g] (the plan's stacked requester rows, (m_q_total, H, d_qk)) to
    every holder in holder_shards[g] and returns the g-th merged Partial.

    This is the serving engine's exec-mode entry (ISSUE 3): one planned
    dispatch = one group = one holder-side batched partial per holder (the
    §6.3 "batched partial is ~free" kernel shape), merged requester-side.
    Semantically each group is route_simulated — so outputs are exact to
    round-off under any partitioning — but the per-group batching mirrors
    the per-(holder, chunk, fabric) dispatch batching the planner already
    did, instead of re-deriving per-request calls.
    """
    if len(queries) != len(holder_shards):
        raise ValueError(
            f"{len(queries)} query groups vs {len(holder_shards)} shard sets")
    return [route_simulated(cfg, q, shards,
                            None if masks is None else masks[g])
            for g, (q, shards) in enumerate(zip(queries, holder_shards))]


# ---------------------------------------------------------------------------
# shard_map collectives (production path; `axis` is the instance mesh axis).
# These run inside shard_map — callers supply per-shard arrays. The bodies
# are split at collective boundaries into named stage functions so the
# shard_map exec backend (ISSUE 7) can time each wire/compute stage
# individually; route_fanout / route_pairwise stay the fused compositions.
# ---------------------------------------------------------------------------

def check_route_shards(axis: str, q_abs: jax.Array, local_ckv: jax.Array,
                       local_valid: Optional[jax.Array] = None,
                       shard: Optional[int] = None) -> None:
    """Up-front shard-shape validation (ISSUE 7 satellite). A per-shard
    B / S_local disagreement used to surface only as an opaque XLA
    all_to_all / scan shape error deep in lowering; shapes are trace-time
    constants, so every expressible mismatch can be rejected here with the
    axis, the offending shard (when the caller knows it — per-shard input
    assembly does) and both shapes in the message."""
    where = f"mesh axis {axis!r}" + ("" if shard is None
                                     else f", shard {shard}")
    if q_abs.ndim < 2:
        raise ValueError(
            f"route shard on {where}: q_abs must be (..., B, H, d_qk), got "
            f"shape {tuple(q_abs.shape)}")
    if local_ckv.ndim != 2:
        raise ValueError(
            f"route shard on {where}: local_ckv must be (S_local, d_qk), "
            f"got shape {tuple(local_ckv.shape)}")
    if q_abs.shape[-1] != local_ckv.shape[-1]:
        raise ValueError(
            f"route shards disagree on {where}: q_abs has d_qk="
            f"{q_abs.shape[-1]} but local_ckv has d_qk={local_ckv.shape[-1]} "
            f"(shapes {tuple(q_abs.shape)} vs {tuple(local_ckv.shape)})")
    if local_valid is not None \
            and tuple(local_valid.shape) != (local_ckv.shape[0],):
        raise ValueError(
            f"route shards disagree on {where}: local_valid covers "
            f"S_local={local_valid.shape[0] if local_valid.ndim else '?'} "
            f"entries but local_ckv holds S_local={local_ckv.shape[0]} "
            f"(shapes {tuple(local_valid.shape)} vs "
            f"{tuple(local_ckv.shape)})")


def fanout_gather(q_abs: jax.Array, axis: str = "instance") -> jax.Array:
    """Fanout wire stage 1 (transfer): broadcast every instance's query
    rows — (B, H, d) per shard -> (M, B, H, d) everywhere."""
    return lax.all_gather(q_abs, axis)


def fanout_exchange(part: Partial, axis: str = "instance",
                    wire_dtype=None) -> Partial:
    """Fanout wire stage 2 (return): deliver partials back — slice m of
    the leading axis -> instance m. wire_dtype=bf16 gives the paper's
    1032-B partial row (o bf16, m/l f32 — §3.2); None keeps full precision
    (exactness tests)."""
    o_wire = part.o if wire_dtype is None else part.o.astype(wire_dtype)
    # barrier: keep the downstream f32 upcast from hoisting across the
    # collective (would double the partial's wire bytes — §Perf P1)
    o = lax.optimization_barrier(
        lax.all_to_all(o_wire, axis, split_axis=0, concat_axis=0))
    m = lax.all_to_all(part.m, axis, split_axis=0, concat_axis=0)
    l = lax.all_to_all(part.l, axis, split_axis=0, concat_axis=0)
    return Partial(o=o.astype(jnp.float32), m=m, l=l)


def route_fanout(cfg: MLAConfig, q_abs: jax.Array, local_ckv: jax.Array,
                 local_valid: jax.Array, axis: str = "instance",
                 partial_fn: Optional[Callable] = None,
                 wire_dtype=None) -> Partial:
    """Scattered multi-holder route (§5.4). Every instance is requester and
    holder at once (the agentic fan-in of §1).

    Per-shard shapes: q_abs (B, H, d_qk) — this instance's decode queries;
    local_ckv (S_local, d_qk) — resident canonical entries; local_valid
    (S_local,) bool — residency mask (scattered selection sets it per step).
    Returns this instance's fully-merged Partial (B, H, .).
    """
    check_route_shards(axis, q_abs, local_ckv, local_valid)
    qs = fanout_gather(q_abs, axis)                     # (M, B, H, d)
    fn = partial_fn or (lambda q, c, v: absorbed_partial(cfg, q, c, v))
    part = fn(qs, local_ckv, local_valid)               # (M, B, H, ...) on holder
    ex = fanout_exchange(part, axis, wire_dtype)
    return merge_stacked(ex.o, ex.m, ex.l)              # (B, H, ...)


def pairwise_ship(q_abs: jax.Array, holder: int, requester: int,
                  axis: str = "instance") -> jax.Array:
    """Pairwise wire stage 1 (transfer): the requester's query rows move to
    the holder — one ppermute = the §4 put."""
    # optimization_barrier pins the wire dtype against convert-hoisting
    # across the collective. NOTE (EXPERIMENTS.md §Perf P1): on the CPU
    # backend the permute STILL lowers as f32 — XLA:CPU float-normalizes
    # bf16 collectives (verified on a bare bf16 ppermute); on TPU bf16
    # collectives are native, so the 1152-B wire row holds there.
    return lax.optimization_barrier(
        lax.ppermute(q_abs, axis, [(requester, holder)]))


def pairwise_return(part: Partial, holder: int, requester: int,
                    axis: str = "instance", wire_dtype=None) -> Partial:
    """Pairwise wire stage 2 (return): the holder's partial travels back."""
    o_wire = part.o if wire_dtype is None else part.o.astype(wire_dtype)
    return Partial(
        o=lax.optimization_barrier(
            lax.ppermute(o_wire, axis,
                         [(holder, requester)])).astype(jnp.float32),
        m=lax.ppermute(part.m, axis, [(holder, requester)]),
        l=lax.ppermute(part.l, axis, [(holder, requester)]),
    )


def route_pairwise(cfg: MLAConfig, q_abs: jax.Array, local_ckv: jax.Array,
                   local_partial: Partial, holder: int, requester: int,
                   axis: str = "instance", wire_dtype=None,
                   local_valid: Optional[jax.Array] = None) -> Partial:
    """Single-holder route (§4 microbenchmark shape): requester ships q to
    holder (one ppermute = the put), holder computes the partial over its
    resident chunk (through local_valid when the selection regime chose a
    subset — §5.4), partial returns, requester merges with its own local
    partial (its private suffix)."""
    check_route_shards(axis, q_abs, local_ckv, local_valid)
    q_at_holder = pairwise_ship(q_abs, holder, requester, axis)
    part = absorbed_partial(cfg, q_at_holder, local_ckv, local_valid)
    back = pairwise_return(part, holder, requester, axis, wire_dtype)
    return merge2(local_partial, back)


def route_ring(cfg: MLAConfig, q_abs: jax.Array, local_ckv: jax.Array,
               local_valid: jax.Array, axis: str = "instance") -> Partial:
    """Ring-scheduled route: each hop ppermutes (q, acc) one step while the
    holder computes the visiting query's partial. After M hops the query is
    home with the full merge. Overlaps transfer with compute (beyond-paper;
    the TPU-native schedule for all-holders attention)."""
    check_route_shards(axis, q_abs, local_ckv, local_valid)
    m_size = compat.axis_size(axis)
    perm = [(i, (i + 1) % m_size) for i in range(m_size)]

    def hop(carry, _):
        q, acc = carry
        part = absorbed_partial(cfg, q, local_ckv, local_valid)
        acc = merge2(acc, part)
        q = lax.ppermute(q, axis, perm)
        acc = Partial(o=lax.ppermute(acc.o, axis, perm),
                      m=lax.ppermute(acc.m, axis, perm),
                      l=lax.ppermute(acc.l, axis, perm))
        return (q, acc), None

    ident = Partial.identity(q_abs.shape[:-1], cfg.kv_lora_rank)
    # the identity carry is device-invariant; mark it varying over the
    # instance axis so the scan carry types line up under shard_map
    ident = jax.tree.map(lambda x: compat.pvary(x, (axis,)), ident)
    (q, acc), _ = lax.scan(hop, (q_abs, ident), None, length=m_size)
    return acc


# ---------------------------------------------------------------------------
# TPLA rank-paired routing (§8 "Tensor parallelism"): the latent is
# column-partitioned across TP ranks; A.rank_r ships only its d_qk/N query
# slice to B.rank_r, the cross-rank reduction stays inside each instance.
# Per-rank inter-instance bytes fall 1/N.
# ---------------------------------------------------------------------------

def route_pairwise_tpla(cfg: MLAConfig, q_abs_slice: jax.Array,
                        local_ckv_slice: jax.Array, holder: int,
                        requester: int, instance_axis: str = "instance",
                        tp_axis: str = "tp") -> Partial:
    """Per-shard shapes: q_abs_slice (B, H, d_qk/N) — this rank's latent
    columns; local_ckv_slice (S, d_qk/N) — same columns of the holder's cache.

    Logits decompose as a sum over latent columns => per-rank partial logits
    psum over the *intra-instance* tp axis (NVLink-analogue: ICI), then each
    rank computes its own d_v/N output slice. Only the (1/N-sized) query and
    output slices cross the instance axis.
    """
    q_h = lax.ppermute(q_abs_slice, instance_axis, [(requester, holder)])
    # Partial logit contribution from this rank's columns.
    logits_r = jnp.einsum("bhc,sc->bhs", q_h.astype(jnp.float32),
                          local_ckv_slice.astype(jnp.float32)) * cfg.scale
    logits = lax.psum(logits_r, tp_axis)               # intra-instance
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    # Each rank holds d_c/N value columns; output slice stays rank-local.
    n_tp = compat.axis_size(tp_axis)
    v_cols = local_ckv_slice[:, :cfg.kv_lora_rank // n_tp].astype(jnp.float32)
    o_slice = jnp.einsum("bhs,sd->bhd", p / l[..., None], v_cols)
    back = Partial(
        o=lax.ppermute(o_slice, instance_axis, [(holder, requester)]),
        m=lax.ppermute(m, instance_axis, [(holder, requester)]),
        l=lax.ppermute(l, instance_axis, [(holder, requester)]),
    )
    return back
