"""The closed-form ROUTE / FETCH / LOCAL primitive-selection predicate (§5).

Per (chunk, request), evaluate the three costs of cost_model.py and take the
argmin — in microseconds of scheduler time, with no online profiling. The
serving engine (repro.serving) calls decide() per scheduled chunk access.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core.constants import Fabric


class Primitive(enum.Enum):
    ROUTE = "route"
    FETCH = "fetch"
    LOCAL = "local"


@dataclasses.dataclass(frozen=True)
class Request:
    """What the scheduler already tracks per (chunk, request) (§5.5)."""
    m_q: int                       # routed-query batch size
    c_t: int                       # chunk size in tokens
    fabric: Fabric                 # requester->holder fabric
    payload: cm.Payload = cm.MLA_PAYLOAD
    # Amortization: expected number of subsequent local decode steps on this
    # instance that would reuse a fetched copy (FETCH "only to amortise").
    expected_reuse_steps: int = 1
    # Selection regime (§5.4): if set, the chunk is a scattered top-k set
    # spread over n_holders; FETCH becomes a gather, splice is inadmissible.
    k_selected: Optional[int] = None
    n_holders: int = 1
    # True-prefix case (§6.3): chunk served at its cached offset => delta
    # rotation is the identity and the splice elides.
    position_delta: int = 1
    # Whether a route to the holder exists at all (disaggregated-prefill
    # corner: a model-agnostic byte store cannot run the partial, §6.3).
    holder_can_compute: bool = True
    # Host-overhead regime (§5.3): 0 for in-graph transport (TPU), or the
    # prototype's 3.5ms + 12.5us/row for validation against the paper.
    host_overhead: bool = False


@dataclasses.dataclass(frozen=True)
class Decision:
    primitive: Primitive
    t_route: float
    t_fetch: float
    t_local: float
    reason: str

    @property
    def costs(self):
        return {Primitive.ROUTE: self.t_route, Primitive.FETCH: self.t_fetch,
                Primitive.LOCAL: self.t_local}


def route_cost(req: Request) -> float:
    t_host = (C.HOST_OVERHEAD_BASE_S + C.HOST_OVERHEAD_PER_ROW_S * req.m_q
              if req.host_overhead else 0.0)
    if not req.holder_can_compute:
        return float("inf")
    if req.k_selected is not None and req.n_holders > 1:
        t = cm.t_route_fanout(req.fabric, req.m_q, req.n_holders, req.payload)
    else:
        t = cm.t_route(req.fabric, req.m_q, req.payload)
    return t + t_host


def fetch_cost(req: Request) -> float:
    if req.k_selected is not None:
        # Scattered gather; no splice (entries at canonical positions). A
        # fetched selection cannot amortise: it is re-chosen every step (§5.4).
        return cm.t_fetch_scattered(req.fabric, req.k_selected, req.n_holders,
                                    req.payload)
    contiguous = req.position_delta != 0
    t = cm.t_fetch(req.fabric, req.c_t, req.payload, contiguous=contiguous)
    # Amortise the one-time pull+splice over expected local reuse steps.
    return t / max(1, req.expected_reuse_steps)


def local_cost(req: Request,
               c_per_token_layer: float = C.PREFILL_PER_TOKEN_LAYER_MID_S) -> float:
    return cm.t_local(req.c_t, req.payload.n_layers, c_per_token_layer)


def decide(req: Request) -> Decision:
    """The closed-form predicate: argmin of the three instantiated costs."""
    tr, tf, tl = route_cost(req), fetch_cost(req), local_cost(req)
    best = min((tr, Primitive.ROUTE), (tf, Primitive.FETCH), (tl, Primitive.LOCAL),
               key=lambda x: x[0])[1]
    reason = _explain(req, tr, tf, tl, best)
    return Decision(best, tr, tf, tl, reason)


def _explain(req: Request, tr: float, tf: float, tl: float,
             best: Primitive) -> str:
    if best is Primitive.ROUTE:
        if req.k_selected is not None:
            return ("selection regime: route is the indexer's choice made "
                    "distributed; scattered gather would grow with holders")
        return (f"decode-shaped (M_q={req.m_q}): route RT "
                f"{tr*1e6:.0f}us vs fetch {tf*1e6:.0f}us / local {tl*1e6:.0f}us")
    if best is Primitive.FETCH:
        if req.expected_reuse_steps > 1:
            return (f"amortised over {req.expected_reuse_steps} local steps; "
                    "fetch pays the splice once")
        if req.m_q > req.c_t:
            return "query batch exceeds chunk: routing would ship more than the chunk"
        return "no cheaper primitive available"
    return f"small chunk (c_t={req.c_t}): re-prefill undercuts the flat splice"


# ---------------------------------------------------------------------------
# Serving rules of thumb (§5.5) as queryable helpers.
# ---------------------------------------------------------------------------

def fetch_local_crossover_ct(fabric: Fabric,
                             payload: cm.Payload = cm.MLA_PAYLOAD,
                             c_lo: float = C.PREFILL_PER_TOKEN_LAYER_S[0],
                             c_hi: float = C.PREFILL_PER_TOKEN_LAYER_S[1]) -> tuple:
    """Chunk size above which FETCH's flat splice undercuts LOCAL re-prefill.
    Paper: ~75-220 tokens for c in [0.5, 1.5] us/token-layer."""
    out = []
    for c in (c_hi, c_lo):      # c_hi gives the small end of the band
        # Solve c_t * L * c = t_fetch(c_t); pull term is tiny, iterate once.
        ct = np.array(1.0)
        for _ in range(50):
            ct = cm.t_fetch(fabric, float(ct), payload) / (payload.n_layers * c)
        out.append(float(ct))
    return tuple(out)


def holder_fanout_cap() -> int:
    """Per-holder concurrent-requester cap: both the copy- and compute-elbows
    sit near 8 (§6.2, §6.3)."""
    return C.HOLDER_COMPUTE_ELBOW_N


def replication_threshold(n_agents: int) -> bool:
    """Agentic fan-in (§6.3): beyond the elbow, added agents cost linearly and
    a second replica (an amortised FETCH) is warranted."""
    return n_agents > C.HOLDER_COMPUTE_ELBOW_N
