"""The closed-form ROUTE / FETCH / LOCAL primitive-selection predicate (§5).

Per (chunk, request), evaluate the three costs of cost_model.py and take the
argmin — in microseconds of scheduler time, with no online profiling. The
serving engine (repro.serving) calls decide() per scheduled chunk access.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core.constants import Fabric


class Primitive(enum.Enum):
    ROUTE = "route"
    FETCH = "fetch"
    LOCAL = "local"


@dataclasses.dataclass(frozen=True)
class Request:
    """What the scheduler already tracks per (chunk, request) (§5.5)."""
    m_q: int                       # routed-query batch size
    c_t: int                       # chunk size in tokens
    fabric: Fabric                 # requester->holder fabric
    payload: cm.Payload = cm.MLA_PAYLOAD
    # Amortization: expected number of subsequent local decode steps on this
    # instance that would reuse a fetched copy (FETCH "only to amortise").
    expected_reuse_steps: int = 1
    # Selection regime (§5.4): if set, the chunk is a scattered top-k set
    # spread over n_holders; FETCH becomes a gather, splice is inadmissible.
    k_selected: Optional[int] = None
    n_holders: int = 1
    # True-prefix case (§6.3): chunk served at its cached offset => delta
    # rotation is the identity and the splice elides.
    position_delta: int = 1
    # Whether a route to the holder exists at all (disaggregated-prefill
    # corner: a model-agnostic byte store cannot run the partial, §6.3).
    holder_can_compute: bool = True
    # Host-overhead regime (§5.3): 0 for in-graph transport (TPU), or the
    # prototype's 3.5ms + 12.5us/row for validation against the paper.
    host_overhead: bool = False


@dataclasses.dataclass(frozen=True)
class Decision:
    primitive: Primitive
    t_route: float
    t_fetch: float
    t_local: float
    reason: str

    @property
    def costs(self):
        return {Primitive.ROUTE: self.t_route, Primitive.FETCH: self.t_fetch,
                Primitive.LOCAL: self.t_local}


def route_cost(req: Request) -> float:
    t_host = (C.HOST_OVERHEAD_BASE_S + C.HOST_OVERHEAD_PER_ROW_S * req.m_q
              if req.host_overhead else 0.0)
    if not req.holder_can_compute:
        return float("inf")
    if req.k_selected is not None and req.n_holders > 1:
        t = cm.t_route_fanout(req.fabric, req.m_q, req.n_holders, req.payload)
    else:
        t = cm.t_route(req.fabric, req.m_q, req.payload)
    return t + t_host


def fetch_cost(req: Request) -> float:
    if req.k_selected is not None:
        # Scattered gather; no splice (entries at canonical positions). A
        # fetched selection cannot amortise: it is re-chosen every step (§5.4).
        return cm.t_fetch_scattered(req.fabric, req.k_selected, req.n_holders,
                                    req.payload)
    contiguous = req.position_delta != 0
    t = cm.t_fetch(req.fabric, req.c_t, req.payload, contiguous=contiguous)
    # Amortise the one-time pull+splice over expected local reuse steps.
    return t / max(1, req.expected_reuse_steps)


def local_cost(req: Request,
               c_per_token_layer: float = C.PREFILL_PER_TOKEN_LAYER_MID_S) -> float:
    return cm.t_local(req.c_t, req.payload.n_layers, c_per_token_layer)


def decide(req: Request) -> Decision:
    """The closed-form predicate: argmin of the three instantiated costs."""
    tr, tf, tl = route_cost(req), fetch_cost(req), local_cost(req)
    best = min((tr, Primitive.ROUTE), (tf, Primitive.FETCH), (tl, Primitive.LOCAL),
               key=lambda x: x[0])[1]
    reason = _explain(req, tr, tf, tl, best)
    return Decision(best, tr, tf, tl, reason)


def _explain(req: Request, tr: float, tf: float, tl: float,
             best: Primitive) -> str:
    if best is Primitive.ROUTE:
        if req.k_selected is not None:
            return ("selection regime: route is the indexer's choice made "
                    "distributed; scattered gather would grow with holders")
        return (f"decode-shaped (M_q={req.m_q}): route RT "
                f"{tr*1e6:.0f}us vs fetch {tf*1e6:.0f}us / local {tl*1e6:.0f}us")
    if best is Primitive.FETCH:
        if req.expected_reuse_steps > 1:
            return (f"amortised over {req.expected_reuse_steps} local steps; "
                    "fetch pays the splice once")
        if req.m_q > req.c_t:
            return "query batch exceeds chunk: routing would ship more than the chunk"
        return "no cheaper primitive available"
    return f"small chunk (c_t={req.c_t}): re-prefill undercuts the flat splice"


# ---------------------------------------------------------------------------
# Vectorized predicate: one argmin over arrays for a whole decode step.
# The serving engine prices every (request, chunk) pair of a step in a
# handful of numpy expressions instead of a Python loop per pair.
# decide_batch() matches decide() element-wise by construction
# (tests/test_predicate_batch.py fuzzes the agreement).
# ---------------------------------------------------------------------------

PRIMITIVE_BY_CODE = (Primitive.ROUTE, Primitive.FETCH, Primitive.LOCAL)
ROUTE_CODE, FETCH_CODE, LOCAL_CODE = 0, 1, 2


@dataclasses.dataclass
class RequestBatch:
    """Struct-of-arrays form of Request over one scheduling batch.

    fabric_idx indexes into `fabrics`; k_selected uses -1 for "no selection
    regime" (None in the scalar form). All arrays share one shape."""
    fabrics: cm.FabricArrays
    m_q: np.ndarray
    c_t: np.ndarray
    fabric_idx: np.ndarray
    expected_reuse_steps: np.ndarray
    k_selected: np.ndarray            # -1 => None
    n_holders: np.ndarray
    position_delta: np.ndarray
    holder_can_compute: np.ndarray    # bool
    host_overhead: np.ndarray         # bool
    payload: cm.Payload = cm.MLA_PAYLOAD

    def __len__(self) -> int:
        return int(np.asarray(self.m_q).shape[0])

    def take(self, idx: np.ndarray) -> "RequestBatch":
        """Row subset sharing the fabric table — the engine's incremental
        §8 repricing re-runs the predicate only on pairs whose link crossed
        the congestion knee (ISSUE 6)."""
        return RequestBatch(
            fabrics=self.fabrics, m_q=self.m_q[idx], c_t=self.c_t[idx],
            fabric_idx=self.fabric_idx[idx],
            expected_reuse_steps=self.expected_reuse_steps[idx],
            k_selected=self.k_selected[idx], n_holders=self.n_holders[idx],
            position_delta=self.position_delta[idx],
            holder_can_compute=self.holder_can_compute[idx],
            host_overhead=self.host_overhead[idx], payload=self.payload)

    @classmethod
    def from_requests(cls, reqs: "list[Request]") -> "RequestBatch":
        """Pack scalar Requests; fabrics are interned by object identity so
        fitted/ad-hoc Fabric rows work too."""
        uniq: list = []
        idx = []
        for r in reqs:
            try:
                idx.append(uniq.index(r.fabric))
            except ValueError:
                uniq.append(r.fabric)
                idx.append(len(uniq) - 1)
        payloads = {r.payload for r in reqs}
        if len(payloads) > 1:
            raise ValueError("one RequestBatch serves one payload geometry")
        return cls(
            fabrics=cm.FabricArrays.from_fabrics(uniq or [C.fabric("tpu_ici")]),
            m_q=np.array([r.m_q for r in reqs], np.int64),
            c_t=np.array([r.c_t for r in reqs], np.int64),
            fabric_idx=np.array(idx, np.int64),
            expected_reuse_steps=np.array(
                [r.expected_reuse_steps for r in reqs], np.int64),
            k_selected=np.array(
                [-1 if r.k_selected is None else r.k_selected for r in reqs],
                np.int64),
            n_holders=np.array([r.n_holders for r in reqs], np.int64),
            position_delta=np.array([r.position_delta for r in reqs],
                                    np.int64),
            holder_can_compute=np.array([r.holder_can_compute for r in reqs],
                                        bool),
            host_overhead=np.array([r.host_overhead for r in reqs], bool),
            payload=reqs[0].payload if reqs else cm.MLA_PAYLOAD)


@dataclasses.dataclass(frozen=True)
class DecisionBatch:
    """Array-of-decisions: per element the three costs + the argmin code."""
    code: np.ndarray                  # int8: 0 ROUTE / 1 FETCH / 2 LOCAL
    t_route: np.ndarray
    t_fetch: np.ndarray
    t_local: np.ndarray

    def primitive(self, i: int) -> Primitive:
        return PRIMITIVE_BY_CODE[int(self.code[i])]

    def primitives(self) -> "list[Primitive]":
        return [PRIMITIVE_BY_CODE[int(c)] for c in self.code]


def route_cost_batch(b: RequestBatch,
                     k_flows: Optional[np.ndarray] = None) -> np.ndarray:
    """Vectorized route_cost(). With k_flows (per-element concurrent flows
    on the element's link), prices under §8 congestion instead of the
    uncontended transport — the engine's steady-state path."""
    fa = b.fabrics
    if k_flows is None:
        t = cm.t_route_batch(fa, b.fabric_idx, b.m_q, b.payload)
    else:
        t = cm.t_route_congested_full_batch(fa, b.fabric_idx, b.m_q,
                                            k_flows, b.payload)
    # selection fan-out / host overhead / dead-holder rows are priced on
    # their row subsets only (all three terms are element-wise, so the
    # scattered values are bitwise what the full-width pass produced)
    fanout = (b.k_selected >= 0) & (b.n_holders > 1)
    if fanout.any():
        idx = np.nonzero(fanout)[0]
        fan = cm.t_route_fanout_batch(fa, b.fabric_idx[idx], b.m_q[idx],
                                      np.maximum(b.n_holders[idx], 1),
                                      b.payload)
        t = t.copy()
        t[idx] = fan
    if b.host_overhead.any():
        t = t + np.where(
            b.host_overhead,
            C.HOST_OVERHEAD_BASE_S + C.HOST_OVERHEAD_PER_ROW_S * b.m_q, 0.0)
    if not b.holder_can_compute.all():
        t = np.where(b.holder_can_compute, t, np.inf)
    return t


def route_cost_rows(b: RequestBatch, idx: np.ndarray,
                    k_flows: np.ndarray) -> np.ndarray:
    """route_cost_batch on a row subset: bitwise what
    route_cost_batch(b.take(idx), k_flows) computes, without materialising
    the sub-batch. The engine's §8 incremental repricing only needs the
    ROUTE term on the over-knee rows — fetch/local costs are congestion-
    independent, so the uncontended pass already has them exactly."""
    fa = b.fabrics
    fi = b.fabric_idx[idx]
    mq = b.m_q[idx]
    t = cm.t_route_congested_full_batch(fa, fi, mq, k_flows, b.payload)
    ks = b.k_selected[idx]
    nh = b.n_holders[idx]
    fanout = (ks >= 0) & (nh > 1)
    if fanout.any():
        j = np.nonzero(fanout)[0]
        t[j] = cm.t_route_fanout_batch(fa, fi[j], mq[j],
                                       np.maximum(nh[j], 1), b.payload)
    ho = b.host_overhead[idx]
    if ho.any():
        t = t + np.where(
            ho, C.HOST_OVERHEAD_BASE_S + C.HOST_OVERHEAD_PER_ROW_S * mq, 0.0)
    hcc = b.holder_can_compute[idx]
    if not hcc.all():
        t = np.where(hcc, t, np.inf)
    return t


def fetch_cost_batch(b: RequestBatch) -> np.ndarray:
    """Vectorized fetch_cost(): scattered gather under selection (never
    amortised, §5.4); otherwise pull+splice amortised over expected reuse."""
    fa = b.fabrics
    contiguous = b.position_delta != 0
    bulk = cm.t_fetch_batch(fa, b.fabric_idx, b.c_t, b.payload, contiguous)
    bulk = bulk / np.maximum(1, b.expected_reuse_steps)
    has_sel = b.k_selected >= 0
    if not has_sel.any():
        return bulk
    # scattered-gather pricing on the selection rows only (element-wise,
    # so the scatter reproduces the full-width np.where bitwise)
    idx = np.nonzero(has_sel)[0]
    bulk[idx] = cm.t_fetch_scattered_batch(
        fa, b.fabric_idx[idx], np.maximum(b.k_selected[idx], 0),
        np.maximum(b.n_holders[idx], 1), b.payload)
    return bulk


def local_cost_batch(b: RequestBatch,
                     c_per_token_layer: float =
                     C.PREFILL_PER_TOKEN_LAYER_MID_S) -> np.ndarray:
    return cm.t_local_batch(b.c_t, b.payload.n_layers, c_per_token_layer)


def decide_batch(b: RequestBatch,
                 k_flows: Optional[np.ndarray] = None) -> DecisionBatch:
    """The closed-form predicate over a whole batch: element-wise argmin of
    the three vectorized costs. Tie-break order (ROUTE < FETCH < LOCAL)
    matches decide()'s min() ordering. k_flows (optional) prices ROUTE under
    link congestion — used by the engine's steady-state scheduler."""
    tr = route_cost_batch(b, k_flows)
    tf = fetch_cost_batch(b)
    tl = local_cost_batch(b)
    code = np.argmin(np.stack([tr, tf, tl], axis=0), axis=0).astype(np.int8)
    return DecisionBatch(code, tr, tf, tl)


# ---------------------------------------------------------------------------
# Serving rules of thumb (§5.5) as queryable helpers.
# ---------------------------------------------------------------------------

def fetch_local_crossover_ct(fabric: Fabric,
                             payload: cm.Payload = cm.MLA_PAYLOAD,
                             c_lo: float = C.PREFILL_PER_TOKEN_LAYER_S[0],
                             c_hi: float = C.PREFILL_PER_TOKEN_LAYER_S[1]) -> tuple:
    """Chunk size above which FETCH's flat splice undercuts LOCAL re-prefill.
    Paper: ~75-220 tokens for c in [0.5, 1.5] us/token-layer."""
    out = []
    for c in (c_hi, c_lo):      # c_hi gives the small end of the band
        # Solve c_t * L * c = t_fetch(c_t); pull term is tiny, iterate once.
        ct = np.array(1.0)
        for _ in range(50):
            ct = cm.t_fetch(fabric, float(ct), payload) / (payload.n_layers * c)
        out.append(float(ct))
    return tuple(out)


def holder_fanout_cap() -> int:
    """Per-holder concurrent-requester cap: both the copy- and compute-elbows
    sit near 8 (§6.2, §6.3)."""
    return C.HOLDER_COMPUTE_ELBOW_N


def replication_threshold(n_agents: int) -> bool:
    """Agentic fan-in (§6.3): beyond the elbow, added agents cost linearly and
    a second replica (an amortised FETCH) is warranted."""
    return n_agents > C.HOLDER_COMPUTE_ELBOW_N
