"""The topology-aware redistribution cost model (paper §4).

    T_redist(F, s, B) = T_probe(F) + T_transfer(F, s, B) + T_compute
                        + T_return(F, s, B') + T_merge

Instantiated per primitive:

    ROUTE : T_probe + M_q (q+p)/BW + T_compute + T_merge
    FETCH : T_pull + T_splice          (contiguous reuse)
            multi-holder scattered gather (selection regime, §5.4)
    LOCAL : c_t * L * c                (re-prefill)

All functions are pure closed-form (numpy-scalar) — the paper's point is that
a scheduler evaluates this *arithmetically*, with no online calibration
(§4.3: "evaluated, not profiled").
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import constants as C
from repro.core.constants import Fabric


@dataclasses.dataclass(frozen=True)
class Payload:
    """Wire payload geometry for one routed query row (model-dependent).

    Extending the predicate to a new architecture requires exactly the two
    coefficients the paper names (abstract): the routed payload here and
    FETCH's move-the-cache cost (b_kv/splice below).
    """
    q_bytes: int = C.Q_ROW_BYTES          # routed query row
    p_bytes: int = C.P_ROW_BYTES          # returned partial (o, m, l)
    b_kv_token_layer: int = C.B_KV_TOKEN_LAYER
    n_layers: int = C.V2_LITE_LAYERS

    @property
    def qp_bytes(self) -> int:
        return self.q_bytes + self.p_bytes

    @property
    def b_kv_token_all_layers(self) -> int:
        return self.b_kv_token_layer * self.n_layers


MLA_PAYLOAD = Payload()


def payload_for(d_qk: int, d_v: int, n_layers: int,
                kv_bytes_token_layer: Optional[int] = None) -> Payload:
    """Instantiate the wire payload from published model dimensions (§1)."""
    q = d_qk * C.BF16
    p = d_v * C.BF16 + 2 * C.FP32
    b_kv = kv_bytes_token_layer if kv_bytes_token_layer is not None else d_qk * C.BF16
    return Payload(q, p, b_kv, n_layers)


# ---------------------------------------------------------------------------
# ROUTE
# ---------------------------------------------------------------------------

def t_route_transport(fabric: Fabric, m_q: int, payload: Payload = MLA_PAYLOAD,
                      include_launch: bool = False) -> float:
    """Transport round trip: T_probe + M_q (q+p) / BW   [paper eq. §4.2].

    include_launch adds the fixed ~9 us kernel-turnaround residual the linear
    term omits (§4.3) — used when predicting small-M_q measurements.
    """
    t = fabric.t_probe_s + m_q * payload.qp_bytes / fabric.bw_Bps
    if include_launch:
        t += fabric.t_launch_s
    return t


def t_route(fabric: Fabric, m_q: int, payload: Payload = MLA_PAYLOAD,
            t_compute: float = np.mean(C.HOLDER_COMPUTE_DECODE_S),
            t_merge: float = C.MERGE_COST_S,
            t_host: float = 0.0,
            include_launch: bool = False) -> float:
    """Full ROUTE cost. t_host models the §5.3 prototype host overhead
    (3.5 ms + 12.5 us * M_q there); 0 for an in-graph transport."""
    return (t_route_transport(fabric, m_q, payload, include_launch)
            + t_compute + t_merge + t_host)


def t_route_fanout(fabric: Fabric, m_q: int, n_holders: int,
                   payload: Payload = MLA_PAYLOAD,
                   t_compute: float = np.mean(C.HOLDER_COMPUTE_DECODE_S),
                   t_merge_per_way: float = C.MERGE_COST_S / 8) -> float:
    """Scattered-selection fan-out (§5.4): the query ships once per holder
    (probe-bound), holders compute in parallel, M-way merge at requester.
    Stays flat in n_holders: the M sends are concurrent (probe-bound) and the
    merge is <= 25 us total."""
    sends = fabric.t_probe_s + m_q * payload.qp_bytes / fabric.bw_Bps
    return sends + t_compute + n_holders * t_merge_per_way


# ---------------------------------------------------------------------------
# FETCH
# ---------------------------------------------------------------------------

def t_splice(c_t: int) -> float:
    """Position-adaptation splice: flat ~3 ms, launch-bound (§2.2, §7)."""
    return C.SPLICE_BASE_S + C.SPLICE_PER_TOKEN_S * c_t


def t_pull(fabric: Fabric, c_t: int, payload: Payload = MLA_PAYLOAD) -> float:
    """Bulk all-layer c^KV pull, coalesced into one transfer => sees the link
    peak, not the dispatch ceiling (§8)."""
    return c_t * payload.b_kv_token_all_layers / fabric.link_peak_Bps


def t_fetch(fabric: Fabric, c_t: int, payload: Payload = MLA_PAYLOAD,
            contiguous: bool = True) -> float:
    """Move-the-cache. Contiguous reuse pays pull + splice; a true-prefix
    re-home (delta = 0) elides the splice (§6.3)."""
    t = t_pull(fabric, c_t, payload)
    if contiguous:
        t += t_splice(c_t)
    return t


def t_fetch_scattered(fabric: Fabric, k_selected: int, n_holders: int,
                      payload: Payload = MLA_PAYLOAD,
                      per_holder_handshake_s: float = 180e-6) -> float:
    """Scattered gather under selection (§5.4): per-holder separate transfers
    (scattering defeats bulk coalescing) + per-holder handshakes; no splice
    (entries stay at canonical positions). Grows linearly in n_holders;
    measured 1.3 -> 3.9 ms/layer for M=1->7 at k=2048. Returns the ALL-layer
    cost. The handshake constant is fit from Fig 4a.
    """
    per_layer_bytes = k_selected * payload.b_kv_token_layer
    # Serial per-holder pulls at the dispatch rate (prototype is host-copy
    # bound; we take the fabric dispatch rate as the optimistic bound).
    per_layer = (n_holders * per_holder_handshake_s
                 + per_layer_bytes / fabric.bw_Bps)
    return payload.n_layers * per_layer


# ---------------------------------------------------------------------------
# LOCAL
# ---------------------------------------------------------------------------

def t_local(c_t: int, n_layers: int = C.V2_LITE_LAYERS,
            c_per_token_layer: float = C.PREFILL_PER_TOKEN_LAYER_MID_S) -> float:
    """Fresh re-prefill of the chunk: c_t * L * c (§5.1)."""
    return c_t * n_layers * c_per_token_layer


# ---------------------------------------------------------------------------
# Wire bytes (§5.2) — the M_q x c_t crossover is on bytes alone.
# ---------------------------------------------------------------------------

def route_wire_bytes(m_q: int, payload: Payload = MLA_PAYLOAD) -> int:
    return m_q * payload.qp_bytes


def fetch_wire_bytes(c_t: int, payload: Payload = MLA_PAYLOAD,
                     all_layers: bool = False) -> int:
    b = payload.b_kv_token_all_layers if all_layers else payload.b_kv_token_layer
    return c_t * b


def byte_breakeven_mq(c_t: int, payload: Payload = MLA_PAYLOAD) -> float:
    """M_q* = c_t * b_KV / (q+p): ROUTE moves fewer bytes below this (§5.2).
    Per-layer on both sides (the L factor cancels)."""
    return c_t * payload.b_kv_token_layer / payload.qp_bytes


# ---------------------------------------------------------------------------
# Congestion (§8): flat until a link is fully subscribed.
# ---------------------------------------------------------------------------

def t_route_congested(fabric: Fabric, m_q: int, k_flows: int,
                      payload: Payload = MLA_PAYLOAD) -> float:
    """K concurrent route flows sharing one link. Measured behaviour: flat
    through K<=2; at K=3 queueing lands on probe and transfer alike."""
    probe_mult = C.CONGESTION_PROBE_MULT.get(min(k_flows, 3), 1.0)
    if k_flows >= 3:
        # Full subscription: each flow sees ~1/k of the dispatch bandwidth
        # plus probe queueing. Calibrated to the measured +119% at M_q=1024.
        bw = fabric.bw_Bps / (k_flows - 1)
    else:
        bw = fabric.bw_Bps
    return fabric.t_probe_s * probe_mult + m_q * payload.qp_bytes / bw


def t_route_congested_full(fabric: Fabric, m_q: int, k_flows: int,
                           payload: Payload = MLA_PAYLOAD,
                           t_compute: float = np.mean(
                               C.HOLDER_COMPUTE_DECODE_S),
                           t_merge: float = C.MERGE_COST_S) -> float:
    """End-to-end congested ROUTE: transport under K flows + holder compute
    + merge. The one formula both the predicate (batch form below) and the
    engine's dispatch pricing use — keep them in lockstep here."""
    return t_route_congested(fabric, m_q, k_flows, payload) \
        + t_compute + t_merge


# ---------------------------------------------------------------------------
# Vectorized (array-safe) forms. The scalar functions above ARE element-wise
# in their numeric arguments but take one Fabric object; the batch forms take
# a FabricArrays table + integer fabric indices so a scheduler can price a
# whole decode step in a handful of numpy expressions (the §4.3 point taken
# to throughput: "evaluated, not profiled" — and evaluated in bulk).
# Element-wise they match the scalar forms exactly (tests/test_predicate_batch).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FabricArrays:
    """Struct-of-arrays view of a fabric table, indexable by fabric id."""
    names: Tuple[str, ...]
    t_probe_s: np.ndarray
    bw_Bps: np.ndarray
    link_peak_Bps: np.ndarray
    t_launch_s: np.ndarray

    @classmethod
    def from_fabrics(cls, fabrics: Sequence[Fabric]) -> "FabricArrays":
        return cls(
            names=tuple(f.name for f in fabrics),
            t_probe_s=np.array([f.t_probe_s for f in fabrics], np.float64),
            bw_Bps=np.array([f.bw_Bps for f in fabrics], np.float64),
            link_peak_Bps=np.array([f.link_peak_Bps for f in fabrics],
                                   np.float64),
            t_launch_s=np.array([f.t_launch_s for f in fabrics], np.float64))

    def index_of(self, name: str) -> int:
        return self.names.index(name)


def fabric_arrays(names: Optional[Sequence[str]] = None) -> FabricArrays:
    """FabricArrays over the named rows of C.FABRICS (all rows by default,
    in sorted-name order so indices are stable)."""
    keys = list(names) if names is not None else sorted(C.FABRICS)
    return FabricArrays.from_fabrics([C.FABRICS[k] for k in keys])


def t_route_batch(fa: FabricArrays, fabric_idx: np.ndarray,
                  m_q: np.ndarray, payload: Payload = MLA_PAYLOAD,
                  t_compute: float = np.mean(C.HOLDER_COMPUTE_DECODE_S),
                  t_merge: float = C.MERGE_COST_S,
                  t_host: np.ndarray = 0.0,
                  include_launch: bool = False) -> np.ndarray:
    """Vectorized t_route over (fabric_idx, m_q) arrays."""
    fi = np.asarray(fabric_idx)
    t = (fa.t_probe_s[fi]
         + np.asarray(m_q, np.float64) * payload.qp_bytes / fa.bw_Bps[fi])
    if include_launch:
        t = t + fa.t_launch_s[fi]
    return t + t_compute + t_merge + np.asarray(t_host, np.float64)


def t_route_fanout_batch(fa: FabricArrays, fabric_idx: np.ndarray,
                         m_q: np.ndarray, n_holders: np.ndarray,
                         payload: Payload = MLA_PAYLOAD,
                         t_compute: float = np.mean(C.HOLDER_COMPUTE_DECODE_S),
                         t_merge_per_way: float = C.MERGE_COST_S / 8
                         ) -> np.ndarray:
    """Vectorized t_route_fanout (§5.4 scattered-selection fan-out)."""
    fi = np.asarray(fabric_idx)
    sends = (fa.t_probe_s[fi]
             + np.asarray(m_q, np.float64) * payload.qp_bytes / fa.bw_Bps[fi])
    return sends + t_compute + np.asarray(n_holders) * t_merge_per_way


def t_fetch_batch(fa: FabricArrays, fabric_idx: np.ndarray,
                  c_t: np.ndarray, payload: Payload = MLA_PAYLOAD,
                  contiguous: np.ndarray = True) -> np.ndarray:
    """Vectorized t_fetch: bulk pull at link peak + splice where contiguous."""
    fi = np.asarray(fabric_idx)
    ct = np.asarray(c_t, np.float64)
    pull = ct * payload.b_kv_token_all_layers / fa.link_peak_Bps[fi]
    splice = C.SPLICE_BASE_S + C.SPLICE_PER_TOKEN_S * ct
    return pull + np.where(np.asarray(contiguous), splice, 0.0)


def t_fetch_scattered_batch(fa: FabricArrays, fabric_idx: np.ndarray,
                            k_selected: np.ndarray, n_holders: np.ndarray,
                            payload: Payload = MLA_PAYLOAD,
                            per_holder_handshake_s: float = 180e-6
                            ) -> np.ndarray:
    """Vectorized t_fetch_scattered (§5.4 gather; linear in n_holders)."""
    fi = np.asarray(fabric_idx)
    per_layer_bytes = np.asarray(k_selected, np.float64) \
        * payload.b_kv_token_layer
    per_layer = (np.asarray(n_holders) * per_holder_handshake_s
                 + per_layer_bytes / fa.bw_Bps[fi])
    return payload.n_layers * per_layer


def t_local_batch(c_t: np.ndarray, n_layers: int = C.V2_LITE_LAYERS,
                  c_per_token_layer: float = C.PREFILL_PER_TOKEN_LAYER_MID_S
                  ) -> np.ndarray:
    """Vectorized t_local (already element-wise; named for symmetry)."""
    return np.asarray(c_t, np.float64) * n_layers * c_per_token_layer


def t_route_congested_batch(fa: FabricArrays, fabric_idx: np.ndarray,
                            m_q: np.ndarray, k_flows: np.ndarray,
                            payload: Payload = MLA_PAYLOAD) -> np.ndarray:
    """Vectorized t_route_congested (§8): flat through K<=2 concurrent
    flows on a link; at K>=3 probe queueing + 1/(K-1) dispatch bandwidth."""
    fi = np.asarray(fabric_idx)
    k = np.asarray(k_flows)
    probe_mult = np.where(k >= 3, C.CONGESTION_PROBE_MULT[3], 1.0)
    bw = np.where(k >= 3, fa.bw_Bps[fi] / np.maximum(k - 1, 1),
                  fa.bw_Bps[fi])
    return (fa.t_probe_s[fi] * probe_mult
            + np.asarray(m_q, np.float64) * payload.qp_bytes / bw)


def t_route_congested_full_batch(fa: FabricArrays, fabric_idx: np.ndarray,
                                 m_q: np.ndarray, k_flows: np.ndarray,
                                 payload: Payload = MLA_PAYLOAD,
                                 t_compute: float = np.mean(
                                     C.HOLDER_COMPUTE_DECODE_S),
                                 t_merge: float = C.MERGE_COST_S
                                 ) -> np.ndarray:
    """Vectorized t_route_congested_full (see scalar form above)."""
    return t_route_congested_batch(fa, fabric_idx, m_q, k_flows, payload) \
        + t_compute + t_merge


# ---------------------------------------------------------------------------
# Per-stage breakdowns (§4: T_probe / T_transfer / T_compute / T_return /
# T_merge). The serving timeline (repro.serving.timeline) consumes these:
# each breakdown is an ordered tuple of (stage_name, seconds) whose durations
# sum to the corresponding closed-form price above, so a one-flow timeline IS
# the scalar cost model. k_flows prices the wire stages under the §8 closed
# form; the timeline passes 0 (uncontended) because there queueing is
# *simulated* — flows serialize on the shared link — rather than priced.
# ---------------------------------------------------------------------------

StageList = Tuple[Tuple[str, float], ...]


def route_stages(fabric: Fabric, m_q: int, k_flows: int = 0,
                 payload: Payload = MLA_PAYLOAD,
                 t_compute: float = np.mean(C.HOLDER_COMPUTE_DECODE_S),
                 t_merge: float = C.MERGE_COST_S,
                 t_host: float = 0.0) -> StageList:
    """ROUTE as the paper's five stages. transfer carries the query rows out,
    return carries the partials back; together they are the t_route_transport
    round trip, so the stage sum equals t_route_congested_full + t_host."""
    probe_mult = C.CONGESTION_PROBE_MULT.get(min(k_flows, 3), 1.0)
    bw = fabric.bw_Bps / (k_flows - 1) if k_flows >= 3 else fabric.bw_Bps
    stages = [
        ("probe", fabric.t_probe_s * probe_mult),
        ("transfer", m_q * payload.q_bytes / bw),
        ("compute", float(t_compute)),
        ("return", m_q * payload.p_bytes / bw),
        ("merge", float(t_merge)),
    ]
    if t_host:
        stages.append(("host", float(t_host)))
    return tuple(stages)


def fetch_stages(fabric: Fabric, c_t: int, payload: Payload = MLA_PAYLOAD,
                 contiguous: bool = True, reuse_steps: int = 1) -> StageList:
    """FETCH as bulk pull + position splice, each amortised over the reuse
    horizon (§5.5 rule 2) so the stage sum equals t_fetch / reuse_steps."""
    r = max(1, reuse_steps)
    stages = [("pull", t_pull(fabric, c_t, payload) / r)]
    if contiguous:
        stages.append(("splice", t_splice(c_t) / r))
    return tuple(stages)


def fetch_scattered_stages(fabric: Fabric, k_selected: int, n_holders: int,
                           payload: Payload = MLA_PAYLOAD,
                           per_holder_handshake_s: float = 180e-6
                           ) -> StageList:
    """Scattered gather (§5.4) as one wire stage: the per-holder transfers
    are serial at the dispatch rate, so there is no overlap to expose."""
    return (("gather", t_fetch_scattered(fabric, k_selected, n_holders,
                                         payload, per_holder_handshake_s)),)


def local_stages(c_t: int, n_layers: int = C.V2_LITE_LAYERS,
                 c_per_token_layer: float = C.PREFILL_PER_TOKEN_LAYER_MID_S
                 ) -> StageList:
    """LOCAL re-prefill: one compute stage on the requester, no wire."""
    return (("prefill", t_local(c_t, n_layers, c_per_token_layer)),)


# ---------------------------------------------------------------------------
# Selection regime (§5.4): the distributed indexer service. Per decode step
# the requester broadcasts a NARROW indexer query (d_index columns, not the
# full d_qk row) to every holder of a selected chunk; each holder scores its
# resident index keys (the chunk store's sidecar) and returns its local
# top-k (block id, score) candidates; the requester merges them into the
# global top-k. The `index` stage below is one holder's share of that round
# trip — it rides the same (link, fabric) wire as the transport stages, and
# the planner prepends it to the ROUTE/FETCH stage chains of selection
# dispatches. Holder compute then scales with the selection budget resident
# on the holder (KB), not the store size.
# ---------------------------------------------------------------------------

INDEX_CANDIDATE_BYTES = 8          # returned (block id i32, score f32) pair


def t_index_roundtrip(fabric: Fabric, m_q: int, k_blocks: int,
                      d_index: int) -> float:
    """One holder's indexer round trip: ship m_q narrow query rows (d_index
    bf16 columns — the scoring projection, not the 1152-B wire row), get
    back <= k_blocks candidates. Scoring compute is folded into the
    attention compute stage (it is a rank-d_index dot, noise next to it)."""
    wire_bytes = m_q * d_index * C.BF16 + k_blocks * INDEX_CANDIDATE_BYTES
    return fabric.t_probe_s + wire_bytes / fabric.bw_Bps


def index_stages(fabric: Fabric, m_q: int, k_blocks: int,
                 d_index: int) -> StageList:
    """The indexer round trip as a timeline stage (wire class: it occupies
    the dispatch's (link, fabric) resource like probe/transfer do)."""
    return (("index", t_index_roundtrip(fabric, m_q, k_blocks, d_index)),)


def t_route_selected_full(fabric: Fabric, m_q: int, k_flows: int,
                          sel_frac: float, k_blocks: int, d_index: int,
                          payload: Payload = MLA_PAYLOAD,
                          t_compute: float = np.mean(
                              C.HOLDER_COMPUTE_DECODE_S),
                          t_merge: float = C.MERGE_COST_S) -> float:
    """End-to-end ROUTE under selection: indexer round trip + congested
    query transport + holder compute scaled by the fraction of the holder's
    store the selection touches (sel_frac = selected/resident tokens — the
    budget KB, not the store size) + merge."""
    return (t_index_roundtrip(fabric, m_q, k_blocks, d_index)
            + t_route_congested(fabric, m_q, k_flows, payload)
            + t_compute * sel_frac + t_merge)


def route_selected_stages(fabric: Fabric, m_q: int, k_flows: int,
                          sel_frac: float, k_blocks: int, d_index: int,
                          payload: Payload = MLA_PAYLOAD,
                          t_compute: float = np.mean(
                              C.HOLDER_COMPUTE_DECODE_S),
                          t_merge: float = C.MERGE_COST_S) -> StageList:
    """ROUTE under selection as stages: index + the five §4 stages with
    compute scaled to the selected fraction. Parameter order matches
    t_route_selected_full (the two must stay in lockstep): the stage sum
    equals it exactly at the same k_flows."""
    return index_stages(fabric, m_q, k_blocks, d_index) + route_stages(
        fabric, m_q, k_flows, payload, t_compute * sel_frac, t_merge)


def t_fetch_selected(fabric: Fabric, k_local: float, m_q: int, k_blocks: int,
                     d_index: int, payload: Payload = MLA_PAYLOAD) -> float:
    """End-to-end FETCH under selection, per holder: indexer round trip +
    scattered gather of the k_local entries chosen on this holder (no
    splice — canonical positions; never amortised — the selection is
    re-chosen every step, §5.4)."""
    return (t_index_roundtrip(fabric, m_q, k_blocks, d_index)
            + t_fetch_scattered(fabric, k_local, 1, payload))


def fetch_selected_stages(fabric: Fabric, k_local: float, m_q: int,
                          k_blocks: int, d_index: int,
                          payload: Payload = MLA_PAYLOAD) -> StageList:
    """FETCH under selection as stages: index + one gather wire stage.
    Summed over a selection's M holders, the gather stages reproduce the
    closed-form t_fetch_scattered(k_total, M) exactly (M handshakes + the
    budget's bytes) — bench_scatter_gather asserts the identity."""
    return index_stages(fabric, m_q, k_blocks, d_index) + (
        ("gather", t_fetch_scattered(fabric, k_local, 1, payload)),)


# ---------------------------------------------------------------------------
# Stage templates (ISSUE 6): the per-stage breakdowns above, assembled by
# broadcast for a whole dispatch column instead of per-dispatch function
# calls. One StageTemplates instance caches the per-(fabric, regime)
# coefficient columns of a FabricArrays + Payload pairing; each method
# returns an (R, n_stages) float64 duration matrix whose rows are
# element-wise bit-identical to the scalar *_stages tuples (the arithmetic
# mirrors the scalar expressions operation-for-operation — the array
# planner's golden parity depends on it). Stage names per kind are the
# class-level *_names tuples, in column order.
# ---------------------------------------------------------------------------


class StageTemplates:
    """Broadcast assembly of the §4 stage breakdowns for the array planner.

    Durations are UNCONTENDED (k_flows = 0), like the timeline inputs the
    engine builds — §8 queueing is simulated by the scheduler, while the
    *_est methods price the congested closed forms the predicate used."""

    route_names = ("probe", "transfer", "compute", "return", "merge")
    fetch_names = ("pull", "splice")
    local_names = ("prefill",)
    route_selected_names = ("index",) + route_names
    fetch_selected_names = ("index", "gather")

    def __init__(self, fa: FabricArrays, payload: Payload = MLA_PAYLOAD,
                 t_compute: float = np.mean(C.HOLDER_COMPUTE_DECODE_S),
                 t_merge: float = C.MERGE_COST_S):
        self.fa = fa
        self.payload = payload
        self.t_compute = t_compute
        self.t_merge = t_merge

    # -- dense ROUTE --------------------------------------------------------

    def route(self, fi: np.ndarray, m_q: np.ndarray) -> np.ndarray:
        fa, p = self.fa, self.payload
        mq = np.asarray(m_q, np.float64)
        bw = fa.bw_Bps[fi]
        out = np.empty((mq.shape[0], 5), np.float64)
        out[:, 0] = fa.t_probe_s[fi]             # probe_mult == 1 at k = 0
        out[:, 1] = mq * p.q_bytes / bw
        out[:, 2] = self.t_compute
        out[:, 3] = mq * p.p_bytes / bw
        out[:, 4] = self.t_merge
        return out

    def route_est(self, fi: np.ndarray, m_q: np.ndarray,
                  k_flows: np.ndarray) -> np.ndarray:
        """t_route_congested_full, the formula the predicate priced with."""
        return t_route_congested_full_batch(
            self.fa, fi, m_q, k_flows, self.payload,
            self.t_compute, self.t_merge)

    # -- dense FETCH --------------------------------------------------------

    def fetch(self, fi: np.ndarray, c_t: np.ndarray,
              reuse: np.ndarray) -> np.ndarray:
        fa, p = self.fa, self.payload
        ct = np.asarray(c_t, np.float64)
        r = np.asarray(reuse, np.float64)
        out = np.empty((ct.shape[0], 2), np.float64)
        out[:, 0] = ct * p.b_kv_token_all_layers / fa.link_peak_Bps[fi] / r
        out[:, 1] = (C.SPLICE_BASE_S + C.SPLICE_PER_TOKEN_S * ct) / r
        return out

    def fetch_est(self, fi: np.ndarray, c_t: np.ndarray,
                  reuse: np.ndarray) -> np.ndarray:
        fa, p = self.fa, self.payload
        ct = np.asarray(c_t, np.float64)
        pull = ct * p.b_kv_token_all_layers / fa.link_peak_Bps[fi]
        splice = C.SPLICE_BASE_S + C.SPLICE_PER_TOKEN_S * ct
        return (pull + splice) / np.asarray(reuse, np.float64)

    # -- LOCAL --------------------------------------------------------------

    def local(self, c_t: np.ndarray) -> np.ndarray:
        return t_local_batch(c_t, self.payload.n_layers)[:, None]

    def local_est(self, c_t: np.ndarray) -> np.ndarray:
        return t_local_batch(c_t, self.payload.n_layers)

    # -- selection regime (§5.4) --------------------------------------------

    def _index_rt(self, fi: np.ndarray, m_q: np.ndarray, k_blocks: np.ndarray,
                  d_index: int) -> np.ndarray:
        wire_bytes = (np.asarray(m_q, np.int64) * d_index * C.BF16
                      + np.asarray(k_blocks, np.int64)
                      * INDEX_CANDIDATE_BYTES)
        return self.fa.t_probe_s[fi] + wire_bytes / self.fa.bw_Bps[fi]

    def route_selected(self, fi: np.ndarray, m_q: np.ndarray,
                       sel_frac: np.ndarray, k_blocks: np.ndarray,
                       d_index: int) -> np.ndarray:
        out = np.empty((np.asarray(m_q).shape[0], 6), np.float64)
        out[:, 0] = self._index_rt(fi, m_q, k_blocks, d_index)
        out[:, 1:] = self.route(fi, m_q)
        out[:, 3] = self.t_compute * np.asarray(sel_frac, np.float64)
        return out

    def route_selected_est(self, fi: np.ndarray, m_q: np.ndarray,
                           k_flows: np.ndarray, sel_frac: np.ndarray,
                           k_blocks: np.ndarray, d_index: int) -> np.ndarray:
        cong = t_route_congested_batch(self.fa, fi, m_q, k_flows,
                                       self.payload)
        return (self._index_rt(fi, m_q, k_blocks, d_index) + cong
                + self.t_compute * np.asarray(sel_frac, np.float64)
                + self.t_merge)

    def fetch_selected(self, fi: np.ndarray, k_local: np.ndarray,
                       m_q: np.ndarray, k_blocks: np.ndarray,
                       d_index: int) -> np.ndarray:
        out = np.empty((np.asarray(m_q).shape[0], 2), np.float64)
        out[:, 0] = self._index_rt(fi, m_q, k_blocks, d_index)
        out[:, 1] = self._gather(fi, k_local)
        return out

    def fetch_selected_est(self, fi: np.ndarray, k_local: np.ndarray,
                           m_q: np.ndarray, k_blocks: np.ndarray,
                           d_index: int) -> np.ndarray:
        return self._index_rt(fi, m_q, k_blocks, d_index) \
            + self._gather(fi, k_local)

    def _gather(self, fi: np.ndarray, k_local: np.ndarray,
                per_holder_handshake_s: float = 180e-6) -> np.ndarray:
        """t_fetch_scattered at n_holders = 1 (per-holder gather)."""
        p = self.payload
        per_layer_bytes = np.asarray(k_local, np.int64) * p.b_kv_token_layer
        return p.n_layers * (per_holder_handshake_s
                             + per_layer_bytes / self.fa.bw_Bps[fi])


def scale_stages(stages: StageList, factor: float) -> StageList:
    """Scale every stage duration (holder/requester slowdown)."""
    if factor == 1.0:
        return stages
    return tuple((name, dur * factor) for name, dur in stages)


def stages_total_s(stages: StageList) -> float:
    return sum(d for _, d in stages)


# ---------------------------------------------------------------------------
# Model-fit diagnostics (§4.3): MAPE of the affine model vs measurements.
# ---------------------------------------------------------------------------

def mape(predicted: Sequence[float], measured: Sequence[float]) -> float:
    p = np.asarray(predicted, dtype=np.float64)
    m = np.asarray(measured, dtype=np.float64)
    return float(np.mean(np.abs(p - m) / m))


def fit_affine(m_qs: Sequence[int], rts: Sequence[float],
               payload: Payload = MLA_PAYLOAD) -> Fabric:
    """Least-squares re-fit of the two per-fabric constants (T_probe, BW)
    from a measured (M_q, round-trip) sweep — 'extending to a new fabric
    requires measuring just two coefficients'."""
    x = np.asarray(m_qs, dtype=np.float64) * payload.qp_bytes
    y = np.asarray(rts, dtype=np.float64)
    slope, intercept = np.polyfit(x, y, 1)
    bw = 1.0 / slope
    return Fabric("fitted", float(intercept), float(bw), float(bw))
