"""Online-softmax partial-attention merge (paper §3.2, §3.3).

A *partial* is the triple (o, m, l):
    o : the holder's normalized attention output over its resident subset,
        shape (..., d_v)
    m : running max-logit, shape (...)
    l : softmax denominator sum(exp(logit - m)), shape (...)

This is the sufficient statistic FlashAttention carries between tiles, here
carried between instances. The merge is exact (associative + commutative up to
float round-off) and has a zero-weight identity (l = 0, m = -inf), which is
what makes multi-holder fan-out partition-invariant (§3.3).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp


NEG_INF = float("-inf")


class Partial(NamedTuple):
    o: jax.Array      # (..., d_v) normalized partial output
    m: jax.Array      # (...,) running max logit
    l: jax.Array      # (...,) softmax denominator at m

    @staticmethod
    def identity(shape: tuple, d_v: int, dtype=jnp.float32) -> "Partial":
        """The zero-weight identity: merging it is a no-op."""
        return Partial(
            o=jnp.zeros(shape + (d_v,), dtype),
            m=jnp.full(shape, NEG_INF, dtype),
            l=jnp.zeros(shape, dtype),
        )


def merge2(a: Partial, b: Partial) -> Partial:
    """Merge two partials exactly.

    Guards: if both are identity (m = -inf), the result is identity without
    producing NaNs from (-inf) - (-inf).
    """
    m = jnp.maximum(a.m, b.m)
    # exp(-inf - -inf) would be NaN; pin the reference point to 0 when both
    # inputs are identity so exp(a.m - 0) = exp(-inf) = 0 falls out cleanly.
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    wa = a.l * jnp.exp(a.m - m_safe)
    wb = b.l * jnp.exp(b.m - m_safe)
    l = wa + wb
    denom = jnp.where(l > 0, l, 1.0)
    o = (wa[..., None] * a.o + wb[..., None] * b.o) / denom[..., None]
    return Partial(o=o, m=jnp.where(l > 0, m, NEG_INF), l=l)


def merge_tree(partials: Sequence[Partial]) -> Partial:
    """M-way merge as a balanced tree (associativity makes any shape exact to
    round-off; the tree minimizes depth for the ring/fan-in variants)."""
    ps = list(partials)
    if not ps:
        raise ValueError("merge_tree needs at least one partial")
    while len(ps) > 1:
        nxt = [merge2(ps[i], ps[i + 1]) for i in range(0, len(ps) - 1, 2)]
        if len(ps) % 2:
            nxt.append(ps[-1])
        ps = nxt
    return ps[0]


def merge_stacked(o: jax.Array, m: jax.Array, l: jax.Array) -> Partial:
    """Merge M stacked partials: o (M, ..., d_v), m/l (M, ...).

    Single-pass fused form (what the softmax_merge Pallas kernel computes):
        m* = max_i m_i ;  w_i = l_i exp(m_i - m*) ;
        o* = sum_i w_i o_i / sum_i w_i
    """
    m_star = jnp.max(m, axis=0)
    safe_m = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
    w = l * jnp.exp(m - safe_m[None])          # exp(-inf) = 0 covers identity
    l_star = jnp.sum(w, axis=0)
    denom = jnp.where(l_star > 0, l_star, 1.0)
    o_star = jnp.einsum("i...,i...d->...d", w / denom[None], o)
    return Partial(o=o_star, m=jnp.where(l_star > 0, m_star, NEG_INF), l=l_star)


def partial_from_logits(logits: jax.Array, values: jax.Array,
                        mask: jax.Array | None = None) -> Partial:
    """Reference construction of a partial from raw attention logits over a
    resident subset: logits (..., S), values (..., S, d_v)."""
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - safe_m[..., None])
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    l = jnp.sum(p, axis=-1)
    denom = jnp.where(l > 0, l, 1.0)
    # values may be bf16 (the resident cache): mixed-precision dot with f32
    # accumulate, no materialized f32 copy of the cache (§Perf P2)
    o = jnp.einsum("...s,...sd->...d", p / denom[..., None], values,
                   preferred_element_type=jnp.float32)
    return Partial(o=o, m=m, l=l)
