"""Sharded checkpointing with async write and elastic restore.

No orbax on this box — .npz per snapshot + JSON manifest (tree structure,
shapes, dtypes, mesh). Restore re-shards to ANY mesh via device_put with
the target sharding (elastic scaling: save on (8,), restore on (4,2) —
tests/progs/dist_ckpt_prog.py proves it). Writes happen on a background
thread from host copies so the train loop overlaps the serialization.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot a pytree. Device->host copy happens synchronously (so
        donated buffers may be reused); serialization is async."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        structure = jax.tree.map(lambda _: 0, tree)

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            tmp.mkdir(parents=True, exist_ok=True)
            # exotic dtypes (bfloat16) do not survive npz: store raw byte
            # views; the manifest carries the true dtype names
            np.savez(tmp / "leaves.npz",
                     **{f"leaf_{i}":
                        np.ascontiguousarray(h).reshape(-1).view(np.uint8)
                        for i, h in enumerate(host)})
            manifest = {
                "step": step,
                "n_leaves": len(host),
                "shapes": [list(h.shape) for h in host],
                "dtypes": [str(h.dtype) for h in host],
                "time": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        snaps = self.all_steps()
        for s in snaps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Rebuild the pytree; shardings (same structure, NamedSharding) re-
        shard onto the CURRENT mesh — elastic restore to any topology."""
        import ml_dtypes                                  # jax dependency
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "leaves.npz")
        manifest = json.loads((path / "manifest.json").read_text())
        leaves, treedef = _flatten(target_tree)
        assert len(leaves) == len(data.files), \
            f"leaf count mismatch: {len(leaves)} vs {len(data.files)}"
        out = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
            raw = data[f"leaf_{i}"]
            dt = np.dtype(getattr(ml_dtypes, manifest["dtypes"][i],
                                  manifest["dtypes"][i]))
            arr = raw.view(dt).reshape(manifest["shapes"][i])
            assert tuple(arr.shape) == tuple(ref.shape), \
                f"leaf {i}: {arr.shape} vs {ref.shape}"
            if arr.dtype != np.dtype(ref.dtype):
                arr = arr.astype(ref.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree.unflatten(treedef, out)
