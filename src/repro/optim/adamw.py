"""AdamW with sharded states (no optax on this box).

Optimizer states inherit the parameter sharding (the dry-run's sharding
rules map m/v through the same logical axes), so FSDP shards them over
`data`. state_dtype=bfloat16 is the memory-relief option for the 340B
config (DESIGN.md §5) — error characteristics documented in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32      # bf16 option for the 340B config


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """One step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    lr_t = cfg.lr if lr is None else lr

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decoupled wd on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr_t * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr_t}


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr_at(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr_at
