"""int8 error-feedback gradient compression for the cross-pod reduction.

At 1000+-node scale the cross-pod (DCN) gradient sync is the scarce
bandwidth (DESIGN.md §5). Scheme: per-tensor scale = max|g|/127, quantize
to int8, all-reduce (psum) the int8-as-int32 payload over the pod axis,
dequantize; the quantization residual feeds back into the next step's
gradient (error feedback keeps SGD convergence — tests check parity).
4x wire reduction vs f32 (2x vs bf16) on the pod axis.

Used inside a shard_map over the 'pod' axis around the gradient sync; the
in-pod reduction stays full-precision.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_with_feedback(grads, errors, axis: str):
    """Inside shard_map over `axis`: error-feedback compressed all-reduce.

    grads/errors: matching pytrees (f32). Returns (mean-reduced grads,
    new errors)."""
    n = compat.axis_size(axis)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        # shared scale across pods (a scalar pmax on the wire — negligible)
        # so the int8 sum dequantizes exactly: sum_i q_i * s / n
        s = lax.pmax(jnp.max(jnp.abs(g)), axis) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8)
        new_e = g - q.astype(jnp.float32) * s  # residual -> next step
        summed = lax.psum(q.astype(jnp.int32), axis)
        mean = summed.astype(jnp.float32) * s / n
        return mean, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def wire_bytes_ratio() -> float:
    """int8 vs f32 gradient payload on the pod axis."""
    return 0.25
