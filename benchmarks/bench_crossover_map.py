"""Fig 3b — ROUTE vs FETCH on wire bytes over the (M_q, c_t) grid: the
break-even line M_q* = c_t b_KV/(q+p); decode at a hot 2k chunk sits at
>= 76% fewer routed bytes. §5.4: the same break-even at the released
selection budgets (V3.2/GLM-5.1 top-2048, V4 top-1024/512)."""

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm

from benchmarks.common import row


def run():
    rows = []
    for ct in (512, 1024, 2048, 4096):
        be = cm.byte_breakeven_mq(ct)
        rows.append(row(f"fig3b/breakeven_mq@ct{ct}", be,
                        "model:bytes", tokens=ct))
    saved = 1 - cm.route_wire_bytes(256) / cm.fetch_wire_bytes(2048)
    rows.append(row("fig3b/bytes_saved_pct@mq256_ct2048", saved * 100,
                    "model:bytes"))
    assert saved >= 0.76
    # grid summary: fraction of decode-typical cells (M_q <= 256) where
    # route wins on bytes, over c_t in [256, 4096]
    mqs = np.array([1, 4, 16, 64, 128, 256])
    cts = np.array([256, 512, 1024, 2048, 4096])
    wins = sum(cm.route_wire_bytes(int(m)) < cm.fetch_wire_bytes(int(c))
               for m in mqs for c in cts)
    rows.append(row("fig3b/route_wins_decode_cells_pct",
                    100 * wins / (len(mqs) * len(cts)), "model:bytes"))
    # selection budgets (§5.4): break-even spans ~270 (top-512) to ~1080
    for name, k in C.SELECTION_BUDGETS.items():
        rows.append(row(f"fig3b/breakeven@{name}_top{k}",
                        cm.byte_breakeven_mq(k), "model:bytes",
                        above_decode_batch=bool(
                            cm.byte_breakeven_mq(k) > 256)))
    return rows
