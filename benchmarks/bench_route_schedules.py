"""Beyond-paper: the three ROUTE schedules' measured collective footprints.

The paper measures one transport schedule (pairwise put + return). On TPU
the same primitive admits three shard_map schedules (core/routing.py):
pairwise ppermute, fan-out (all_gather q + all_to_all partials — the
scattered-selection shape), and ring (q+accumulator circulate; transfer
overlaps holder compute). This bench compiles all three on an 8-instance
mesh and reads their collective bytes + op counts off the HLO — the
schedule-selection data a TPU serving stack needs.
"""

import json
import os
import pathlib
import subprocess
import sys

from benchmarks.common import row

ROOT = pathlib.Path(__file__).resolve().parent.parent

_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.merge import Partial
from repro.core.routing import route_fanout, route_pairwise, route_ring
from repro.distributed.hlo_costs import analyse_hlo
from repro.models.mla import MLAConfig

CFG = MLAConfig()
NI, B, S_LOCAL = 8, 32, 2048
mesh = compat.make_mesh((NI,), ("instance",))
q = jax.ShapeDtypeStruct((NI * B, CFG.n_heads, CFG.d_qk), jnp.bfloat16)
ckv = jax.ShapeDtypeStruct((NI * S_LOCAL, CFG.d_qk), jnp.bfloat16)
valid = jax.ShapeDtypeStruct((NI * S_LOCAL,), jnp.bool_)
out = {}

def compile_and_count(name, fn, specs, out_specs, args):
    sm = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=specs,
                               out_specs=out_specs))
    c = analyse_hlo(sm.lower(*args).compile().as_text(), NI)
    out[name] = {"wire": c.collective_wire_bytes,
                 "counts": {k: int(v) for k, v in
                            c.collective_counts.items()}}

pspec = Partial(o=P("instance"), m=P("instance"), l=P("instance"))
compile_and_count(
    "pairwise",
    lambda q, c: route_pairwise(CFG, q, c,
                                Partial.identity(q.shape[:-1],
                                                 CFG.kv_lora_rank),
                                holder=3, requester=0, axis="instance",
                                wire_dtype=jnp.bfloat16),
    (P("instance"), P("instance")), pspec, (q, ckv))
compile_and_count(
    "fanout",
    lambda q, c, v: route_fanout(CFG, q, c, v, axis="instance",
                                 wire_dtype=jnp.bfloat16),
    (P("instance"), P("instance"), P("instance")), pspec, (q, ckv, valid))
compile_and_count(
    "ring",
    lambda q, c, v: route_ring(CFG, q, c, v, axis="instance"),
    (P("instance"), P("instance"), P("instance")), pspec, (q, ckv, valid))
print("RESULT " + json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PROG], capture_output=True,
                       text=True, env=env, cwd=str(ROOT), timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("RESULT ")][0][7:])
    rows = []
    for name, d in data.items():
        rows.append(row(f"schedules/{name}_wire_bytes", None,
                        "measured:compiled-HLO@8dev",
                        bytes=int(d["wire"]), counts=d["counts"]))
    # pairwise (1 holder) moves the least; fanout pays the all-holder
    # gather; ring multiplies by hops but buys transfer/compute overlap
    assert data["pairwise"]["wire"] < data["fanout"]["wire"]
    assert data["fanout"]["wire"] <= data["ring"]["wire"]
    rows.append(row("schedules/ring_over_fanout", None,
                    "measured:compiled-HLO@8dev",
                    ratio=round(data["ring"]["wire"]
                                / data["fanout"]["wire"], 2)))
    return rows
