"""Fig 4b — the route-holder's compute-capacity elbow at N~8: a holder
serving N routed requesters runs a batched partial; flat while the cache
read dominates, linear once per-requester compute does.

TPU-native derivation (DESIGN.md §2 — CPU wall-times are meaningless in
us): from OUR mla_decode kernel's tiling we count exact flops and HBM
bytes per (N, c_t) and evaluate on the v5e roofline constants. The cache
read (S x 576 x 2 B, shared by all N requesters) is the flat term; the
N-proportional MXU work is the linear term — elbow where they cross.
Also: the sparse-kernel premium tracks the selection budget k, not the
resident store size (§6.3)."""

import numpy as np

from repro.core import constants as C

from benchmarks.common import row

H, DQ, DV = 16, 576, 512
CT = 2048


def kernel_cost_s(n_req: int, s_tokens: int, h: int = H) -> tuple:
    """(time, flat_term, linear_term) for the batched decode kernel."""
    cache_bytes = s_tokens * DQ * 2               # streamed once, shared
    flops = n_req * h * (2 * s_tokens * DQ + 2 * s_tokens * DV)
    q_bytes = n_req * h * DQ * 2
    t_mem = (cache_bytes + q_bytes) / C.TPU_HBM_BW
    t_compute = flops / C.TPU_PEAK_FLOPS_BF16
    return max(t_mem, t_compute), t_mem, t_compute


def run():
    rows = []
    prev = None
    elbow = None
    for n in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        t, t_mem, t_c = kernel_cost_s(n, CT)
        rows.append(row(f"fig4b/holder_partial@N{n}", t * 1e6,
                        "derived:kernel-flops-bytes@v5e",
                        mem_us=round(t_mem * 1e6, 2),
                        compute_us=round(t_c * 1e6, 2)))
        if elbow is None and t_c > t_mem:
            elbow = n
        prev = t
    rows.append(row("fig4b/compute_elbow_N", elbow,
                    "derived:kernel-flops-bytes@v5e"))
    # the elbow lands at the same order as the paper's N~8 (H100-measured)
    assert 4 <= elbow <= 32, elbow
    # saturated holder stays far below the ~3 ms splice (paper: <= 0.4 ms)
    t256, _, _ = kernel_cost_s(256, CT)
    rows.append(row("fig4b/saturated@N256_vs_splice", t256 * 1e6,
                    "derived:kernel-flops-bytes@v5e",
                    splice_ratio=round(2.9e-3 / t256, 1)))

    # §6.3: sparse holder cost tracks the selection budget, not store size
    for store in (2048, 32768):
        t, _, _ = kernel_cost_s(8, 2048)   # k=2048 selected from `store`
        rows.append(row(f"fig4b/sparse_k2048_store{store}", t * 1e6,
                        "derived:selection-budget-bound",
                        store_tokens=store))
    # dense-vs-sparse premium at matched k (gather lengthening): modeled as
    # the block-gather's extra index traffic — small, bounded
    for k, prem in C.SPARSE_PREMIUM.items():
        t, _, _ = kernel_cost_s(8, k)
        rows.append(row(f"fig4b/sparse_premium@k{k}", t * prem * 1e6,
                        "model:paper-premium-x-kernel-cost",
                        premium=prem))
    return rows
