"""Where the fused execution path's wall time goes (ISSUE 8).

Runs the frozen mixed_congested trace through the fused/overlapped
shard_map backend and reads the backend's own phase accumulator
(`phase_wall_total`) — the four phases of `_execute_overlapped`:

  * stack    — host-side shard assembly + the ONE batched device_put
               per step (`_StackBatch.commit`);
  * dispatch — issuing every group's fused jitted program without
               blocking (async dispatch; compile cost lands here on the
               cold rep, warm reps are just launch overhead);
  * barrier  — the single per-step block_until_ready over all launched
               tasks (this is where the device compute is actually
               waited out);
  * merge    — wall attribution + stage apportioning + on-device merges
               of the committed partials.

Mirrors benchmarks/profile_planner.py for the execution side. Needs an
8-device mesh (the mesh size is fixed at jax import, so the CALLER sets
XLA_FLAGS):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/profile_exec.py [--reps N]
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time


def profile(repetitions: int, serial: bool = False) -> dict:
    import jax
    if len(jax.devices()) < 8:
        raise SystemExit(
            "profile_exec needs an 8-device mesh: set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before python starts")
    tests_dir = str(pathlib.Path(__file__).resolve().parent.parent / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    from engine_scenarios import SCENARIOS
    from repro.serving.backends import ShardMapExecBackend

    backend = ShardMapExecBackend(fused=not serial)
    per_rep = []
    for _ in range(repetitions):
        eng, steps = SCENARIOS["mixed_congested"](backend)
        t0 = time.perf_counter()
        for reqs in steps:
            eng.schedule_step(reqs)
        per_rep.append(time.perf_counter() - t0)
    return {"reps": per_rep, "split": dict(backend.phase_wall_total),
            "last_step_split": dict(backend.phase_wall),
            "mode": "serial" if serial else "fused"}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=3,
                    help="engines to run through one backend (rep 0 cold)")
    ap.add_argument("--serial", action="store_true",
                    help="profile the serial staged_call chain instead "
                         "(no phase split: it has no stack/dispatch/"
                         "barrier structure)")
    a = ap.parse_args()
    out = profile(a.reps, a.serial)
    print(f"mode {out['mode']}; per-rep wall "
          + " ".join(f"{1000 * t:.1f}ms" for t in out["reps"])
          + " (rep 0 cold: compiles land there)")
    total = sum(out["split"].values())
    if not out["split"]:
        print("  (no phase split recorded — serial mode bypasses "
              "_execute_overlapped)")
        return
    print(f"phase split over all reps ({1000 * total:.1f} ms attributed):")
    for name, v in sorted(out["split"].items(), key=lambda kv: -kv[1]):
        share = v / total if total else 0.0
        print(f"  {name:10s} {1000 * v:8.2f} ms  ({share:5.1%})")
    last = sum(out["last_step_split"].values())
    print(f"warmest step ({1000 * last:.1f} ms): "
          + ", ".join(f"{k} {1000 * v:.2f}ms"
                      for k, v in sorted(out["last_step_split"].items(),
                                         key=lambda kv: -kv[1])))


if __name__ == "__main__":
    main()
