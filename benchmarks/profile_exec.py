"""Where the fused execution path's wall time goes (ISSUE 8).

Runs the frozen mixed_congested trace through the fused/overlapped
shard_map backend and reads the backend's own phase accumulator
(`phase_wall_total`) — the four phases of `_execute_overlapped`:

  * stack    — host-side shard assembly + the ONE batched device_put
               per step (`_StackBatch.commit`);
  * dispatch — issuing every group's fused jitted program without
               blocking (async dispatch; compile cost lands here on the
               cold rep, warm reps are just launch overhead);
  * barrier  — the single per-step block_until_ready over all launched
               tasks (this is where the device compute is actually
               waited out);
  * merge    — wall attribution + stage apportioning + on-device merges
               of the committed partials.

Mirrors benchmarks/profile_planner.py for the execution side. Needs an
8-device mesh (the mesh size is fixed at jax import, so the CALLER sets
XLA_FLAGS):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python benchmarks/profile_exec.py [--reps N]
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time


def profile(repetitions: int, serial: bool = False,
            pipeline_depth: int = 1) -> dict:
    import jax
    if len(jax.devices()) < 8:
        raise SystemExit(
            "profile_exec needs an 8-device mesh: set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before python starts")
    tests_dir = str(pathlib.Path(__file__).resolve().parent.parent / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    from engine_scenarios import SCENARIOS
    from repro.serving.backends import ShardMapExecBackend
    from repro.serving.engine import EngineConfig

    backend = ShardMapExecBackend(fused=not serial)
    per_rep, overlap = [], []
    plan_wall, barrier0 = 0.0, sum(
        v for k, v in backend.phase_wall_total.items() if k == "barrier")
    for _ in range(repetitions):
        eng, steps = SCENARIOS["mixed_congested"](
            backend, cfg=EngineConfig(pipeline_depth=pipeline_depth))
        t0 = time.perf_counter()
        eng.run(iter(steps))
        per_rep.append(time.perf_counter() - t0)
        overlap.append(eng.planner_overlap_s)
        plan_wall += sum(eng.plan_walls)
    return {"reps": per_rep, "split": dict(backend.phase_wall_total),
            "last_step_split": dict(backend.phase_wall),
            "mode": "serial" if serial else "fused",
            "pipeline_depth": pipeline_depth,
            "plan_wall_s": plan_wall,
            "overlap_per_rep": overlap,
            "device_wall_s": backend.phase_wall_total.get("barrier", 0.0)
            - barrier0}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=3,
                    help="engines to run through one backend (rep 0 cold)")
    ap.add_argument("--serial", action="store_true",
                    help="profile the serial staged_call chain instead "
                         "(no phase split: it has no stack/dispatch/"
                         "barrier structure)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="engine pipeline depth (ISSUE 10): >= 2 plans "
                         "step N+1 while step N's device work runs, and "
                         "the plan-overlap row below becomes non-zero")
    a = ap.parse_args()
    out = profile(a.reps, a.serial, a.pipeline_depth)
    print(f"mode {out['mode']}; per-rep wall "
          + " ".join(f"{1000 * t:.1f}ms" for t in out["reps"])
          + " (rep 0 cold: compiles land there)")
    total = sum(out["split"].values())
    if not out["split"]:
        print("  (no phase split recorded — serial mode bypasses "
              "_execute_overlapped)")
        return
    print(f"phase split over all reps ({1000 * total:.1f} ms attributed):")
    for name, v in sorted(out["split"].items(), key=lambda kv: -kv[1]):
        share = v / total if total else 0.0
        print(f"  {name:10s} {1000 * v:8.2f} ms  ({share:5.1%})")
    last = sum(out["last_step_split"].values())
    print(f"warmest step ({1000 * last:.1f} ms): "
          + ", ".join(f"{k} {1000 * v:.2f}ms"
                      for k, v in sorted(out["last_step_split"].items(),
                                         key=lambda kv: -kv[1])))
    # plan-overlap row (ISSUE 10): attribute the pipelining win instead
    # of leaving it as a per-rep wall ratio
    hidden = sum(out["overlap_per_rep"])
    frac = hidden / out["plan_wall_s"] if out["plan_wall_s"] else 0.0
    print(f"plan overlap (depth {out['pipeline_depth']}): plan wall "
          f"{1000 * out['plan_wall_s']:.2f}ms, device (barrier) wall "
          f"{1000 * out['device_wall_s']:.2f}ms, hidden "
          f"{1000 * hidden:.2f}ms ({frac:.1%} of plan wall)")


if __name__ == "__main__":
    main()
