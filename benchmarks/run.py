"""Benchmark harness entry: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <substr>]

Prints ``name,us_per_call,derived`` CSV (with per-row extras as a trailing
JSON column) and writes benchmarks/results/benchmarks.json.
"""

from __future__ import annotations

import argparse
import importlib
import json
import pathlib
import time
import traceback

BENCHES = [
    "bench_payload_sweep",       # Table 1
    "bench_fabric_fit",          # Table 2
    "calibrate_fabric",          # measured fabric tables (ROADMAP item)
    "bench_primitive_costs",     # Fig 1b
    "bench_crossover_map",       # Fig 3b
    "bench_scatter_gather",      # Fig 4a
    "bench_holder_compute",      # Fig 4b
    "bench_staging_elbow",       # Fig 5b
    "bench_fabric_robustness",   # Fig 6
    "bench_congestion",          # Fig 7
    "bench_host_overhead",       # §5.3
    "bench_wire_bytes_hlo",      # §2.1/§5.2 measured from compiled HLO
    "bench_route_schedules",     # beyond-paper: pairwise/fanout/ring bytes
    "bench_serving_steadystate",  # §6.3/§8 multi-step scheduler throughput
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    all_rows = []
    failures = []
    print("name,us_per_call,derived")
    for name in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run()
        except Exception as e:                           # noqa: BLE001
            failures.append((name, traceback.format_exc()))
            print(f"{name}/ERROR,,{e!r}")
            continue
        for r in rows:
            us = "" if r.get("us_per_call") is None else r["us_per_call"]
            extras = {k: v for k, v in r.items()
                      if k not in ("name", "us_per_call", "derived")}
            suffix = (" " + json.dumps(extras, default=str)) if extras else ""
            print(f"{r['name']},{us},{r['derived']}{suffix}")
        all_rows.extend(rows)
        print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
              flush=True)

    out = pathlib.Path(__file__).parent / "results" / "benchmarks.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=1, default=str))
    if failures:
        for n, tb in failures:
            print(f"\n=== {n} FAILED ===\n{tb}", flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
