"""Fig 6 — fabric robustness at the decode point (M_q=256, c_t=2048):
route stays 1-3 orders below fetch/local from SSD-tier to NVLink-tier BW;
the five measured fabrics cluster within 1.5x because route-RT tracks
single-dispatch rate, not link peak."""

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core.constants import Fabric

from benchmarks.common import row

MQ, CT = 256, 2048


def run():
    rows = []
    # (a) model sweep across four orders of magnitude of BW
    for bw_gbps in (0.2, 1, 5, 25, 100, 300, 1000):
        fab = Fabric("sweep", 16e-6, bw_gbps * 1e9, bw_gbps * 1e9)
        tr = cm.t_route_transport(fab, MQ)
        tf = cm.t_fetch(fab, CT)
        tl = cm.t_local(CT)
        rows.append(row(f"fig6a/route@bw{bw_gbps}GBps", tr * 1e6, "model",
                        fetch_us=round(tf * 1e6, 1),
                        local_us=round(tl * 1e6, 1)))
        assert tr < tf and tr < tl, bw_gbps
    # route loses only when BW degrades below ~0.2 GB/s (congestion floor)
    bw_lose = MQ * cm.MLA_PAYLOAD.qp_bytes / cm.t_splice(CT)
    rows.append(row("fig6a/route_loses_below_GBps", None, "model",
                    bw_GBps=round(bw_lose / 1e9, 3)))
    assert bw_lose / 1e9 < 0.3

    # (b) five measured fabrics cluster at decode
    ts = {}
    for name in ("h100_ibgda", "h100_nvlink4", "a100_nvlink3",
                 "rtx6000_pcie5", "a40_pcie4"):
        fab = C.fabric(name)
        t = cm.t_route_transport(fab, MQ, include_launch=True)
        ts[name] = t
        rows.append(row(f"fig6b/route@{name}", t * 1e6,
                        "model:fabric-constants",
                        link_peak_GBps=fab.link_peak_Bps / 1e9,
                        dispatch_GBps=fab.bw_Bps / 1e9))
    spread = max(ts.values()) / min(ts.values())
    rows.append(row("fig6b/five_fabric_spread", None, "model",
                    ratio=round(spread, 2)))
    assert spread < 1.5
    # dispatch-bound: the same H100's NVLink4 (125 GB/s pair peak) moves a
    # single dispatch no faster than its cross-node IBGDA
    rows.append(row("fig6b/nvlink4_vs_ibgda_dispatch", None, "model",
                    nvlink_GBps=C.fabric("h100_nvlink4").bw_Bps / 1e9,
                    ibgda_GBps=C.fabric("h100_ibgda").bw_Bps / 1e9))
    return rows
