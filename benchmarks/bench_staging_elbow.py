"""Fig 5b — holder-side K-stream staging elbow (policy simulation).

The CUDA copy-engine mechanism does not transfer to TPU (DESIGN.md §8);
we keep the POLICY (cap staging parallelism at the elbow) and reproduce
the elbow's shape with a queueing simulation: C=8 parallel copy engines,
per-stream issue overhead, scheduler oversubscription penalty beyond C.
"""

import numpy as np

from benchmarks.common import row

N_ENGINES = 8
COPY_MS = 1.0                 # one chunk stage
ISSUE_MS = 0.02               # per-stream issue overhead
OVERSUB_MS = 0.15             # scheduler penalty per stream beyond engines
N_REQS = 64


def simulate(k_streams: int) -> tuple:
    """Deterministic service simulation: N_REQS staged copies across
    k_streams streams multiplexed onto N_ENGINES engines."""
    engines = min(k_streams, N_ENGINES)
    oversub = max(0, k_streams - N_ENGINES) * OVERSUB_MS
    # each wave runs `engines` copies in parallel
    waves = int(np.ceil(N_REQS / engines))
    per_copy = COPY_MS + ISSUE_MS * k_streams + oversub
    p50 = per_copy * (waves / 2)          # median request waits half the waves
    floor = per_copy                      # steady-state inter-completion
    return p50, floor


def run():
    rows = []
    base_p50, base_floor = simulate(1)
    best = None
    for k in (1, 2, 4, 8, 16):
        p50, floor = simulate(k)
        rows.append(row(f"fig5b/staging@K{k}", p50 * 1e3, "sim:queueing",
                        floor_ms=round(floor, 3),
                        p50_vs_serial_pct=round(100 * (1 - p50 / base_p50), 1)))
        if best is None or p50 < best[1]:
            best = (k, p50)
    rows.append(row("fig5b/elbow_K", best[0], "sim:queueing"))
    assert best[0] == 8                   # the policy constant the engine uses
    # K=16 regresses (oversubscription), K=1 is the serial baseline
    assert simulate(16)[0] > simulate(8)[0]
    return rows
