"""§2.1/§5.2 MEASURED on our own system: compile the ROUTE and FETCH
shard_map programs on an 8-instance mesh and read the actual collective
bytes off the compiled HLO — the byte asymmetry as the compiler sees it.

Runs in a subprocess (needs 8 host devices; benches keep 1)."""

import json
import os
import pathlib
import subprocess
import sys

from benchmarks.common import row

ROOT = pathlib.Path(__file__).resolve().parent.parent

_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.merge import Partial
from repro.core.routing import route_fanout, route_pairwise
from repro.core.splice import fetch_chunk
from repro.distributed.hlo_costs import analyse_hlo
from repro.models.mla import MLAConfig

CFG = MLAConfig()                      # real V2 geometry: d_qk=576, d_v=512
NI, B, S_LOCAL, CT = 8, 32, 2048, 2048
mesh = compat.make_mesh((NI,), ("instance",))

def route_prog(q, ckv, valid):
    return route_pairwise(CFG, q, ckv,
                          Partial.identity(q.shape[:-1], CFG.kv_lora_rank),
                          holder=3, requester=0, axis="instance",
                          wire_dtype=jnp.bfloat16)   # paper 1032-B partial

def fetch_prog(pool, ckv):
    return fetch_chunk(pool, ckv[:CT], delta=128, dst_offset=0, cfg=CFG,
                       holder=3, requester=0, axis="instance")

out = {}
q = jax.ShapeDtypeStruct((NI * B, CFG.n_heads, CFG.d_qk), jnp.bfloat16)
ckv = jax.ShapeDtypeStruct((NI * S_LOCAL, CFG.d_qk), jnp.bfloat16)
valid = jax.ShapeDtypeStruct((NI * S_LOCAL,), jnp.bool_)
pool = jax.ShapeDtypeStruct((NI * S_LOCAL, CFG.d_qk), jnp.bfloat16)

sm = jax.jit(compat.shard_map(route_prog, mesh=mesh,
                           in_specs=(P("instance"), P("instance"),
                                     P("instance")),
                           out_specs=Partial(o=P("instance"),
                                             m=P("instance"),
                                             l=P("instance"))))
txt = sm.lower(q, ckv, valid).compile().as_text()
c = analyse_hlo(txt, NI)
out["route"] = {"wire": c.collective_wire_bytes,
                "result": c.collective_result_bytes}

sm2 = jax.jit(compat.shard_map(fetch_prog, mesh=mesh,
                            in_specs=(P("instance"), P("instance")),
                            out_specs=P("instance")))
txt2 = sm2.lower(pool, ckv).compile().as_text()
c2 = analyse_hlo(txt2, NI)
out["fetch"] = {"wire": c2.collective_wire_bytes,
                "result": c2.collective_result_bytes}
out["q_rows"] = B
out["ct"] = CT
print("RESULT " + json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PROG], capture_output=True,
                       text=True, env=env, cwd=str(ROOT), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("RESULT ")][0][7:])
    rows = []
    # XLA:CPU float-normalizes bf16 collectives to f32 (verified on a bare
    # bf16 ppermute), inflating BOTH sides 2x vs the TPU target where bf16
    # collectives are native — the ratio is unaffected; the tpu_native
    # columns divide the payload terms back.
    route_b = data["route"]["wire"]
    fetch_b = data["fetch"]["wire"]
    rows.append(row("hlo/route_wire_bytes", None,
                    "measured:compiled-HLO@8dev(cpu-f32-normalized)",
                    bytes=int(route_b), tpu_native_bytes=int(route_b // 2),
                    q_rows=data["q_rows"]))
    rows.append(row("hlo/fetch_wire_bytes", None,
                    "measured:compiled-HLO@8dev(cpu-f32-normalized)",
                    bytes=int(fetch_b), tpu_native_bytes=int(fetch_b // 2),
                    chunk_tokens=data["ct"]))
    rows.append(row("hlo/fetch_over_route", None,
                    "measured:compiled-HLO@8dev",
                    ratio=round(fetch_b / route_b, 1)))
    # model-vs-measured agreement at this exact shape: 512 absorbed rows x
    # (q+p) vs c_t x b_KV (one layer)
    from repro.core import cost_model as cm
    model_route = cm.route_wire_bytes(data["q_rows"] * 16)
    model_fetch = cm.fetch_wire_bytes(data["ct"])
    rows.append(row("hlo/model_agreement", None, "model-vs-measured",
                    model_ratio=round(model_fetch / model_route, 2),
                    measured_ratio=round(fetch_b / route_b, 2)))
    # the measured asymmetry: fetching the 2k chunk moves far more bytes
    # than routing the decode queries (paper: >=76% fewer at M_q<=256;
    # our per-instance M_q = 32 rows x 16 heads = 512 absorbed rows)
    assert fetch_b > 2 * route_b, (fetch_b, route_b)
    return rows
