"""Fig 7 — route under self-congestion: flat through K<=2 flows, rises at
full subscription (K=3), and the route-vs-fetch ranking never inverts.

Two views of the same §8 effect:

  * the closed-form premium (t_route_congested) the predicate prices with;
  * the overlap-aware timeline (repro.serving.timeline), where K flows'
    wire stages SERIALIZE on one (link, fabric) resource and the queueing
    emerges from the schedule instead of the formula. The timeline rows
    report makespan, overlap efficiency (makespan / sum-of-stages) and the
    ratio to the old max-reduce price — at K>=4 the makespan strictly
    exceeds what the independent-price max reported.
"""

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.serving import timeline as TL

from benchmarks.common import row


def run():
    fab = C.fabric("h100_ibgda")
    rows = []
    for mq in (256, 1024):
        t0 = cm.t_route_congested(fab, mq, 0)
        for k in (0, 1, 2, 3):
            t = cm.t_route_congested(fab, mq, k)
            rows.append(row(f"fig7/route@mq{mq}_K{k}", t * 1e6,
                            "model:congestion",
                            vs_K0_pct=round(100 * (t / t0 - 1), 1)))
    # paper anchors: +119% at (1024, K=3); flat through K=2; never inverts
    r = cm.t_route_congested(fab, 1024, 3) / cm.t_route_congested(fab, 1024, 0)
    rows.append(row("fig7/K3_rise@mq1024", None, "model:congestion",
                    rise_pct=round((r - 1) * 100, 1)))
    assert abs(r - 2.19) < 0.35
    assert cm.t_splice(2048) / cm.t_route_congested(fab, 1024, 3) > 10

    # -- timeline view: K flows serialized on one link ----------------------
    mq = 1024
    for k in (1, 2, 4, 8):
        stages = cm.route_stages(fab, mq)
        flows = [TL.transport_flow(f"route#{i}", stages,
                                   link_res=TL.link(0, 0),
                                   holder_sm=TL.sm(0),
                                   requester_sm=TL.sm(1 + i))
                 for i in range(k)]
        t = TL.simulate(flows)
        old = cm.t_route_congested_full(fab, mq, k)
        rows.append(row(f"fig7/timeline@mq{mq}_K{k}", t.makespan_s * 1e6,
                        "model:timeline",
                        overlap_efficiency=round(t.overlap_efficiency, 3),
                        vs_max_reduce=round(float(t.makespan_s / old), 3)))
        if k == 1:
            # one flow: the timeline IS the scalar price
            assert abs(t.makespan_s - old) <= 1e-9 * old
        if k >= 4:
            # serialized wire: the makespan strictly exceeds what the old
            # independent max-reduce (here = the congested single price)
            # reported for the step
            assert t.makespan_s > old
    return rows
