"""Fig 7 — route under self-congestion: flat through K<=2 flows, rises at
full subscription (K=3), and the route-vs-fetch ranking never inverts."""

from repro.core import constants as C
from repro.core import cost_model as cm

from benchmarks.common import row


def run():
    fab = C.fabric("h100_ibgda")
    rows = []
    for mq in (256, 1024):
        t0 = cm.t_route_congested(fab, mq, 0)
        for k in (0, 1, 2, 3):
            t = cm.t_route_congested(fab, mq, k)
            rows.append(row(f"fig7/route@mq{mq}_K{k}", t * 1e6,
                            "model:congestion",
                            vs_K0_pct=round(100 * (t / t0 - 1), 1)))
    # paper anchors: +119% at (1024, K=3); flat through K=2; never inverts
    r = cm.t_route_congested(fab, 1024, 3) / cm.t_route_congested(fab, 1024, 0)
    rows.append(row("fig7/K3_rise@mq1024", None, "model:congestion",
                    rise_pct=round((r - 1) * 100, 1)))
    assert abs(r - 2.19) < 0.35
    assert cm.t_splice(2048) / cm.t_route_congested(fab, 1024, 3) > 10
    return rows
