"""Where the planner's remaining wall time goes (ISSUE 6).

Wraps the plan -> execute critical path of the steady-state bench
workload with perf_counter probes — no in-source instrumentation, the
hot path stays clean — and splits one 128-step x 64-agent run into:

  * plan_step        — phase 1-4 of the columnar planner (pair/group
                       assembly, decide, §8 occupancy, StepPlanArrays);
  * execute          — the analytic backend (flow build + scheduling);
  * simulate_arrays  — the heap scheduler inside execute;
  * flow_arrays      — StepPlanArrays -> FlowArrays columnarization;
  * accounting       — schedule_step outside plan+execute: record
                       materialization (StepPlan.records) + StepStats.
                       NOT part of sched_wall_s / decisions_per_sec.

Run:

    PYTHONPATH=src:. python benchmarks/profile_planner.py [--steps N]
"""

from __future__ import annotations

import argparse
import time

import repro.serving.plan as PL
import repro.serving.timeline as TL


def profile(n_steps: int, agents: int, seed: int = 0) -> dict:
    acc: dict = {}

    def clock(name, fn):
        def wrapped(*a, **k):
            t0 = time.perf_counter()
            r = fn(*a, **k)
            acc[name] = acc.get(name, 0.0) + time.perf_counter() - t0
            return r
        return wrapped

    TL.simulate_arrays = clock("simulate_arrays", TL.simulate_arrays)
    PL.StepPlanArrays.flow_arrays = clock("flow_arrays",
                                          PL.StepPlanArrays.flow_arrays)

    # import AFTER patching so the engine binds the wrapped callables
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                        materialize_trace, register_corpus)

    eng = ServingEngine(16, 64 * 2048, cfg=EngineConfig(),
                        instances_per_pod=8)
    eng.plan_step = clock("plan_step", eng.plan_step)
    eng.backend.execute = clock("execute", eng.backend.execute)

    w = WorkloadConfig(n_steps=n_steps, agents=agents, n_corpus_chunks=48,
                       chunk_tokens=2048, session_steps=(8, 64),
                       selection_frac=0.1, seed=seed)
    cids = register_corpus(eng, w)
    steps = materialize_trace(agentic_trace(w, eng, cids))
    t0 = time.perf_counter()
    for reqs in steps:
        eng.schedule_step(reqs)
    total = time.perf_counter() - t0

    sched_wall = sum(s.sched_wall_s for s in eng.stats)
    priced = sum(s.n_priced for s in eng.stats)
    acc["accounting (outside sched_wall)"] = (
        total - acc["plan_step"] - acc["execute"])
    acc["execute: other"] = (acc["execute"] - acc["simulate_arrays"]
                             - acc.get("flow_arrays", 0.0))
    return {"total_s": total, "sched_wall_s": sched_wall,
            "decisions_per_sec": priced / sched_wall if sched_wall else 0.0,
            "split": acc,
            # cache effectiveness (ISSUE 9): throughput regressions are
            # attributable — a warm run that stops hitting these is slow
            # for a DIFFERENT reason than one that was never warm
            "planner_cache": eng.planner_cache_stats(),
            "sim_memo": TL.sim_memo_stats()}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=128)
    ap.add_argument("--agents", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    out = profile(a.steps, a.agents, a.seed)
    print(f"total wall      {1000 * out['total_s']:8.1f} ms")
    print(f"sched wall      {1000 * out['sched_wall_s']:8.1f} ms "
          f"({out['decisions_per_sec']:,.0f} decisions/sec)")
    for name, v in sorted(out["split"].items(), key=lambda kv: -kv[1]):
        print(f"  {name:32s} {1000 * v:8.2f} ms")
    pc = out["planner_cache"]
    print("planner caches  "
          + ", ".join(f"{k}={v}" for k, v in pc.items() if v))
    print("schedule memo   "
          + ", ".join(f"{k}={v}" for k, v in out["sim_memo"].items()))


if __name__ == "__main__":
    main()
