"""Table 2 — the affine model T_route = T_probe + M_q(q+p)/BW re-fits all
five measured fabrics with its own two constants; MAPE in the amortised
regime (M_q >= 512) matches the paper's 2-7% band."""

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm

from benchmarks.common import row

FABRICS = ["h100_ibgda", "h100_nvlink4", "a100_nvlink3", "rtx6000_pcie5",
           "a40_pcie4"]
MQS = [1, 4, 16, 64, 128, 256, 512, 1024, 2048, 4096]


def run():
    rows = []
    for name in FABRICS:
        fab = C.fabric(name)
        # synthetic "measurement": transport + the fixed kernel turnaround
        # the linear model omits (the small-M_q residual, §4.3)
        measured = [cm.t_route_transport(fab, m, include_launch=True)
                    for m in MQS]
        amort = [(m, t) for m, t in zip(MQS, measured) if m >= 512]
        fit = cm.fit_affine([m for m, _ in amort], [t for _, t in amort])
        pred_amort = [cm.t_route_transport(fab, m) for m, _ in amort]
        mape_a = cm.mape(pred_amort, [t for _, t in amort])
        pred_full = [cm.t_route_transport(fab, m) for m in MQS]
        mape_f = cm.mape(pred_full, measured)
        rows.append(row(f"table2/{name}", fab.t_probe_s * 1e6,
                        "model-fit:two-constant-affine",
                        bw_GBps=fab.bw_Bps / 1e9,
                        fit_probe_us=round(fit.t_probe_s * 1e6, 2),
                        fit_bw_GBps=round(fit.bw_Bps / 1e9, 2),
                        mape_amortised_pct=round(mape_a * 100, 1),
                        mape_full_pct=round(mape_f * 100, 1)))
        assert mape_a < 0.08, (name, mape_a)
    return rows
