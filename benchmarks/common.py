"""Benchmark harness helpers.

Each bench module exposes run() -> list of row dicts with keys:
  name          — metric id (stable, CSV-friendly)
  us_per_call   — microseconds (model-derived or measured; see source)
  derived       — provenance/notes ("model:<constants>" vs "measured:cpu")
plus free-form extras. run.py aggregates to CSV.

This container is CPU-only: kernel-level wall-times are not meaningful in
absolute terms, so benches report (a) the closed-form cost model evaluated
at the paper's measured constants (validated against the paper's headline
numbers by tests/test_cost_model.py), and (b) structural measurements from
our own compiled artifacts (HLO collective bytes, kernel flop/byte counts),
which ARE meaningful on this box. Provenance is always in `derived`.
"""

from __future__ import annotations

import time
from typing import Callable, List

import numpy as np


def timeit_us(fn: Callable, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us, derived: str, **extra) -> dict:
    r = {"name": name, "us_per_call": (None if us is None
                                       else round(float(us), 3)),
         "derived": derived}
    r.update(extra)
    return r


def emit_csv(rows: List[dict]) -> str:
    lines = ["name,us_per_call,derived"]
    for r in rows:
        us = "" if r.get("us_per_call") is None else r["us_per_call"]
        lines.append(f"{r['name']},{us},{r['derived']}")
    return "\n".join(lines)
