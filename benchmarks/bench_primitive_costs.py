"""Fig 1b — the cost-shape asymmetry: FETCH flat (~3 ms splice) in chunk
size, LOCAL size-scaling, ROUTE two orders below both; fetch/local
crossover at ~75-220 tokens."""

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core import predicate as P

from benchmarks.common import row

CHUNKS = [55, 128, 256, 512, 1024, 2048, 4096]
MQ = 256


def run():
    fab = C.fabric("h100_ibgda")
    rows = []
    for ct in CHUNKS:
        tr = cm.t_route_transport(fab, MQ, include_launch=True)
        tf = cm.t_fetch(fab, ct)
        tl = cm.t_local(ct)
        rows.append(row(f"fig1b/route@ct{ct}", tr * 1e6, "model",
                        fetch_us=round(tf * 1e6, 1),
                        local_us=round(tl * 1e6, 1),
                        route_vs_fetch=round(tf / tr, 1)))
    lo, hi = P.fetch_local_crossover_ct(fab)
    rows.append(row("fig1b/fetch_local_crossover_lo_tokens", lo,
                    "model:c=1.5us/token-layer"))
    rows.append(row("fig1b/fetch_local_crossover_hi_tokens", hi,
                    "model:c=0.5us/token-layer"))
    # route stays >= 1 order below fetch across the whole range
    assert all(cm.t_fetch(fab, ct) / cm.t_route_transport(fab, MQ,
               include_launch=True) > 10 for ct in CHUNKS)
    return rows
