"""Roofline report: aggregate the dry-run JSONs into the EXPERIMENTS.md
tables — per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and a bottleneck note.

    PYTHONPATH=src python -m benchmarks.roofline_report [--update-md]
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def model_flops_per_device(rec: dict) -> float:
    """6*N*D train / 2*N*D inference (N = active params for MoE), per
    device."""
    import jax
    from repro.configs import SHAPES, get_config
    from repro.models import model as MD
    from repro.models.module import split

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    params_abs = jax.eval_shape(
        functools.partial(MD.init_model, cfg), jax.random.PRNGKey(0))
    vals, _ = split(params_abs)
    flat = jax.tree.flatten_with_path(vals)[0]
    total = active = 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(k, "key", str(k)) for k in path]
        if cfg.moe is not None and any(k in ("gate", "up", "down")
                                       for k in keys) \
                and len(leaf.shape) >= 3 \
                and leaf.shape[-3] == cfg.moe.n_experts:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch * 1
        mult = 2.0
    return mult * active * tokens / rec["n_devices"], total, active


def load(mesh_tag="pod1", tag=""):
    recs = []
    for p in sorted(RESULTS.glob(f"*__{mesh_tag}{tag}.json")):
        if tag == "" and p.stem.count("__") != 2:
            continue          # skip tagged variants in the baseline table
        r = json.loads(p.read_text())
        if r.get("ok"):
            recs.append(r)
    return recs


def note_for(rec, terms):
    dom = terms["dominant"]
    if dom == "collective_s":
        return ("shrink/overlap collectives: FSDP gather batching, "
                "SP boundary placement")
    if dom == "memory_s":
        return "raise arithmetic intensity: fuse (Pallas), wider blocks"
    return "compute-bound: near roofline; MXU-align remaining matmuls"


def table(recs) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " roofline frac | MODEL/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = r["roofline"]
        mf, total, active = model_flops_per_device(r)
        hlo = r.get("hlo_flops") or 1.0
        dom_val = max(v for k, v in t.items()
                      if k.endswith("_s") and v) if t.get("dominant") else 0
        frac = (t.get("compute_s") or 0) / dom_val if dom_val else 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{(t.get('compute_s') or 0)*1e3:.2f}ms | "
            f"{(t.get('memory_s') or 0)*1e3:.2f}ms | "
            f"{(t.get('collective_s') or 0)*1e3:.2f}ms | "
            f"{(t.get('dominant') or '-').replace('_s','')} | "
            f"{frac:.3f} | {mf/hlo:.2f} | {note_for(r, t)} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--tag", default="")
    ap.add_argument("--update-md", action="store_true",
                    help="splice the table into EXPERIMENTS.md")
    args = ap.parse_args()
    recs = load(args.mesh, args.tag)
    tbl = table(recs)
    print(f"## Roofline — {len(recs)} cells ({args.mesh}{args.tag})\n")
    print(tbl)
    if args.update_md:
        md = pathlib.Path(__file__).resolve().parent.parent / "EXPERIMENTS.md"
        text = md.read_text()
        marker = "<!-- ROOFLINE_TABLE -->"
        start = text.index(marker)
        end = text.index("\n\nReading the table", start)
        text = (text[:start] + marker + "\n\n" + tbl + text[end:])
        md.write_text(text)
        print(f"\n[updated {md}]")


if __name__ == "__main__":
    main()
