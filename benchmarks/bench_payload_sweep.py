"""Table 1 — IBGDA Q-dispatch across a 10x payload span: the probe and the
effective bandwidth are payload-independent (the empirical basis of the
linear-in-bytes cost term)."""

from repro.core import constants as C
from repro.core import cost_model as cm

from benchmarks.common import row

PAYLOADS = [(900, "synthetic"), (2184, "real"), (4368, "2x"), (8736, "4x")]
MQ = 1024


def run():
    fab = C.fabric("h100_ibgda")
    rows = []
    for qp, tag in PAYLOADS:
        pay = cm.Payload(q_bytes=qp - C.P_ROW_BYTES)
        sig_rt = fab.t_probe_s
        full_rt = cm.t_route_transport(fab, MQ, pay, include_launch=True)
        eff_bw = MQ * qp / (full_rt - sig_rt) / 1e9
        rows.append(row(f"table1/full_rt@{MQ}/qp{qp}_{tag}", full_rt * 1e6,
                        "model:h100_ibgda(16us,25GB/s)+9us-turnaround",
                        sig_rt_us=sig_rt * 1e6,
                        eff_bw_GBps=round(eff_bw, 2)))
    # payload-independence check: effBW spread < 5%
    bws = [r["eff_bw_GBps"] for r in rows]
    rows.append(row("table1/effBW_spread_pct",
                    (max(bws) - min(bws)) / min(bws) * 100,
                    "derived:payload-independence"))
    return rows
