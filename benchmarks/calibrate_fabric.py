"""Multi-backend fabric tables (ROADMAP item): calibrate a fabric's two
affine constants from a payload sweep and write a JSON fabric table.

The paper's extension recipe (abstract, §4.3): a new fabric needs exactly
two measured coefficients — T_probe and the effective dispatch bandwidth.
This CLI runs the (M_q, round-trip) sweep, fits them with
cost_model.fit_affine over the amortised regime (M_q >= 512, where the
fixed kernel-turnaround residual washes out), and writes

    {fabric_name: {t_probe_s, bw_Bps, link_peak_Bps, t_launch_s, notes,
                   mape_amortised_pct, sweep_points}}

which constants.Fabric.load_table() reads back and register_fabrics()
installs, so engines (EngineConfig fabric names) and benchmarks run on
MEASURED rather than paper constants:

    PYTHONPATH=src python -m benchmarks.calibrate_fabric \
        --out benchmarks/results/fabric_table.json
    PYTHONPATH=src python -m repro.launch.serve \
        --fabric-table benchmarks/results/fabric_table.json \
        --intra-fabric tpu_ici_fit

Sweep sources:
  model  — round trips synthesized from the paper-constant closed form
           (+ the §4.3 launch residual, + optional --noise jitter): the
           container has no multi-node fabric, so this validates the
           fit pipeline end-to-end and regenerates the paper table.
  device — round trips measured from real jax device_put transfers of the
           actual routed payload bytes between two local devices (use
           XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU).
           Numbers are only meaningful on real multi-device hardware;
           provenance lands in the row's `notes`.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core.constants import Fabric

from benchmarks.common import row

MQS = (1, 4, 16, 64, 128, 256, 512, 1024, 2048, 4096)
AMORTISED_MQ = 512          # fit window: where the launch residual washes out
DEFAULT_FABRICS = ("h100_ibgda", "h100_nvlink4", "a100_nvlink3",
                   "rtx6000_pcie5", "a40_pcie4", "tpu_ici", "tpu_dcn")


def sweep_model(fab: Fabric, mqs: Sequence[int] = MQS, noise: float = 0.0,
                seed: int = 0,
                payload: cm.Payload = cm.MLA_PAYLOAD
                ) -> List[Tuple[int, float]]:
    """Synthesized 'measurement': transport + the fixed kernel turnaround
    the linear model omits, with optional multiplicative jitter."""
    rng = np.random.RandomState(seed)
    out = []
    for m in mqs:
        t = cm.t_route_transport(fab, m, payload, include_launch=True)
        if noise:
            t *= float(1.0 + noise * rng.randn())
        out.append((m, t))
    return out


def sweep_device(mqs: Sequence[int] = MQS, iters: int = 10,
                 payload: cm.Payload = cm.MLA_PAYLOAD
                 ) -> List[Tuple[int, float]]:
    """Measured round trips: ship M_q routed-payload rows to another jax
    device and back, timed end-to-end (the q out + partial back shape of
    §4.2). Requires >= 2 devices."""
    import jax
    import jax.numpy as jnp
    devs = jax.devices()
    if len(devs) < 2:
        raise RuntimeError(
            f"device sweep needs >= 2 jax devices, have {len(devs)} "
            "(hint: XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    src, dst = devs[0], devs[1]
    out = []
    for m in mqs:
        q = jax.device_put(jnp.zeros((m, payload.q_bytes), jnp.int8), src)
        p = jax.device_put(jnp.zeros((m, payload.p_bytes), jnp.int8), dst)
        jax.block_until_ready((q, p))
        t0 = time.perf_counter()
        for _ in range(iters):
            there = jax.device_put(q, dst)
            back = jax.device_put(p, src)
            jax.block_until_ready((there, back))
        out.append((m, (time.perf_counter() - t0) / iters))
    return out


def fit_sweep(name: str, sweep: List[Tuple[int, float]],
              link_peak_Bps: float = 0.0, notes: str = "",
              payload: cm.Payload = cm.MLA_PAYLOAD) -> Tuple[Fabric, float]:
    """Fit (T_probe, BW) on the amortised window; returns the fitted Fabric
    row plus its amortised-regime MAPE. link_peak defaults to the fitted
    dispatch BW — a single-flow sweep cannot see the coalesced peak, so a
    measured table is conservative for FETCH until a bulk sweep refines it."""
    amort = [(m, t) for m, t in sweep if m >= AMORTISED_MQ]
    if len(amort) < 2:
        raise ValueError(f"{name}: need >= 2 sweep points at M_q >= "
                         f"{AMORTISED_MQ}, have {len(amort)}")
    fit = cm.fit_affine([m for m, _ in amort], [t for _, t in amort],
                        payload)
    fitted = Fabric(name, fit.t_probe_s, fit.bw_Bps,
                    link_peak_Bps or fit.bw_Bps, notes=notes)
    pred = [cm.t_route_transport(fitted, m, payload) for m, _ in amort]
    return fitted, cm.mape(pred, [t for _, t in amort])


def calibrate(fabrics: Sequence[str] = DEFAULT_FABRICS,
              source: str = "model", noise: float = 0.0,
              seed: int = 0) -> Dict[str, dict]:
    """One JSON-able table row per fabric (the load_table format, plus fit
    diagnostics from_json ignores)."""
    table: Dict[str, dict] = {}
    if source == "device":
        sweep = sweep_device()
        fitted, err = fit_sweep("device_fit", sweep,
                                notes="measured:jax-device_put-roundtrip")
        table["device_fit"] = dict(fitted.to_json(),
                                   mape_amortised_pct=round(err * 100, 2),
                                   sweep_points=len(sweep))
        return table
    for name in fabrics:
        ref = C.fabric(name)
        sweep = sweep_model(ref, noise=noise, seed=seed)
        fitted, err = fit_sweep(
            f"{name}_fit", sweep, link_peak_Bps=ref.link_peak_Bps,
            notes=f"fit:payload-sweep(source=model,noise={noise})")
        table[f"{name}_fit"] = dict(fitted.to_json(),
                                    mape_amortised_pct=round(err * 100, 2),
                                    sweep_points=len(sweep))
    return table


def run() -> list:
    """benchmarks.run entry: calibrate every paper fabric from a clean
    model sweep and assert the fit recovers the table constants — the
    round-trip (constants -> sweep -> fit -> constants) is the pipeline's
    correctness check."""
    rows = []
    table = calibrate()
    for name, fitted in ((n, Fabric.from_json(r)) for n, r in table.items()):
        ref = C.fabric(name[:-len("_fit")])
        probe_err = abs(fitted.t_probe_s - ref.t_probe_s) \
            / max(ref.t_probe_s, 1e-12)
        bw_err = abs(fitted.bw_Bps - ref.bw_Bps) / ref.bw_Bps
        rows.append(row(
            f"calibrate/{name}", fitted.t_probe_s * 1e6,
            "fit:affine(amortised M_q>=512) source=model",
            fit_bw_GBps=round(fitted.bw_Bps / 1e9, 2),
            probe_err_pct=round(probe_err * 100, 2),
            bw_err_pct=round(bw_err * 100, 2),
            mape_amortised_pct=table[name]["mape_amortised_pct"]))
        # noiseless model sweep must round-trip the two constants: the
        # launch residual perturbs the intercept slightly, nothing else
        assert bw_err < 0.02, (name, bw_err)
        assert fitted.t_probe_s <= ref.t_probe_s + ref.t_launch_s + 1e-9, name
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fabrics", nargs="*", default=list(DEFAULT_FABRICS),
                    help="paper fabrics to sweep (model source)")
    ap.add_argument("--source", choices=("model", "device"), default="model")
    ap.add_argument("--noise", type=float, default=0.0,
                    help="multiplicative jitter sigma on model sweeps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "results" / "fabric_table.json"))
    args = ap.parse_args(argv)

    table = calibrate(args.fabrics, args.source, args.noise, args.seed)
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(table, indent=1) + "\n")
    for name, r in table.items():
        print(f"[calibrate] {name}: probe {r['t_probe_s']*1e6:.2f}us "
              f"bw {r['bw_Bps']/1e9:.2f}GB/s "
              f"(mape {r['mape_amortised_pct']}%)")
    print(f"[calibrate] wrote {out} ({len(table)} fabrics); load with "
          "repro.core.constants.Fabric.load_table + register_fabrics")


if __name__ == "__main__":
    main()
