"""Steady-state serving under sustained agentic fan-in (§1, §6.3, §8).

Drives the vectorized multi-step scheduler over the trace-driven agentic
workload (repro.serving.workload): 128 steps x 64 concurrent agent
sessions over a Zipf-popular corpus on a 16-instance, 2-pod topology.
Reports:

  * p50/p99 simulated step latency — the MAKESPAN of each step's
    overlap-aware transport timeline (repro.serving.timeline: wire stages
    serialize per (link, fabric), holder compute charged per-instance) —
    warmup and fully-resident (empty) steps excluded;
  * overlap efficiency (makespan / sum-of-stages, 1.0 = fully serial) and
    the makespan / max-reduce ratio — how much latency the old
    independent-price max hid;
  * scheduler decisions/sec — (request, chunk) predicate evaluations per
    wall-clock second, the scheduler's own throughput (the paper's "no
    online calibration" claim cashed out: pricing is a few numpy
    expressions, so a single host schedules hundreds of thousands of
    chunk accesses per second);
  * steady-state residency fraction + replica/eviction counts: the
    amortised-FETCH feedback loop (fetched chunks persist, cold replicas
    retire under pool pressure).

Run directly for the full JSON, or via benchmarks/run.py for CSV rows:

    PYTHONPATH=src python -m benchmarks.bench_serving_steadystate
"""

from __future__ import annotations

import json
from collections import Counter

import numpy as np

from benchmarks.common import row
from repro.serving.engine import (EngineConfig, ServingEngine,
                                  transport_latencies)
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    materialize_trace, register_corpus)

N_STEPS = 128          # >= 100 (acceptance floor)
AGENTS = 64            # >= 64 concurrent requests per step
WARMUP_STEPS = 16


def simulate(n_steps: int = N_STEPS, agents: int = AGENTS,
             seed: int = 0) -> dict:
    eng = ServingEngine(n_instances=16, pool_tokens=64 * 2048,
                        cfg=EngineConfig(), instances_per_pod=8)
    cfg = WorkloadConfig(n_steps=n_steps, agents=agents,
                         n_corpus_chunks=48, chunk_tokens=2048,
                         session_steps=(8, 64), selection_frac=0.1,
                         seed=seed)
    cids = register_corpus(eng, cfg)
    stats = eng.run(agentic_trace(cfg, eng, cids))

    steady = stats[WARMUP_STEPS:]
    # empty (fully-resident) steps schedule nothing: their 0.0 makespan is
    # excluded from the percentiles (transport_latencies skips them)
    lat = transport_latencies(steady)
    wall = sum(s.sched_wall_s for s in stats)
    pairs = sum(s.n_pairs for s in stats)
    priced = sum(s.n_priced for s in stats)
    prim = Counter()
    for s in stats:
        prim.update(s.primitives)
    resident_late = (sum(s.n_resident for s in steady)
                     / max(1, sum(s.n_pairs for s in steady)))
    serial = sum(s.serial_stage_s for s in steady)
    makespan = sum(s.latency_s for s in steady)
    max_reduce = sum(s.max_dispatch_s for s in steady)
    return {
        "steps": len(stats),
        "requests_per_step": agents,
        "pairs_scheduled": pairs,
        "p50_step_latency_us": float(np.percentile(lat, 50) * 1e6),
        "p99_step_latency_us": float(np.percentile(lat, 99) * 1e6),
        "empty_steps_skipped": int(sum(1 for s in steady
                                       if not s.has_transport)),
        # makespan / sum-of-stages over the steady window: 1.0 = fully
        # serial, 1/n = n flows perfectly overlapped (lower = more overlap)
        "overlap_efficiency": makespan / serial if serial else 1.0,
        # how much step latency the old independent max-reduce price hid
        "makespan_vs_max_reduce": makespan / max_reduce if max_reduce else 1.0,
        "pairs_priced": priced,
        "decisions_per_sec": priced / wall if wall else 0.0,
        "sched_wall_s_total": wall,
        "sched_wall_us_p50": float(np.percentile(
            [s.sched_wall_s for s in stats], 50) * 1e6),
        "sched_wall_us_p99": float(np.percentile(
            [s.sched_wall_s for s in stats], 99) * 1e6),
        "steady_resident_frac": resident_late,
        "replicas_spawned": sum(s.replicas_spawned for s in stats),
        "evictions": sum(s.evictions for s in stats),
        "primitive_mix": dict(prim),
        # planner-cache effectiveness for THIS engine/run (ISSUE 9):
        # regressions in decisions_per_sec are attributable to cold caches
        # vs slow code (timeline._SIM_MEMO is process-global; its separate
        # counters are reported by planner_bench per position in the
        # best-of sequence)
        "planner_cache": eng.planner_cache_stats(),
    }


def backend_parity(n_steps: int = 12, agents: int = 8, seed: int = 0) -> dict:
    """ISSUE 3: ONE materialized trace through the analytic AND the exec
    backend (real c^KV arrays, CPU-scale geometry). Reports planner parity
    (identical per-step decisions) and the worst |exec - single-instance
    oracle| output error (§3.3, end-to-end through the scheduler)."""
    from repro.serving.backends import AnalyticBackend, JaxExecBackend
    from repro.serving.backends.jax_exec import max_oracle_err

    def build(backend):
        eng = ServingEngine(n_instances=4, pool_tokens=32 * 256,
                            cfg=EngineConfig(), instances_per_pod=2,
                            backend=backend)
        cfg = WorkloadConfig(n_steps=n_steps, agents=agents,
                             n_corpus_chunks=8, chunk_tokens=256,
                             session_steps=(2, 8), seed=seed)
        cids = register_corpus(eng, cfg)
        return eng, materialize_trace(agentic_trace(cfg, eng, cids))

    ana, steps = build(AnalyticBackend())
    exe, _ = build(JaxExecBackend())
    worst = 0.0
    for reqs in steps:
        ana.schedule_step(reqs)
        exe.schedule_step(reqs)
        worst = max(worst, max_oracle_err(exe, reqs, exe.step_idx))
    keys = [(r.step, r.primitive, r.chunk_id, r.holder, r.m_q_total)
            for r in ana.log]
    parity = keys == [(r.step, r.primitive, r.chunk_id, r.holder,
                       r.m_q_total) for r in exe.log]
    return {"steps": n_steps, "agents": agents,
            "decisions_identical": parity,
            "dispatches": len(exe.log),
            "max_output_err": worst}


def selection_regime(n_steps: int = 24, agents: int = 16,
                     seed: int = 0) -> dict:
    """ISSUE 4: the §5.4 selection regime END-TO-END — the distributed
    indexer scores/selects per step (live IndexerService), the planner
    threads the masks and prices the indexer round trips, the timeline
    schedules the `index` stages on the links. Reports p50/p99 step
    latency plus the indexer stage's share of the summed makespan (how
    much of the step the scoring round trips occupy)."""
    from repro.serving.selection import IndexerService
    eng = ServingEngine(n_instances=8, pool_tokens=64 * 512,
                        cfg=EngineConfig(), instances_per_pod=4,
                        selector=IndexerService())
    cfg = WorkloadConfig(n_steps=n_steps, agents=agents,
                         n_corpus_chunks=12, chunk_tokens=512,
                         session_steps=(4, 16), selection_frac=0.5,
                         k_selected=128, seed=seed)
    cids = register_corpus(eng, cfg)
    stats = eng.run(agentic_trace(cfg, eng, cids))
    lat = transport_latencies(stats)
    makespan = sum(s.latency_s for s in stats)
    index_s = sum(s.stage_totals.get("index", 0.0) for s in stats)
    return {
        "steps": len(stats),
        "requests_per_step": agents,
        "p50_step_latency_us": float(np.percentile(lat, 50) * 1e6),
        "p99_step_latency_us": float(np.percentile(lat, 99) * 1e6),
        "selected_pairs": int(sum(s.n_selected for s in stats)),
        "selection_fallbacks": int(sum(s.selection_fallbacks
                                       for s in stats)),
        # how much of the summed step makespan the indexer round trips
        # occupy — the "indexer latency is a first-class system object"
        # number (IndexCache / DSA, PAPERS.md)
        "index_stage_share": index_s / makespan if makespan else 0.0,
    }


def shard_map_measured(n_steps: int = 6, agents: int = 6,
                       seed: int = 0) -> dict:
    """ISSUE 7: the shard_map backend on a real device mesh — measured
    per-stage wall timings re-scheduled against the analytic model
    (timeline.measured_vs_analytic, the §7 loop). Skips (with the forced-
    host-device recipe) when the process lacks a 4-device mesh: the
    device count is fixed at jax import, so the CALLER sets XLA_FLAGS."""
    import jax
    if len(jax.devices()) < 4:
        return {"skipped": "needs >=4 devices: set XLA_FLAGS="
                           "--xla_force_host_platform_device_count=4"}
    from repro.serving.backends import ShardMapExecBackend
    from repro.serving.backends.jax_exec import max_oracle_err
    eng = ServingEngine(n_instances=4, pool_tokens=32 * 256,
                        cfg=EngineConfig(), instances_per_pod=2,
                        backend=ShardMapExecBackend())
    cfg = WorkloadConfig(n_steps=n_steps, agents=agents, n_corpus_chunks=8,
                         chunk_tokens=128, session_steps=(2, 8), seed=seed)
    cids = register_corpus(eng, cfg)
    worst, ratios = 0.0, []
    for reqs in agentic_trace(cfg, eng, cids):
        eng.schedule_step(reqs)
        worst = max(worst, max_oracle_err(eng, reqs, eng.stats[-1].step))
        rep = eng.measured_reports[-1]
        if rep is not None and rep.analytic.makespan_s > 0:
            ratios.append(rep.makespan_ratio)
    return {"steps": n_steps, "agents": agents, "devices": 4,
            "max_output_err": worst,
            "measured_steps": len(ratios),
            # forced host devices: launch overhead dominates — the SHAPE
            # and the machinery are the artifact, not the absolute ratio
            "makespan_ratio_p50": (float(np.percentile(ratios, 50))
                                   if ratios else None)}


def run() -> list:
    out = simulate()
    par = backend_parity()
    assert par["decisions_identical"], "analytic/exec planner divergence"
    assert par["max_output_err"] < 1e-4, par["max_output_err"]
    sel = selection_regime()
    assert sel["selection_fallbacks"] == 0, "indexer configured yet fellback"
    assert sel["selected_pairs"] > 0
    derived = "model:predicate+congestion measured:scheduler-wall"
    derived_sel = "model:predicate+indexer-service measured:scheduler-wall"
    return [
        row("serving_steadystate/p50_step_latency",
            out["p50_step_latency_us"], derived, **out),
        row("serving_steadystate/p99_step_latency",
            out["p99_step_latency_us"], derived),
        row("serving_steadystate/overlap_efficiency", None, derived,
            overlap_efficiency=round(out["overlap_efficiency"], 4),
            makespan_vs_max_reduce=round(out["makespan_vs_max_reduce"], 4)),
        row("serving_steadystate/decisions_per_sec", None, derived,
            decisions_per_sec=round(out["decisions_per_sec"]),
            sched_wall_s=round(out["sched_wall_s_total"], 6),
            sched_wall_us_p50=round(out["sched_wall_us_p50"], 2),
            sched_wall_us_p99=round(out["sched_wall_us_p99"], 2)),
        row("serving_backend_parity/exec_vs_analytic", None,
            "measured:exec-backend(real arrays) vs analytic planner", **par),
        row("serving_selection/p50_step_latency",
            sel["p50_step_latency_us"], derived_sel, **sel),
        row("serving_selection/p99_step_latency",
            sel["p99_step_latency_us"], derived_sel),
        row("serving_selection/index_stage_share", None, derived_sel,
            index_stage_share=round(sel["index_stage_share"], 4)),
        row("serving_shard_map/measured_vs_analytic", None,
            "measured:shard_map collectives vs analytic timeline",
            **shard_map_measured()),
    ]


# ---------------------------------------------------------------------------
# Planner-throughput artifact + CI floor (ISSUE 6 satellite).
# ---------------------------------------------------------------------------

# PR-4 object-path planner on the same workload (pairs_priced ~11.3k):
# ~8.6k decisions/sec on the machine the ISSUE quotes, 12.5k on the
# dev container this refactor was measured on. Kept here so every
# BENCH_planner.json carries its own baseline context.
PR4_BASELINE_QUOTED = 8_600
PR4_BASELINE_DEV_CONTAINER = 12_500


def planner_bench(out_path: str = "BENCH_planner.json",
                  min_decisions_per_sec: float = 0.0,
                  best_of: int = 3) -> dict:
    """Run the steady-state sim `best_of` times, write the planner
    throughput artifact, and enforce an optional decisions/sec floor
    (the CI smoke — the floor is set WELL below a healthy run so only a
    real regression to object-path speeds trips it, not runner noise)."""
    from repro.serving import timeline as TL
    runs = []
    memo_before = TL.sim_memo_stats()
    memo_deltas = []
    for _ in range(best_of):
        runs.append(simulate())
        memo_after = TL.sim_memo_stats()
        memo_deltas.append({k: memo_after[k] - memo_before[k]
                            for k in memo_after})
        memo_before = memo_after
    # run 1 is COLD: every schedule is computed. Later runs of the same
    # trace hit timeline._SIM_MEMO (transport structures repeating
    # bit-for-bit reuse their schedule) — the steady-state regime the
    # memo exists for. Both are reported; neither is hidden in the other.
    cold = runs[0]
    best = max(runs, key=lambda r: r["decisions_per_sec"])
    payload = {
        "bench": "bench_serving_steadystate.planner_bench",
        "workload": {"steps": N_STEPS, "agents": AGENTS,
                     "pairs_priced": best["pairs_priced"]},
        "decisions_per_sec": round(best["decisions_per_sec"]),
        "decisions_per_sec_cold": round(cold["decisions_per_sec"]),
        "decisions_per_sec_runs": [round(r["decisions_per_sec"])
                                   for r in runs],
        "sched_wall_s": [round(r["sched_wall_s_total"], 6) for r in runs],
        "sched_wall_us_p50": round(best["sched_wall_us_p50"], 2),
        "sched_wall_us_p99": round(best["sched_wall_us_p99"], 2),
        "baseline_pr4_decisions_per_sec": {
            "quoted": PR4_BASELINE_QUOTED,
            "dev_container": PR4_BASELINE_DEV_CONTAINER,
        },
        "speedup_vs_quoted": round(
            best["decisions_per_sec"] / PR4_BASELINE_QUOTED, 2),
        "speedup_vs_dev_container": round(
            best["decisions_per_sec"] / PR4_BASELINE_DEV_CONTAINER, 2),
        "speedup_cold_vs_quoted": round(
            cold["decisions_per_sec"] / PR4_BASELINE_QUOTED, 2),
        "speedup_cold_vs_dev_container": round(
            cold["decisions_per_sec"] / PR4_BASELINE_DEV_CONTAINER, 2),
        # cache effectiveness (ISSUE 9): per-run planner-cache counters
        # (fresh engine each run) and the process-global schedule-memo
        # delta per run — run 1 cold, later runs memo-warm by design
        "planner_cache_cold": cold["planner_cache"],
        "planner_cache_best": best["planner_cache"],
        "sim_memo_per_run": memo_deltas,
    }
    if out_path:
        import pathlib
        pathlib.Path(out_path).write_text(json.dumps(payload, indent=1)
                                          + "\n")
    if best["decisions_per_sec"] < min_decisions_per_sec:
        raise SystemExit(
            f"planner throughput regression: best-of-{best_of} "
            f"{best['decisions_per_sec']:.0f} decisions/sec is below the "
            f"floor {min_decisions_per_sec:.0f} "
            f"(runs: {payload['decisions_per_sec_runs']})")
    return payload


# ---------------------------------------------------------------------------
# Execution-overlap artifact + CI floor (ISSUE 8).
# ---------------------------------------------------------------------------


def _exec_rep(backend, mode_steps: list) -> list:
    """One repetition: a FRESH engine over the frozen mixed_congested
    trace, the (possibly warm) backend reused so jit caches persist.
    Returns the per-step MeasuredReports of transporting steps."""
    import pathlib
    import sys
    tests_dir = str(pathlib.Path(__file__).resolve().parent.parent / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    from engine_scenarios import SCENARIOS
    eng, steps = SCENARIOS["mixed_congested"](backend)
    for reqs in steps:
        eng.schedule_step(reqs)
    reps = [r for r in eng.measured_reports
            if r is not None and r.analytic.makespan_s > 0]
    mode_steps.append(reps)
    return reps


def _exec_mode(fused: bool, repetitions: int) -> dict:
    """Run `repetitions` fresh engines through ONE backend instance.
    Rep 0 is COLD (every fused program compiles); the last rep is WARM
    (executable + buffer caches hit). Reports per-step measured walls and
    measured/analytic ratios for both, plus overlap efficiency."""
    from repro.serving.backends import ShardMapExecBackend
    backend = ShardMapExecBackend(fused=fused)
    all_reps: list = []
    for _ in range(repetitions):
        _exec_rep(backend, all_reps)
    cold, warm = all_reps[0], all_reps[-1]

    def rows(reports):
        return [{"step": r.step,
                 "wall_ms": round(r.wall_s * 1e3, 3),
                 "measured_makespan_ms": round(
                     r.measured.makespan_s * 1e3, 3),
                 "analytic_makespan_us": round(
                     r.analytic.makespan_s * 1e6, 3),
                 "ratio": round(r.makespan_ratio, 1),
                 "overlap_efficiency": round(r.overlap_efficiency, 3),
                 "stage_fills": r.stage_fills} for r in reports]

    def pct(reports, q):
        return float(np.percentile([r.makespan_ratio for r in reports], q))

    return {
        "mode": "fused" if fused else "serial",
        "repetitions": repetitions,
        "cold_steps": rows(cold),
        "warm_steps": rows(warm),
        "cold_ratio_p50": round(pct(cold, 50), 1),
        "warm_ratio_p50": round(pct(warm, 50), 1),
        "warm_ratio_p99": round(pct(warm, 99), 1),
        "warm_wall_ms_p50": round(float(np.percentile(
            [r.wall_s for r in warm], 50)) * 1e3, 3),
        "warm_overlap_efficiency_p50": round(float(np.percentile(
            [r.overlap_efficiency for r in warm], 50)), 3),
        "pool_entries": warm[-1].pool_entries,
        "pool_bytes": warm[-1].pool_bytes,
        "stage_fills_total": int(sum(r.stage_fills
                                     for reps in all_reps for r in reps)),
    }


def _pipeline_mode(depth: int, repetitions: int) -> dict:
    """ISSUE 10: `repetitions` fresh engines at one pipeline depth over
    the frozen mixed_congested trace, ONE warm backend. Reports the step
    wall and the planner-overlap attribution per rep (rep 0 cold — the
    cold rep's dispatch wall is compile time, so only the warm rep's
    hidden fraction is gate material)."""
    import pathlib
    import sys
    import time
    tests_dir = str(pathlib.Path(__file__).resolve().parent.parent / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    from engine_scenarios import SCENARIOS
    from repro.serving.backends import ShardMapExecBackend
    backend = ShardMapExecBackend()
    rows = []
    for _ in range(repetitions):
        eng, steps = SCENARIOS["mixed_congested"](
            backend, cfg=EngineConfig(pipeline_depth=depth))
        t0 = time.perf_counter()
        eng.run(iter(steps))
        wall = time.perf_counter() - t0
        # the first step's plan can never overlap (nothing is in flight
        # yet): the hidden fraction is over the ELIGIBLE plan walls
        eligible = sum(eng.plan_walls[1:])
        rows.append({
            "wall_ms": round(wall * 1e3, 3),
            "wall_per_step_ms": round(wall / len(eng.stats) * 1e3, 3),
            "plan_wall_ms": round(sum(eng.plan_walls) * 1e3, 3),
            "eligible_plan_wall_ms": round(eligible * 1e3, 3),
            "hidden_ms": round(eng.planner_overlap_s * 1e3, 3),
            "hidden_frac": (round(eng.planner_overlap_s / eligible, 4)
                            if eligible else 0.0),
            "replans": eng.misspeculation_replans,
        })
    return {"depth": depth, "repetitions": repetitions,
            "cold": rows[0], "warm": rows[-1],
            "warm_hidden_frac": rows[-1]["hidden_frac"]}


def exec_bench(out_path: str = "BENCH_exec.json",
               max_warm_ratio: float = 0.0,
               min_improvement: float = 0.0,
               repetitions: int = 3,
               min_hidden_frac: float = 0.0) -> dict:
    """ISSUE 8: the serial (PR-7 staged_call chain) and fused/overlapped
    execution paths side by side on the frozen mixed_congested trace over
    an 8-device mesh. The host-independent gate is `min_improvement`
    (serial warm p50 ratio / fused warm p50 ratio — the overlap win
    itself); `max_warm_ratio` is a deliberately generous absolute ceiling
    on the fused warm p50 (forced host devices time-share cores, so raw
    ratios are large and host-dependent — the paper's §7 caveat)."""
    import jax
    if len(jax.devices()) < 8:
        raise SystemExit(
            "exec_bench needs an 8-device mesh: set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before python starts")
    serial = _exec_mode(fused=False, repetitions=repetitions)
    fused = _exec_mode(fused=True, repetitions=repetitions)
    improvement = (serial["warm_ratio_p50"] / fused["warm_ratio_p50"]
                   if fused["warm_ratio_p50"] else float("inf"))
    # ISSUE 10: the same trace lockstep vs pipelined. The gated number is
    # the warm hidden FRACTION (planner wall demonstrably overlapped with
    # the deferred barrier / eligible planner wall) — wall-time ratios on
    # time-shared forced host devices are too noisy to gate, so the
    # lockstep row is informational context
    lockstep = _pipeline_mode(depth=1, repetitions=repetitions)
    pipelined = _pipeline_mode(depth=2, repetitions=repetitions)
    payload = {
        "bench": "bench_serving_steadystate.exec_bench",
        "workload": "tests/engine_scenarios.mixed_congested (8 instances, "
                    "2 transporting steps: 4 hot routes + cold fetch + "
                    "tiny local)",
        "devices": len(jax.devices()),
        "serial": serial,
        "fused": fused,
        # the number the ISSUE 8 tentpole is about: how much closer the
        # fused + overlapped path gets measured wall to the analytic model
        "warm_ratio_improvement": round(improvement, 2),
        "lockstep": lockstep,
        "pipelined": pipelined,
        "gates": {"max_warm_ratio": max_warm_ratio,
                  "min_improvement": min_improvement,
                  "min_hidden_frac": min_hidden_frac},
    }
    if out_path:
        import pathlib
        pathlib.Path(out_path).write_text(json.dumps(payload, indent=1)
                                          + "\n")
    if max_warm_ratio and fused["warm_ratio_p50"] > max_warm_ratio:
        raise SystemExit(
            f"exec overlap regression: fused warm p50 ratio "
            f"{fused['warm_ratio_p50']:.0f} exceeds the ceiling "
            f"{max_warm_ratio:.0f}")
    if min_improvement and improvement < min_improvement:
        raise SystemExit(
            f"exec overlap regression: fused path only improves the warm "
            f"measured/analytic ratio x{improvement:.2f} over serial "
            f"(floor x{min_improvement:.2f})")
    if min_hidden_frac \
            and pipelined["warm_hidden_frac"] < min_hidden_frac:
        raise SystemExit(
            f"pipelining regression: warm depth-2 run hid only "
            f"{pipelined['warm_hidden_frac']:.0%} of the eligible planner "
            f"wall under the device barrier "
            f"(floor {min_hidden_frac:.0%})")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--planner-bench", action="store_true",
                    help="run only the planner-throughput bench and write "
                         "the BENCH_planner.json artifact")
    ap.add_argument("--exec-bench", action="store_true",
                    help="run only the execution-overlap bench (serial vs "
                         "fused shard_map, needs 8 devices) and write the "
                         "BENCH_exec.json artifact")
    ap.add_argument("--out", default="",
                    help="artifact path ('' = per-bench default; with "
                         "--planner-bench/--exec-bench only)")
    ap.add_argument("--min-decisions-per-sec", type=float, default=0.0,
                    help="fail (exit 1) below this floor — the CI smoke")
    ap.add_argument("--best-of", type=int, default=3)
    ap.add_argument("--max-warm-ratio", type=float, default=0.0,
                    help="exec bench: fail if the fused warm p50 "
                         "measured/analytic ratio exceeds this (0 = off)")
    ap.add_argument("--min-improvement", type=float, default=0.0,
                    help="exec bench: fail if serial/fused warm p50 ratio "
                         "improvement is below this (0 = off)")
    ap.add_argument("--repetitions", type=int, default=3,
                    help="exec bench: engines per mode (rep 0 cold, "
                         "last warm)")
    ap.add_argument("--min-hidden-frac", type=float, default=0.0,
                    help="exec bench: fail if the warm depth-2 pipelined "
                         "run hides less than this fraction of the "
                         "eligible planner wall under the device barrier "
                         "(ISSUE 10; 0 = off)")
    a = ap.parse_args()
    if a.planner_bench:
        print(json.dumps(planner_bench(a.out or "BENCH_planner.json",
                                       a.min_decisions_per_sec,
                                       a.best_of), indent=1))
    elif a.exec_bench:
        print(json.dumps(exec_bench(a.out or "BENCH_exec.json",
                                    a.max_warm_ratio, a.min_improvement,
                                    a.repetitions, a.min_hidden_frac),
                         indent=1))
    else:
        print(json.dumps({"steadystate": simulate(),
                          "selection_regime": selection_regime()},
                         indent=1))
