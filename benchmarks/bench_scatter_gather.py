"""Fig 4a — scatter transport under selection: gathering a 2048-entry
selected set across M holders grows ~linearly in M (scattering defeats
bulk coalescing); the route fan-out stays flat at tens of microseconds.
The M-way merge itself is measured on CPU (it is pure math — flat in M).

Since ISSUE 4 the per-M costs are built from the SAME per-holder stage
builders the serving planner prices selection dispatches with
(cost_model.fetch_selected_stages / route_selected_stages — the
distributed indexer service's cost path): M per-holder dispatches, each
an indexer round trip + its share of the gather. The benchmark asserts
the stage sum reproduces the closed-form t_fetch_scattered exactly, so
Fig 4a and the scheduler report from one code path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core.merge import merge_stacked

from benchmarks.common import row, timeit_us

K_SELECTED = 2048
M_Q = 256
D_INDEX = 64                       # lightning-indexer width (core.selection)
KB = K_SELECTED // C.NSA_BLOCK_TOKENS


def run():
    fab = C.fabric("h100_ibgda")
    rows = []
    for m in range(1, 8):
        # the planner's view: one selection FETCH dispatch per holder,
        # each gathering its k/M share after the indexer round trip
        per_holder = dict(cm.fetch_selected_stages(
            fab, K_SELECTED / m, M_Q, KB, D_INDEX))
        gather = m * per_holder["gather"]
        index = m * per_holder["index"]
        # stage identity: M per-holder gathers == the Fig 4a closed form
        closed = cm.t_fetch_scattered(fab, K_SELECTED, m)
        assert abs(gather - closed) <= 1e-12 * closed, (gather, closed)
        tf = gather / cm.MLA_PAYLOAD.n_layers
        # ROUTE under selection stays flat: per-holder masked partial,
        # concurrent sends (the fan-out closed form), budget-scaled compute
        trt = cm.t_route_fanout(fab, M_Q, m)
        rows.append(row(f"fig4a/fetch_gather_per_layer@M{m}", tf * 1e6,
                        "model:selection-service-stages",
                        route_fanout_us=round(trt * 1e6, 1),
                        indexer_roundtrips_us=round(index * 1e6, 1)))
    # paper: ~1.3 -> ~3.9 ms/layer for M=1..7 — linear growth ~3x
    t1 = cm.t_fetch_scattered(fab, K_SELECTED, 1)
    t7 = cm.t_fetch_scattered(fab, K_SELECTED, 7)
    rows.append(row("fig4a/gather_growth_M1_to_M7", None,
                    "model:selection-service-stages",
                    ratio=round(t7 / t1, 2)))
    assert 2.0 < t7 / t1 < 5.0

    # measured (CPU): the M-way online-softmax merge is flat in M
    B, H, dv = 8, 16, 512
    key = jax.random.PRNGKey(0)
    merged_us = {}
    for m in (1, 2, 4, 8):
        o = jax.random.normal(key, (m, B, H, dv))
        mm = jax.random.normal(key, (m, B, H))
        ll = jnp.abs(jax.random.normal(key, (m, B, H))) + 0.5
        f = jax.jit(lambda o, mm, ll: merge_stacked(o, mm, ll).o)
        f(o, mm, ll).block_until_ready()
        merged_us[m] = timeit_us(
            lambda: f(o, mm, ll).block_until_ready())
        rows.append(row(f"fig4a/merge_measured@M{m}", merged_us[m],
                        "measured:cpu-jit"))
    rows.append(row("fig4a/merge_M8_over_M1", None, "measured:cpu-jit",
                    ratio=round(merged_us[8] / merged_us[1], 2)))
    return rows
