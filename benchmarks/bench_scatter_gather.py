"""Fig 4a — scatter transport under selection: gathering a 2048-entry
selected set across M holders grows ~linearly in M (scattering defeats
bulk coalescing); the route fan-out stays flat at tens of microseconds.
The M-way merge itself is measured on CPU (it is pure math — flat in M)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core.merge import merge_stacked

from benchmarks.common import row, timeit_us

K_SELECTED = 2048


def run():
    fab = C.fabric("h100_ibgda")
    rows = []
    for m in range(1, 8):
        tf = cm.t_fetch_scattered(fab, K_SELECTED, m) / cm.MLA_PAYLOAD.n_layers
        trt = cm.t_route_fanout(fab, 256, m)
        rows.append(row(f"fig4a/fetch_gather_per_layer@M{m}", tf * 1e6,
                        "model:scatter",
                        route_fanout_us=round(trt * 1e6, 1)))
    # paper: ~1.3 -> ~3.9 ms/layer for M=1..7 — linear growth ~3x
    t1 = cm.t_fetch_scattered(fab, K_SELECTED, 1)
    t7 = cm.t_fetch_scattered(fab, K_SELECTED, 7)
    rows.append(row("fig4a/gather_growth_M1_to_M7", None, "model:scatter",
                    ratio=round(t7 / t1, 2)))
    assert 2.0 < t7 / t1 < 5.0

    # measured (CPU): the M-way online-softmax merge is flat in M
    B, H, dv = 8, 16, 512
    key = jax.random.PRNGKey(0)
    merged_us = {}
    for m in (1, 2, 4, 8):
        o = jax.random.normal(key, (m, B, H, dv))
        mm = jax.random.normal(key, (m, B, H))
        ll = jnp.abs(jax.random.normal(key, (m, B, H))) + 0.5
        f = jax.jit(lambda o, mm, ll: merge_stacked(o, mm, ll).o)
        f(o, mm, ll).block_until_ready()
        merged_us[m] = timeit_us(
            lambda: f(o, mm, ll).block_until_ready())
        rows.append(row(f"fig4a/merge_measured@M{m}", merged_us[m],
                        "measured:cpu-jit"))
    rows.append(row("fig4a/merge_M8_over_M1", None, "measured:cpu-jit",
                    ratio=round(merged_us[8] / merged_us[1], 2)))
    return rows
