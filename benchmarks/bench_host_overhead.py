"""§5.3 — when host overhead, not wire bytes, decides: the prototype's
TTFT ~ 3.5 ms + 12.5 us * M_q buries the microsecond wire win; the three
named transport reductions (collapsed-response put, holder-compute
amortisation, cross-request dispatcher batching) close the gap. Our
in-graph TPU transport has none of these host terms (DESIGN.md §2) — the
serving engine ships the reduced form natively."""

from repro.core import constants as C
from repro.core import cost_model as cm

from benchmarks.common import row

MQ = 256
CT = 2048


def ttft(m_q: int, collapsed_put: bool, amortised_holder: bool,
         batched_dispatch: bool) -> float:
    base = C.HOST_OVERHEAD_BASE_S
    per_row = C.HOST_OVERHEAD_PER_ROW_S
    if collapsed_put:
        base *= 0.55          # one put instead of the three-put (o, m, l)
    if amortised_holder:
        base *= 0.70          # holder compute overlapped across requests
    if batched_dispatch:
        per_row *= 0.08       # per-request -> per-batch dispatch
    fab = C.fabric("h100_ibgda")
    return base + per_row * m_q + cm.t_route_transport(fab, m_q)


def run():
    rows = []
    fab = C.fabric("h100_ibgda")
    fetch_bb = cm.t_fetch(fab, CT, contiguous=False)    # splice-free bytes-back
    stages = [
        ("prototype", (False, False, False)),
        ("collapsed_put", (True, False, False)),
        ("holder_amortised", (True, True, False)),
        ("dispatcher_batched", (True, True, True)),
    ]
    prev = None
    for name, flags in stages:
        t = ttft(MQ, *flags)
        rows.append(row(f"s53/ttft@{name}", t * 1e6, "model:host-overhead",
                        vs_bytes_back_fetch=round(t / fetch_bb, 2),
                        route_wins=bool(t < fetch_bb)))
        prev = t
    # prototype loses to splice-free fetch at decode; fully reduced wins
    assert ttft(MQ, False, False, False) > fetch_bb
    assert ttft(MQ, True, True, True) < fetch_bb
    # in-graph transport (no host path at all): the wire-byte win is the
    # end-to-end win outright
    rows.append(row("s53/ttft@tpu_in_graph",
                    cm.t_route_transport(C.fabric("tpu_ici"), MQ) * 1e6,
                    "model:no-host-path"))
    return rows
