"""Trace export (ISSUE 9): determinism + schema validity of the Chrome
trace-event / Perfetto JSON on all three golden traces and the selection
trace, and the planned/measured track-group structure."""

import dataclasses
import json
import pathlib

from engine_scenarios import SCENARIOS, selection_scenario
from repro.obs import Obs, Tracer
from repro.obs.trace import (PID_ENGINE, PID_MEASURED, PID_PLANNED,
                             validate_trace)
from repro.serving import timeline as TL

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "selection_trace.json"


def _scenarios():
    """The three golden builders + the frozen selection trace (replayed
    through the numpy-only planner — no jax needed here)."""
    out = dict(SCENARIOS)

    def _selection():
        from repro.serving.selection import ReplaySelector
        return selection_scenario(selector=ReplaySelector(str(FIXTURE)))

    out["selection"] = _selection
    return out


def _traced_run(build):
    eng, steps = build()
    obs = Obs(tracer=Tracer())
    eng.obs = obs
    obs.bind_engine(eng)
    for reqs in steps:
        eng.schedule_step(reqs)
    return eng, obs.tracer.export()


def _timeline_events(doc):
    """Everything except the wall-clock pid (pid 0 carries perf_counter
    times, legitimately different between two runs)."""
    return [ev for ev in doc["traceEvents"] if ev["pid"] != PID_ENGINE]


class TestTraceExport:
    def test_schema_valid_on_all_traces(self):
        for name, build in _scenarios().items():
            _, doc = _traced_run(build)
            assert validate_trace(doc) == [], name
            # and it round-trips through JSON unchanged
            assert json.loads(json.dumps(doc)) == doc, name

    def test_deterministic_on_all_traces(self):
        """Two fresh runs of the same frozen trace export byte-identical
        timeline events (simulated times, stable tid allocation). Only
        the wall-clock pid may differ."""
        for name, build in _scenarios().items():
            _, doc_a = _traced_run(build)
            _, doc_b = _traced_run(build)
            assert (json.dumps(_timeline_events(doc_a))
                    == json.dumps(_timeline_events(doc_b))), name

    def test_planned_track_group_structure(self):
        _, doc = _traced_run(SCENARIOS["mixed_congested"])
        events = doc["traceEvents"]
        thread_names = {(e["pid"], e["args"]["name"]) for e in events
                        if e["ph"] == "M" and e["name"] == "thread_name"}
        planned_tracks = {n for p, n in thread_names if p == PID_PLANNED}
        # one track per (link, fabric) and per holder SM
        assert any(n.startswith("link i") for n in planned_tracks)
        assert any(n.startswith("sm i") for n in planned_tracks)
        # per-dispatch stage spans carry their flow + step
        stage_evs = [e for e in events
                     if e["ph"] == "X" and e["pid"] == PID_PLANNED
                     and e.get("cat") not in ("step",)]
        assert stage_evs
        assert all("flow" in e["args"] and "step" in e["args"]
                   for e in stage_evs)
        stage_names = {e["name"] for e in stage_evs}
        assert {"transfer", "compute"} <= stage_names
        # engine wall spans: plan/execute/account per step
        wall_names = [e["name"] for e in events
                      if e["ph"] == "X" and e["pid"] == PID_ENGINE]
        assert wall_names.count("plan") == 2       # mixed_congested: 2 steps
        assert wall_names.count("execute") == 2
        assert wall_names.count("account") == 2

    def test_steps_tile_without_overlap(self):
        _, doc = _traced_run(SCENARIOS["routed_only"])
        markers = sorted(
            (e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["pid"] == PID_PLANNED
             and e.get("cat") == "step"),
            key=lambda e: e["ts"])
        assert len(markers) == 3
        for a, b in zip(markers, markers[1:]):
            assert a["ts"] + a["dur"] < b["ts"]

    def test_measured_group_renders_from_report(self):
        """A synthetic MeasuredReport (analytic flows, scaled walls)
        renders as a parallel measured track group aligned on the same
        step origin — the planned/measured visual comparison the tentpole
        promises, exercised without a device mesh."""
        eng, steps = SCENARIOS["routed_only"]()
        tracer = Tracer()
        for reqs in steps:
            eng.schedule_step(reqs)
            analytic = eng.timelines[-1]
            measured_flows = [
                dataclasses.replace(f, stages=tuple(
                    dataclasses.replace(s, duration_s=s.duration_s * 40.0)
                    for s in f.stages))
                for f in analytic.flows]
            report = TL.measured_vs_analytic(eng.step_idx, analytic,
                                             measured_flows)
            tracer.add_step(eng.step_idx, analytic, report.measured)
        doc = tracer.export()
        assert validate_trace(doc) == []
        planned = [e for e in doc["traceEvents"]
                   if e["ph"] == "X" and e["pid"] == PID_PLANNED
                   and e.get("cat") == "step"]
        measured = [e for e in doc["traceEvents"]
                    if e["ph"] == "X" and e["pid"] == PID_MEASURED
                    and e.get("cat") == "step"]
        assert len(planned) == len(measured) == 3
        for p, m in zip(sorted(planned, key=lambda e: e["ts"]),
                        sorted(measured, key=lambda e: e["ts"])):
            assert p["ts"] == m["ts"]              # shared step origin
            assert m["dur"] > p["dur"]             # measured walls dominate

    def test_export_writes_file(self, tmp_path):
        _, doc = _traced_run(SCENARIOS["fetch_heavy"])
        tracer = Tracer()
        eng, steps = SCENARIOS["fetch_heavy"]()
        obs = Obs(tracer=tracer)
        eng.obs = obs
        obs.bind_engine(eng)
        for reqs in steps:
            eng.schedule_step(reqs)
        path = tmp_path / "trace.json"
        tracer.export(str(path))
        on_disk = json.loads(path.read_text())
        assert validate_trace(on_disk) == []
        assert (_timeline_events(on_disk) == _timeline_events(doc))
