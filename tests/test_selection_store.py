"""Selection (indexer) utilities + the canonical chunk store, plus
predicate property tests (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core import predicate as P
from repro.core import selection as SEL
from repro.core.chunk_store import ChunkStore
from repro.models.module import KeyGen, split


class TestSelection:
    def test_topk_tokens_and_mask_roundtrip(self):
        scores = jnp.asarray([[0.1, 0.9, 0.3, 0.8, 0.2, 0.7, 0.0, 0.5]])
        idx = SEL.topk_tokens(scores, 3)
        assert set(np.asarray(idx)[0]) == {1, 3, 5}
        mask = SEL.selection_mask(idx, 8)
        assert np.asarray(mask)[0].sum() == 3
        assert all(np.asarray(mask)[0][[1, 3, 5]])

    def test_topk_blocks_selects_max_blocks(self):
        # 4 blocks of 4 tokens; blocks 1 and 3 carry the peaks
        s = np.zeros((1, 16), np.float32)
        s[0, 5] = 9.0
        s[0, 14] = 8.0
        idx = SEL.topk_blocks(jnp.asarray(s), block_tokens=4, k_blocks=2)
        assert set(np.asarray(idx)[0]) == {1, 3}
        mask = SEL.block_mask_to_tokens(idx, 4, 16)
        assert np.asarray(mask)[0].sum() == 8

    def test_topk_blocks_partial_tail_selectable(self):
        """S % block_tokens != 0 (ISSUE 4 bugfix): the score tail pads to
        the block boundary with -inf instead of being truncated, so the
        partial last block can win on its real scores — and
        block_mask_to_tokens agrees on the padded length."""
        s = np.zeros((1, 20), np.float32)          # blocks of 8, 8, 4
        s[0, 18] = 9.0                             # peak IN the tail
        s[0, 2] = 1.0
        idx = SEL.topk_blocks(jnp.asarray(s), block_tokens=8, k_blocks=2)
        assert set(np.asarray(idx)[0]) == {2, 0}
        mask = SEL.block_mask_to_tokens(idx, 8, 20)
        assert np.asarray(mask).shape == (1, 20)   # truncated, not widened
        assert np.asarray(mask)[0].sum() == 8 + 4  # full block + real tail
        # numpy mirror agrees (the serving indexer's host-side path)
        bs = SEL.block_scores(s[0], 8)
        assert bs.shape == (3,) and bs[2] == 9.0

    def test_indexer_scores_shape(self):
        cfg = SEL.IndexerConfig(d_model=32, d_index=8)
        params, _ = split(SEL.init_indexer(KeyGen(jax.random.PRNGKey(0)),
                                           cfg, dtype=jnp.float32))
        xq = jax.random.normal(jax.random.PRNGKey(1), (2, 32))
        keys = SEL.index_keys(params, jax.random.normal(
            jax.random.PRNGKey(2), (64, 32)))
        scores = SEL.index_scores(params, xq, keys)
        assert scores.shape == (2, 64)

    def test_residency_split_partitions_exactly(self):
        idx = np.asarray([3, 17, 40, 41, 63])
        masks = SEL.residency_split(idx, [0, 16, 32, 64])
        assert masks[0].sum() == 1 and masks[0][3]
        assert masks[1].sum() == 1 and masks[1][1]       # 17 - 16
        assert masks[2].sum() == 3
        # distributed selection covers the set exactly once (§5.4)
        assert sum(m.sum() for m in masks) == len(idx)


class TestSelectionDecode:
    def test_deepseek_selection_decode_path(self):
        """The DSA-style top-k decode path (long_500k's sub-quadratic
        attention): selection_k on the smoke config produces finite logits
        and matches the dense path when k >= cache length."""
        import dataclasses
        from repro.configs import get_smoke_config
        from repro.models import model as MD
        cfg0 = get_smoke_config("deepseek_v2_236b")
        params, _ = split(MD.init_model(cfg0, jax.random.PRNGKey(0)))
        B, S = 2, 32
        state = MD.init_decode_state(cfg0, B, S)
        token = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.full((B, 1), S, jnp.int32)
        # k == S+... selection over the whole cache == dense
        cfg_sel = dataclasses.replace(cfg0, selection_k=S)
        dense, _ = MD.decode_step(params, cfg0, state, token, pos,
                                  jnp.int32(0))
        sel, _ = MD.decode_step(params, cfg_sel, state, token, pos,
                                jnp.int32(0))
        np.testing.assert_allclose(np.asarray(sel, np.float32),
                                   np.asarray(dense, np.float32),
                                   atol=1e-2)
        # small k: still finite, different result (actually sparse)
        cfg_k4 = dataclasses.replace(cfg0, selection_k=4)
        out, _ = MD.decode_step(params, cfg_k4, state, token, pos,
                                jnp.int32(0))
        assert np.all(np.isfinite(np.asarray(out, np.float32)))


class TestChunkStore:
    def test_register_lookup_replicate(self):
        s = ChunkStore(4, 10_000)
        c = s.register("doc", holder=1, length=2048)
        assert s.holders_of("doc") == [1]
        s.add_replica("doc", 3)
        assert set(s.holders_of("doc")) == {1, 3}
        assert s.resident_on("doc", 3)

    def test_fork_refcount_and_release(self):
        s = ChunkStore(4, 10_000)
        s.register("doc", 0, 1000)
        forks = [s.fork("doc", i % 4) for i in range(10)]
        assert s.fan_in("doc") == 10         # the N of the §6.3 elbow
        s.append_suffix(forks[0].fork_id, 128)
        assert forks[0].suffix_length == 128
        for f in forks:
            s.release(f.fork_id)
        assert s.fan_in("doc") == 0

    def test_drop_holder_promotes_or_orphans(self):
        s = ChunkStore(4, 10_000)
        s.register("a", 0, 100)
        s.register("b", 0, 100)
        s.add_replica("a", 2)
        orphaned = s.drop_holder(0)
        assert orphaned == ["b"]
        assert s.lookup("a").holder == 2

    def test_pool_exhaustion_raises(self):
        s = ChunkStore(2, 100)
        s.register("a", 0, 80)
        with pytest.raises(MemoryError):
            s.register("c", 0, 50)


class TestPredicateProperties:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 4096), st.integers(32, 8192),
           st.sampled_from(["h100_ibgda", "h100_nvlink4", "tpu_ici",
                            "tpu_dcn"]))
    def test_decision_is_argmin(self, m_q, c_t, fname):
        req = P.Request(m_q=m_q, c_t=c_t, fabric=C.fabric(fname))
        d = P.decide(req)
        best = min(d.costs.values())
        assert d.costs[d.primitive] == best

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 2048), st.integers(64, 4096))
    def test_route_cost_monotone_in_mq(self, m_q, c_t):
        fab = C.fabric("h100_ibgda")
        t1 = cm.t_route_transport(fab, m_q)
        t2 = cm.t_route_transport(fab, m_q + 64)
        assert t2 > t1

    @settings(max_examples=40, deadline=None)
    @given(st.integers(64, 4096))
    def test_fetch_amortisation_monotone(self, c_t):
        fab = C.fabric("h100_ibgda")
        costs = [P.fetch_cost(P.Request(m_q=1, c_t=c_t, fabric=fab,
                                        expected_reuse_steps=r))
                 for r in (1, 10, 100)]
        assert costs[0] >= costs[1] >= costs[2]

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 256), st.integers(256, 4096))
    def test_decode_regime_always_routes(self, m_q, c_t):
        # §5.5 rule 1 as a property: decode-shaped requests on any measured
        # fabric pick ROUTE (one-shot, no selection, holder can compute)
        for fname in ("h100_ibgda", "tpu_ici"):
            d = P.decide(P.Request(m_q=m_q, c_t=c_t,
                                   fabric=C.fabric(fname)))
            assert d.primitive is P.Primitive.ROUTE
