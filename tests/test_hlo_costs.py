"""Validate the trip-count-aware HLO cost parser against XLA's own
cost_analysis (unscanned) and against trip-count scaling (scanned)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.distributed.hlo_costs import analyse_hlo, split_computations


def _body(x, w):
    return jnp.tanh(x @ w), None


def _scanned(x, ws):
    y, _ = jax.lax.scan(_body, x, ws)
    return y


def _unrolled(x, ws):
    for i in range(8):
        x, _ = _body(x, ws[i])
    return x


@pytest.fixture(scope="module")
def compiled_pair():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    cs = jax.jit(_scanned).lower(x, ws).compile()
    cu = jax.jit(_unrolled).lower(x, ws).compile()
    return cs, cu


class TestHloCosts:
    def test_matches_xla_on_unrolled(self, compiled_pair):
        _, cu = compiled_pair
        ours = analyse_hlo(cu.as_text()).flops
        xla = compat.cost_analysis(cu)["flops"]
        assert ours == pytest.approx(xla, rel=0.01)

    def test_scan_trip_count_correction(self, compiled_pair):
        cs, cu = compiled_pair
        ours_scan = analyse_hlo(cs.as_text()).flops
        xla_unrolled = compat.cost_analysis(cu)["flops"]
        # corrected scan flops == unrolled flops (8 matmuls)
        assert ours_scan == pytest.approx(xla_unrolled, rel=0.01)
        # and XLA's own number on the scanned version is ~8x too small
        assert compat.cost_analysis(cs)["flops"] == pytest.approx(
            xla_unrolled / 8, rel=0.01)

    def test_nested_scan(self):
        def inner(x, w):
            return jnp.tanh(x @ w), None

        def outer(x, ws):
            def step(c, w_outer):
                y, _ = jax.lax.scan(inner, c, ws_inner)
                return y @ w_outer, None
            y, _ = jax.lax.scan(step, x, ws)
            return y

        ws_inner = jnp.ones((4, 64, 64))
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
        c = jax.jit(outer).lower(x, ws).compile()
        flops = analyse_hlo(c.as_text()).flops
        # 3 outer iters x (4 inner matmuls + 1) = 15 matmuls of 2*32*64*64
        expect = 15 * 2 * 32 * 64 * 64
        assert flops == pytest.approx(expect, rel=0.05)

    def test_collectives_scaled_by_trips(self):
        mesh = compat.make_mesh((1,), ("x",))

        def f(xs):
            def step(c, x):
                return c + jax.lax.psum(x, "x"), None
            y, _ = jax.lax.scan(step, jnp.zeros((16,)), xs)
            return y

        sm = jax.jit(compat.shard_map_unchecked(
            f, mesh=mesh,
            in_specs=jax.sharding.PartitionSpec("x"),
            out_specs=jax.sharding.PartitionSpec()))
        xs = jax.ShapeDtypeStruct((5, 16), jnp.float32)
        c = sm.lower(xs).compile()
        costs = analyse_hlo(c.as_text(), n_devices=1)
        # 5 loop iterations => ~5 all-reduce executions counted
        n_ar = costs.collective_counts.get("all-reduce", 0)
        assert n_ar >= 5 or not costs.collective_counts  # 1-dev may elide
