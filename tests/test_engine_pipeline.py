"""Pipelined plan/execute (ISSUE 10): depth semantics, bit-identity
against the lockstep oracle, speculation invalidation, and the run()
iterator fix.

The engine's contract is that pipeline_depth is a LATENCY knob, never a
behavior knob: StepStats (minus host wall clock), DispatchRecords and
final residency must be bit-identical at every depth on every workload.
The depth {1,2,4} sweeps here enforce that on the frozen scenarios, the
selection trace, the generated agentic workload, and (under hypothesis)
randomized workload configurations.
"""

import dataclasses

import numpy as np
import pytest

from engine_scenarios import SCENARIOS, selection_scenario
from repro.serving.backends import AnalyticBackend, JaxExecBackend, TINY_MLA
from repro.serving.backends.base import StepTicket, await_step, submit_step
from repro.serving.backends.jax_exec import oracle_partial
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.selection import IndexerService
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    materialize_trace, register_corpus)

DEPTHS = (1, 2, 4)
RTOL, ATOL = 2e-5, 1e-6


def _record_key(r):
    return (r.step, r.primitive, r.chunk_id, r.holder, r.n_requesters,
            r.m_q_total, r.backup, r.fabric_idx, r.link_instance, r.home,
            r.req_ids, r.est_cost_s, r.stages)


def _run_at_depth(build, depth, backend=None, selector=None):
    kw = {"cfg": EngineConfig(pipeline_depth=depth)}
    if selector is not None:
        kw["selector"] = selector
    eng, steps = build(backend, **kw) if backend is not None \
        else build(**kw)
    eng.run(iter(steps))
    return eng


def _assert_engines_identical(a, b, ctx=""):
    assert len(a.stats) == len(b.stats), ctx
    for sa, sb in zip(a.stats, b.stats):
        assert sa.comparable() == sb.comparable(), (ctx, sa.step)
    assert [_record_key(r) for r in a.log] \
        == [_record_key(r) for r in b.log], ctx
    assert a.store.residency_snapshot() == b.store.residency_snapshot(), ctx


# ---------------------------------------------------------------------------
# run() iterator contract (satellite: the max_steps off-by-one).
# ---------------------------------------------------------------------------

class TestRunIterator:
    @pytest.mark.parametrize("depth", DEPTHS)
    def test_max_steps_pulls_exactly_max_steps_items(self, depth):
        """The old loop pulled item i == max_steps from the trace before
        breaking — fatal for generator-backed traces whose production has
        side effects (or blocks). islice caps the pulls exactly."""
        eng = ServingEngine(4, pool_tokens=10**6,
                            cfg=EngineConfig(pipeline_depth=depth))
        eng.register_chunk("c0", holder=1, length=256)
        pulled = []

        def trace():
            for i in range(10):
                pulled.append(i)
                yield [Request(0, home=0, chunk_ids=["c0"], m_q=8)]

        stats = eng.run(trace(), max_steps=2)
        assert len(stats) == 2
        assert pulled == [0, 1]

    def test_unbounded_run_consumes_whole_trace(self):
        eng = ServingEngine(4, pool_tokens=10**6)
        eng.register_chunk("c0", holder=1, length=256)
        reqs = [Request(0, home=0, chunk_ids=["c0"], m_q=8)]
        assert len(eng.run(iter([reqs] * 3))) == 3

    @pytest.mark.parametrize("depth", (2, 4))
    def test_pipelined_run_flushes(self, depth):
        """run() returns with nothing left in flight — stats cover every
        scheduled step even when the last ones were pipelined."""
        eng = ServingEngine(4, pool_tokens=10**6,
                            cfg=EngineConfig(pipeline_depth=depth))
        eng.register_chunk("c0", holder=1, length=256)
        reqs = [Request(0, home=0, chunk_ids=["c0"], m_q=8)]
        stats = eng.run(iter([reqs] * 5))
        assert len(stats) == 5
        assert eng._inflight == []


# ---------------------------------------------------------------------------
# Depth is a latency knob: bit-identity against the lockstep oracle.
# ---------------------------------------------------------------------------

class TestDepthBitIdentity:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    @pytest.mark.parametrize("depth", (2, 4))
    def test_scenarios_match_lockstep(self, name, depth):
        base = _run_at_depth(SCENARIOS[name], 1)
        pipe = _run_at_depth(SCENARIOS[name], depth)
        _assert_engines_identical(base, pipe, (name, depth))

    @pytest.mark.parametrize("depth", (2, 4))
    def test_selection_trace_matches_lockstep(self, depth):
        base = _run_at_depth(selection_scenario, 1,
                             selector=IndexerService())
        pipe = _run_at_depth(selection_scenario, depth,
                             selector=IndexerService())
        _assert_engines_identical(base, pipe, depth)

    @pytest.mark.parametrize("depth", (2, 4))
    def test_schedule_step_plus_flush_matches_run(self, depth):
        """Driving the pipeline by hand (schedule_step per step, flush at
        the end, no speculation) accounts the same steps as run()."""
        base = _run_at_depth(SCENARIOS["mixed_congested"], 1)
        eng, steps = SCENARIOS["mixed_congested"](
            cfg=EngineConfig(pipeline_depth=depth))
        for reqs in steps:
            eng.schedule_step(reqs)
        eng.flush()
        _assert_engines_identical(base, eng, depth)

    @pytest.mark.parametrize("depth", (2, 4))
    def test_agentic_workload_matches_lockstep(self, depth):
        wl = WorkloadConfig(n_steps=10, agents=8, n_corpus_chunks=6,
                            chunk_tokens=256, session_steps=(2, 6),
                            selection_frac=0.0, seed=3)

        def build(depth_):
            eng = ServingEngine(4, pool_tokens=32 * 256,
                                cfg=EngineConfig(pipeline_depth=depth_),
                                instances_per_pod=2)
            cids = register_corpus(eng, wl)
            return eng, materialize_trace(agentic_trace(wl, eng, cids))

        base, steps_b = build(1)
        base.run(iter(steps_b))
        pipe, steps_p = build(depth)
        assert [[dataclasses.asdict(r) for r in s] for s in steps_b] \
            == [[dataclasses.asdict(r) for r in s] for s in steps_p]
        pipe.run(iter(steps_p))
        _assert_engines_identical(base, pipe, depth)
        # the fault-free agentic run never misspeculates: every
        # speculative plan is claimed as-is
        assert pipe.misspeculation_replans == 0


# ---------------------------------------------------------------------------
# Randomized workloads (hypothesis, dev-only).
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover - dev-only dep
    st = None

if st is not None:
    @given(seed=st.integers(0, 2**16), agents=st.integers(1, 8),
           n_chunks=st.integers(2, 8), depth=st.sampled_from((2, 3, 4)))
    @settings(max_examples=25, deadline=None)
    def test_randomized_workloads_match_lockstep(seed, agents, n_chunks,
                                                 depth):
        wl = WorkloadConfig(n_steps=6, agents=agents,
                            n_corpus_chunks=n_chunks, chunk_tokens=256,
                            session_steps=(1, 4), selection_frac=0.0,
                            seed=seed)

        def build(depth_):
            eng = ServingEngine(4, pool_tokens=24 * 256,
                                cfg=EngineConfig(pipeline_depth=depth_),
                                instances_per_pod=2)
            cids = register_corpus(eng, wl)
            return eng, materialize_trace(agentic_trace(wl, eng, cids))

        base, steps_b = build(1)
        base.run(iter(steps_b))
        pipe, steps_p = build(depth)
        pipe.run(iter(steps_p))
        _assert_engines_identical(base, pipe, (seed, agents, depth))
else:
    @pytest.mark.skip(
        reason="property tests need hypothesis (requirements-dev.txt)")
    def test_randomized_workloads_match_lockstep():
        pass


# ---------------------------------------------------------------------------
# Speculation lifecycle: claim, misspeculation, mutation invalidation.
# ---------------------------------------------------------------------------

class TestSpeculation:
    def _engine(self, depth=2, backend=None):
        eng = ServingEngine(4, pool_tokens=10**6,
                            cfg=EngineConfig(pipeline_depth=depth),
                            backend=backend)
        for i in range(3):
            eng.register_chunk(f"c{i}", holder=1 + i % 3, length=256)
        return eng

    def test_speculative_plan_claimed_when_world_unchanged(self):
        eng = self._engine()
        r1 = [Request(0, home=0, chunk_ids=["c0"], m_q=8)]
        r2 = [Request(1, home=0, chunk_ids=["c1"], m_q=8)]
        eng.schedule_step(r1)
        eng.speculate_step(r2)
        assert eng._spec is not None
        spec_plan = eng._spec.plan
        eng.schedule_step(r2)
        eng.flush()
        assert eng.misspeculation_replans == 0
        assert eng.plans[-1] is spec_plan

    def test_request_mismatch_triggers_replan(self):
        eng = self._engine()
        r1 = [Request(0, home=0, chunk_ids=["c0"], m_q=8)]
        eng.schedule_step(r1)
        eng.speculate_step([Request(1, home=0, chunk_ids=["c1"], m_q=8)])
        other = [Request(2, home=0, chunk_ids=["c2"], m_q=8)]
        eng.schedule_step(other)
        eng.flush()
        assert eng.misspeculation_replans == 1
        # the replan re-planned at the speculated step index, not past it
        assert [s.step for s in eng.stats] == [1, 2]

    def test_fail_instance_invalidates_and_flushes(self):
        eng = self._engine()
        r1 = [Request(0, home=0, chunk_ids=["c0"], m_q=8)]
        r2 = [Request(1, home=0, chunk_ids=["c1"], m_q=8)]
        eng.schedule_step(r1)
        eng.speculate_step(r2)
        assert eng._inflight          # step 1 still in flight at depth 2
        eng.fail_instance(2)
        assert eng._inflight == []    # drained before the store mutated
        assert eng._spec is None
        assert eng.misspeculation_replans == 1
        eng.schedule_step(r2)
        eng.flush()
        assert [s.step for s in eng.stats] == [1, 2]

    def test_set_straggler_invalidates_speculation(self):
        eng = self._engine()
        r1 = [Request(0, home=0, chunk_ids=["c0"], m_q=8)]
        eng.schedule_step(r1)
        eng.speculate_step([Request(1, home=0, chunk_ids=["c1"], m_q=8)])
        eng.set_straggler(1, 2.5)
        assert eng._spec is None
        assert eng._inflight == []
        assert eng.misspeculation_replans == 1

    def test_depth1_fault_path_unchanged(self):
        """At depth 1 the fault hooks are no-ops (nothing in flight, no
        speculation) — lockstep fault behavior is untouched."""
        eng = self._engine(depth=1)
        eng.schedule_step([Request(0, home=0, chunk_ids=["c0"], m_q=8)])
        eng.fail_instance(1)
        assert eng.misspeculation_replans == 0

    def test_failover_mid_pipeline_matches_oracle(self):
        """The tentpole fault drill: speculate step 2, kill the holder
        mid-pipeline, replan — the replanned step's outputs must still
        match the single-instance oracle on the post-fault store."""
        eng = self._engine(backend=JaxExecBackend())
        r1 = [Request(0, home=0, chunk_ids=["c0"], m_q=4)]
        r2 = [Request(1, home=0, chunk_ids=["c1"], m_q=4)]
        eng.schedule_step(r1)
        eng.speculate_step(r2)
        eng.fail_instance(2)          # c1's holder dies under speculation
        eng.schedule_step(r2)
        eng.flush()
        assert eng.misspeculation_replans == 1
        assert [r.primitive for r in eng.plans[-1].records] == ["local"]
        for step, reqs in ((1, r1), (2, r2)):
            outs = eng.outputs_of(step)
            for rq in reqs:
                want = oracle_partial(TINY_MLA, eng.store, rq, step)
                got = outs[rq.req_id]
                np.testing.assert_allclose(got.o, want.o,
                                           rtol=RTOL, atol=ATOL)
                np.testing.assert_allclose(got.l, want.l,
                                           rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# The submit/await split: compat shim + exec-backend pipelining.
# ---------------------------------------------------------------------------

class TestSubmitAwaitProtocol:
    def test_legacy_backend_degrades_to_eager(self):
        """A backend with only execute() (the pre-split protocol) still
        works at any depth — submit_step wraps it eagerly."""
        class Legacy:
            name = "legacy"

            def execute(self, engine, plan):
                from repro.serving.backends.analytic import AnalyticBackend
                return AnalyticBackend().execute(engine, plan)

        eng = ServingEngine(4, pool_tokens=10**6,
                            cfg=EngineConfig(pipeline_depth=3),
                            backend=Legacy())
        eng.register_chunk("c0", holder=1, length=256)
        reqs = [Request(0, home=0, chunk_ids=["c0"], m_q=8)]
        stats = eng.run(iter([reqs] * 3))
        assert len(stats) == 3
        # eager tickets hide nothing: the await never blocks
        assert eng.planner_overlap_s == 0.0

    def test_ticket_roundtrip_on_analytic(self):
        eng = ServingEngine(4, pool_tokens=10**6)
        eng.register_chunk("c0", holder=1, length=256)
        plan = eng.plan_step([Request(0, home=0, chunk_ids=["c0"], m_q=8)])
        ticket = submit_step(eng.backend, eng, plan)
        assert isinstance(ticket, StepTicket)
        assert ticket.execution is not None      # analytic is eager
        execution = await_step(eng.backend, eng, ticket)
        assert execution.timeline is not None

    @pytest.mark.parametrize("depth", (2, 4))
    def test_jax_exec_pipelined_matches_oracle(self, depth):
        """In-process exec backend under pipelining: outputs per step
        still reproduce single-instance attention."""
        base = _run_at_depth(SCENARIOS["mixed_congested"], 1,
                             backend=AnalyticBackend())
        eng, steps = SCENARIOS["mixed_congested"](
            JaxExecBackend(), cfg=EngineConfig(pipeline_depth=depth))
        eng.run(iter(steps))
        _assert_engines_identical(base, eng, depth)
        for step, reqs in enumerate(steps, start=1):
            outs = eng.outputs_of(step)
            for rq in reqs:
                want = oracle_partial(TINY_MLA, eng.store, rq, step)
                np.testing.assert_allclose(outs[rq.req_id].o, want.o,
                                           rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Obs integration: pipeline series + overlapping lane spans.
# ---------------------------------------------------------------------------

class TestPipelineObs:
    def test_pipeline_metrics_published(self):
        from repro.obs import Obs, Tracer, validate_trace
        obs = Obs(tracer=Tracer())
        eng, steps = SCENARIOS["mixed_congested"](
            cfg=EngineConfig(pipeline_depth=2))
        eng.obs = obs
        obs.bind_engine(eng)
        eng.run(iter(steps))
        snap = obs.metrics.snapshot()
        assert snap["gauges"]["engine.pipeline_depth"] == 2
        assert "engine.misspeculation_replans" in snap["gauges"]
        assert "engine.planner_overlap_s" in snap["histograms"]
        assert "engine.planner_overlap_s_total" in snap["counters"]
        # lane-tracked wall spans still form a valid trace
        validate_trace(obs.tracer.export())

    def test_depth1_keeps_single_engine_track(self):
        from repro.obs import Obs, Tracer
        obs = Obs(tracer=Tracer())
        eng, steps = SCENARIOS["mixed_congested"]()
        eng.obs = obs
        obs.bind_engine(eng)
        eng.run(iter(steps))
        names = {e["args"]["name"] for e in obs.tracer.events
                 if e.get("ph") == "M" and e["pid"] == 0
                 and e["name"] == "thread_name"}
        assert names == {"engine"}

    def test_depth2_spans_fan_out_over_lanes(self):
        from repro.obs import Obs, Tracer
        obs = Obs(tracer=Tracer())
        eng, steps = SCENARIOS["mixed_congested"](
            cfg=EngineConfig(pipeline_depth=2))
        eng.obs = obs
        obs.bind_engine(eng)
        eng.run(iter(steps))
        names = {e["args"]["name"] for e in obs.tracer.events
                 if e.get("ph") == "M" and e["pid"] == 0
                 and e["name"] == "thread_name"}
        assert names == {"engine lane 0", "engine lane 1"}
