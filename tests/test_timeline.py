"""Directed timeline invariants (hypothesis-free; the randomized property
sweep lives in test_timeline_props.py): a one-flow timeline IS the scalar
cost model, wire stages serialize per (link, fabric), independent flows
overlap, and the engine's step latency is the makespan — strictly above
the old independent max-reduce price once a link is shared by >= 4 flows
(the ISSUE-2 acceptance bar)."""

import numpy as np
import pytest

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.serving import timeline as TL
from repro.serving.engine import (EngineConfig, Request, ServingEngine,
                                  build_timeline)

IB = C.fabric("h100_ibgda")
ICI = C.fabric("tpu_ici")


def _route_flow(i: int, fabric=IB, m_q: int = 1024, link_inst: int = 0,
                holder: int = 0, requester: int = 99) -> TL.Flow:
    return TL.transport_flow(
        f"route#{i}", cm.route_stages(fabric, m_q),
        link_res=TL.link(link_inst, 0), holder_sm=TL.sm(holder),
        requester_sm=TL.sm(requester + i), primitive="route")


class TestStageBreakdownsMatchClosedForms:
    def test_route_stages_sum_to_congested_full(self):
        for k in (0, 1, 2, 3, 5):
            for mq in (1, 64, 1024):
                want = cm.t_route_congested_full(IB, mq, k)
                got = cm.stages_total_s(cm.route_stages(IB, mq, k))
                np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_route_stages_with_host_overhead(self):
        got = cm.stages_total_s(cm.route_stages(IB, 64, 0, t_host=3.5e-3))
        np.testing.assert_allclose(
            got, cm.t_route_congested_full(IB, 64, 0) + 3.5e-3, rtol=1e-12)

    def test_fetch_stages_sum_to_amortised_fetch(self):
        for reuse in (1, 7, 100_000):
            want = cm.t_fetch(IB, 2048) / reuse
            got = cm.stages_total_s(
                cm.fetch_stages(IB, 2048, reuse_steps=reuse))
            np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_fetch_stages_prefix_rehome_elides_splice(self):
        stages = cm.fetch_stages(IB, 2048, contiguous=False)
        assert [n for n, _ in stages] == ["pull"]
        np.testing.assert_allclose(cm.stages_total_s(stages),
                                   cm.t_fetch(IB, 2048, contiguous=False),
                                   rtol=1e-12)

    def test_local_and_scattered_stages(self):
        np.testing.assert_allclose(
            cm.stages_total_s(cm.local_stages(512)), cm.t_local(512),
            rtol=1e-12)
        np.testing.assert_allclose(
            cm.stages_total_s(cm.fetch_scattered_stages(IB, 2048, 7)),
            cm.t_fetch_scattered(IB, 2048, 7), rtol=1e-12)

    def test_scale_stages(self):
        stages = cm.route_stages(IB, 64)
        scaled = cm.scale_stages(stages, 5.0)
        np.testing.assert_allclose(cm.stages_total_s(scaled),
                                   5.0 * cm.stages_total_s(stages),
                                   rtol=1e-12)
        assert cm.scale_stages(stages, 1.0) is stages


class TestSingleFlowIsScalarPrice:
    def test_one_route_flow_makespan_equals_price(self):
        t = TL.simulate([_route_flow(0)])
        want = cm.t_route_congested_full(IB, 1024, 0)
        assert abs(t.makespan_s - want) <= 1e-9 * want
        assert t.overlap_efficiency == pytest.approx(1.0)

    def test_one_fetch_flow_makespan_equals_price(self):
        f = TL.transport_flow("fetch#0",
                              cm.fetch_stages(IB, 2048, reuse_steps=8),
                              link_res=TL.link(0, 0), holder_sm=TL.sm(0),
                              requester_sm=TL.sm(1))
        t = TL.simulate([f])
        want = cm.t_fetch(IB, 2048) / 8
        assert abs(t.makespan_s - want) <= 1e-9 * want

    def test_empty_timeline(self):
        t = TL.simulate([])
        assert t.makespan_s == 0.0 and t.overlap_efficiency == 1.0
        assert t.link_flow_counts() == {} and t.stage_totals() == {}


class TestSharedLinkSerializes:
    def test_no_two_flows_overlap_on_a_link(self):
        t = TL.simulate([_route_flow(i) for i in range(5)])
        on_link = sorted((s for s in t.scheduled
                          if s.resource == TL.link(0, 0)),
                         key=lambda s: s.start_s)
        assert len(on_link) == 3 * 5          # probe + transfer + return
        for a, b in zip(on_link, on_link[1:]):
            assert b.start_s >= a.end_s - 1e-15

    def test_makespan_bracketed(self):
        flows = [_route_flow(i) for i in range(6)]
        t = TL.simulate(flows)
        assert t.makespan_s >= max(f.serial_s for f in flows) - 1e-15
        assert t.makespan_s <= sum(f.serial_s for f in flows) + 1e-12

    def test_four_flows_exceed_old_max_reduce(self):
        # acceptance bar: >= 4 concurrent flows on one link => the schedule
        # makespan strictly exceeds the old (congested, independent) price
        k = 4
        t = TL.simulate([_route_flow(i) for i in range(k)])
        assert t.makespan_s > cm.t_route_congested_full(IB, 1024, k)
        assert t.link_flow_counts()[TL.link(0, 0)] == k

    def test_independent_links_fully_overlap(self):
        # distinct links, holders and requesters: no shared resource, so
        # the makespan is the max single-flow price, not the sum
        flows = [_route_flow(i, link_inst=i, holder=i) for i in range(4)]
        t = TL.simulate(flows)
        want = max(f.serial_s for f in flows)
        assert abs(t.makespan_s - want) <= 1e-9 * want
        assert t.overlap_efficiency == pytest.approx(0.25, rel=1e-6)

    def test_holder_sm_occupancy_serializes_compute(self):
        # distinct links but ONE holder: computes queue on the holder's SM
        flows = [_route_flow(i, link_inst=i, holder=0) for i in range(3)]
        t = TL.simulate(flows)
        comp = sorted((s for s in t.scheduled if s.stage == "compute"),
                      key=lambda s: s.start_s)
        for a, b in zip(comp, comp[1:]):
            assert b.start_s >= a.end_s - 1e-15


class TestEngineTimelineLatency:
    def test_single_dispatch_step_latency_is_the_scalar_price(self):
        eng = ServingEngine(4, pool_tokens=10**6)
        eng.register_chunk("doc", holder=1, length=2048)
        recs = eng.schedule_step([Request(0, home=0, chunk_ids=["doc"],
                                          m_q=256)])
        assert [r.primitive for r in recs] == ["route"]
        s = eng.stats[-1]
        assert abs(s.latency_s - recs[0].est_cost_s) \
            <= 1e-9 * recs[0].est_cost_s
        assert s.latency_s == pytest.approx(s.max_dispatch_s, rel=1e-9)

    def test_four_shared_link_flows_exceed_max_reduce(self):
        eng = ServingEngine(8, pool_tokens=10**6, instances_per_pod=8)
        for i in range(4):
            eng.register_chunk(f"c{i}", holder=1, length=2048)
        eng.schedule_step([Request(i, home=2 + i, chunk_ids=[f"c{i}"],
                                   m_q=1024) for i in range(4)])
        s = eng.stats[-1]
        # old price: max over dispatches of the congested closed form
        assert s.max_dispatch_s == pytest.approx(
            cm.t_route_congested_full(ICI, 1024, 4), rel=1e-9)
        assert s.latency_s > s.max_dispatch_s
        assert 0.0 < s.overlap_efficiency < 1.0
        assert s.serial_stage_s == pytest.approx(
            sum(v for v in s.stage_totals.values()), rel=1e-9)

    def test_backup_replaces_straggler_primary_in_timeline(self):
        eng = ServingEngine(4, pool_tokens=10**6)
        eng.register_chunk("doc", holder=1, length=2048)
        eng.store.add_replica("doc", 3)
        eng.set_straggler(1, 10.0)
        recs = eng.schedule_step([Request(0, home=0, chunk_ids=["doc"],
                                          m_q=256)])
        backups = [r for r in recs if r.backup]
        assert backups
        s = eng.stats[-1]
        # the timeline schedules the cheaper (backup) path
        assert s.latency_s == pytest.approx(backups[0].est_cost_s, rel=1e-9)

    def test_build_timeline_skips_stageless_records(self):
        t = build_timeline([])
        assert t.makespan_s == 0.0 and not t.flows

    def test_backup_caps_only_its_own_fabric_group(self):
        # one chunk on a straggler, requesters from BOTH pods: each fabric
        # group fires its own backup. The cross-pod primary must be capped
        # by the cross-pod backup — not by the other group's cheap
        # intra-pod one — and each backup must schedule exactly once
        eng = ServingEngine(8, pool_tokens=10**6, instances_per_pod=4)
        eng.register_chunk("doc", holder=1, length=2048)
        eng.store.add_replica("doc", 2)
        eng.set_straggler(1, 10.0)
        recs = eng.schedule_step([
            Request(0, home=0, chunk_ids=["doc"], m_q=64),   # intra-pod
            Request(1, home=5, chunk_ids=["doc"], m_q=64)])  # cross-pod
        backups = sorted((r.est_cost_s for r in recs if r.backup))
        assert len(backups) == 2
        t = eng.timelines[-1]
        assert len(t.flows) == 2               # one flow per fabric group
        ends = sorted(t.flow_end_s(f.key) for f in t.flows)
        # the cheap intra-pod backup cannot have absorbed the cross-pod
        # group: the slowest flow costs at least the cross-pod backup
        assert ends[-1] >= backups[-1] - 1e-12
        assert eng.stats[-1].latency_s >= backups[-1] - 1e-12
