"""The multi-step, congestion-aware scheduler: fetch persistence (the
amortisation the predicate prices must actually accrue), per-group fabric
correctness across pods, §8 link-subscription pricing, replica retirement
under pool pressure, and the trace-driven workload driver."""

import numpy as np
import pytest

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.serving.engine import (EngineConfig, Request, ServingEngine,
                                  transport_latencies)
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    register_corpus)


def _engine(n=8, ipp=0, pool=100_000, **cfg_kw):
    return ServingEngine(n, pool_tokens=pool, cfg=EngineConfig(**cfg_kw),
                         instances_per_pod=ipp)


class TestFetchPersistence:
    def test_fetched_chunk_becomes_resident_and_amortizes(self):
        eng = _engine(n=4)
        eng.register_chunk("doc", holder=1, length=2048)
        # long reuse horizon => predicate picks FETCH (§5.5 rule 2)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=1,
                     expected_reuse_steps=100_000)
        recs = eng.schedule_step([rq])
        assert [r.primitive for r in recs] == ["fetch"]
        # the amortised price matches the predicate's fetch_cost exactly
        want = cm.t_fetch(C.fabric("tpu_ici"), 2048) / 100_000
        assert recs[0].est_cost_s == pytest.approx(want, rel=1e-9)
        assert eng.store.resident_on("doc", 0)
        # subsequent steps: resident => no transport at all
        recs2 = eng.schedule_step([rq])
        assert recs2 == []
        assert eng.stats[-1].n_resident == 1

    def test_persistence_can_be_disabled(self):
        eng = _engine(n=4, persist_fetches=False)
        eng.register_chunk("doc", holder=1, length=2048)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=1,
                     expected_reuse_steps=100_000)
        eng.schedule_step([rq])
        assert not eng.store.resident_on("doc", 0)
        assert [r.primitive for r in eng.schedule_step([rq])] == ["fetch"]


class TestPerGroupFabric:
    def test_cross_pod_requester_not_priced_at_first_entrys_fabric(self):
        # requesters from BOTH pods hit one holder: the seed engine priced
        # the whole batch at entries[0]'s fabric; now each fabric gets its
        # own dispatch at its own probe
        eng = _engine(n=8, ipp=4, congestion_aware=False)
        eng.register_chunk("doc", holder=1, length=2048)
        reqs = [Request(0, home=0, chunk_ids=["doc"], m_q=8),    # intra-pod
                Request(1, home=5, chunk_ids=["doc"], m_q=8)]    # cross-pod
        recs = eng.schedule_step(reqs)
        routes = sorted((r for r in recs if r.primitive == "route"),
                        key=lambda r: r.est_cost_s)
        assert len(routes) == 2                   # one dispatch per fabric
        ici, dcn = C.fabric("tpu_ici"), C.fabric("tpu_dcn")
        overhead = float(np.mean(C.HOLDER_COMPUTE_DECODE_S)) + C.MERGE_COST_S
        assert routes[0].est_cost_s == pytest.approx(
            cm.t_route_congested(ici, 8, 1) + overhead, rel=1e-9)
        assert routes[1].est_cost_s == pytest.approx(
            cm.t_route_congested(dcn, 8, 1) + overhead, rel=1e-9)

    def test_same_fabric_requesters_still_batch_to_one_dispatch(self):
        eng = _engine(n=8, ipp=8)
        eng.register_chunk("doc", holder=1, length=2048)
        reqs = [Request(i, home=i, chunk_ids=["doc"], m_q=4)
                for i in (0, 2, 3)]
        recs = eng.schedule_step(reqs)
        assert len(recs) == 1 and recs[0].m_q_total == 12


class TestCongestionPricing:
    def test_three_flows_on_one_link_pay_the_k3_premium(self):
        eng = _engine(n=8, ipp=8)
        for i in range(3):
            eng.register_chunk(f"c{i}", holder=1, length=2048)
        # 3 distinct chunks on holder 1 => 3 concurrent flows on its link
        reqs = [Request(i, home=2 + i, chunk_ids=[f"c{i}"], m_q=1024)
                for i in range(3)]
        recs = eng.schedule_step(reqs)
        ici = C.fabric("tpu_ici")
        overhead = float(np.mean(C.HOLDER_COMPUTE_DECODE_S)) + C.MERGE_COST_S
        want = cm.t_route_congested(ici, 1024, 3) + overhead
        for r in recs:
            assert r.est_cost_s == pytest.approx(want, rel=1e-9)
        # and the congested price is strictly above the uncontended one
        assert want > cm.t_route_congested(ici, 1024, 1) + overhead

    def test_flows_on_different_holders_stay_uncontended(self):
        eng = _engine(n=8, ipp=8)
        for i in range(3):
            eng.register_chunk(f"c{i}", holder=i + 1, length=2048)
        reqs = [Request(i, home=0, chunk_ids=[f"c{i}"], m_q=1024)
                for i in range(3)]
        recs = eng.schedule_step(reqs)
        ici = C.fabric("tpu_ici")
        overhead = float(np.mean(C.HOLDER_COMPUTE_DECODE_S)) + C.MERGE_COST_S
        want = cm.t_route_congested(ici, 1024, 1) + overhead
        for r in recs:
            assert r.est_cost_s == pytest.approx(want, rel=1e-9)


class TestEmptySteps:
    def test_fully_resident_step_is_skipped_in_aggregation(self):
        # the _critical_path edge case: an empty dispatch list prices to
        # 0.0 and step_latency() still records the step — that zero must
        # not enter p50/p99 (nobody waited 0s; the step moved no bytes)
        eng = _engine(n=4)
        eng.register_chunk("doc", holder=1, length=2048)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=1,
                     expected_reuse_steps=100_000)
        eng.schedule_step([rq])          # FETCH, persists
        eng.schedule_step([rq])          # resident: empty step
        empty = eng.stats[-1]
        assert empty.n_dispatches == 0 and not empty.has_transport
        assert empty.latency_s == 0.0 and eng.step_latency(2) == 0.0
        lats = transport_latencies(eng.stats)
        assert len(lats) == 1            # only the fetch step aggregates
        assert lats[0] == pytest.approx(eng.stats[0].latency_s)
        assert (lats > 0).all()
        # percentiles over transport steps only: unpolluted by the zero
        assert np.percentile(lats, 50) > 0

    def test_empty_step_overlap_efficiency_is_neutral(self):
        eng = _engine(n=4)
        eng.register_chunk("doc", holder=0, length=2048)
        eng.schedule_step([Request(0, home=0, chunk_ids=["doc"])])
        s = eng.stats[-1]                # resident at home: nothing priced
        assert not s.has_transport and s.overlap_efficiency == 1.0
        assert s.serial_stage_s == 0.0 and s.stage_totals == {}


class TestOccupancyDerivedKFlows:
    def test_local_voting_group_does_not_inflate_link_k(self):
        # holder 1's link carries 2 ROUTE groups plus a group whose vote is
        # LOCAL (tiny chunk, huge m_q): LOCAL never touches the wire, so
        # the observed occupancy is K=2 — priced flat (§8), where the old
        # assumed-count path would have charged the K=3 premium
        eng = _engine(n=8, ipp=8)
        eng.register_chunk("a", holder=1, length=2048)
        eng.register_chunk("b", holder=1, length=2048)
        eng.register_chunk("tiny", holder=1, length=8)
        recs = eng.schedule_step([
            Request(0, home=2, chunk_ids=["a"], m_q=1024),
            Request(1, home=3, chunk_ids=["b"], m_q=1024),
            Request(2, home=4, chunk_ids=["tiny"], m_q=4096)])
        prims = {r.chunk_id: r for r in recs if not r.backup}
        assert prims["tiny"].primitive == "local"
        ici = C.fabric("tpu_ici")
        overhead = float(np.mean(C.HOLDER_COMPUTE_DECODE_S)) + C.MERGE_COST_S
        flat = cm.t_route_congested(ici, 1024, 2) + overhead
        for cid in ("a", "b"):
            assert prims[cid].primitive == "route"
            assert prims[cid].est_cost_s == pytest.approx(flat, rel=1e-9)
        # and flat == uncontended: K=2 is below the §8 subscription knee
        assert flat == pytest.approx(
            cm.t_route_congested(ici, 1024, 0) + overhead, rel=1e-9)

    def test_observed_k_matches_timeline_link_count(self):
        # the k the predicate was fed is exactly what the schedule shows
        eng = _engine(n=8, ipp=8)
        for i in range(3):
            eng.register_chunk(f"c{i}", holder=1, length=2048)
        eng.schedule_step([Request(i, home=2 + i, chunk_ids=[f"c{i}"],
                                   m_q=1024) for i in range(3)])
        from repro.serving import timeline as TL
        counts = eng.timelines[-1].link_flow_counts()
        assert counts[TL.link(1, 0)] == 3
        ici = C.fabric("tpu_ici")
        overhead = float(np.mean(C.HOLDER_COMPUTE_DECODE_S)) + C.MERGE_COST_S
        want = cm.t_route_congested(ici, 1024, 3) + overhead
        for r in eng.log:
            assert r.est_cost_s == pytest.approx(want, rel=1e-9)


class TestPoolPressure:
    def test_cold_replica_retires_for_hot_fetch(self):
        # pool fits ONE 2048-token replica next to a 2048 canonical chunk
        eng = _engine(n=2, pool=4096)
        eng.register_chunk("cold", holder=1, length=2048)
        eng.register_chunk("hot", holder=1, length=2048)
        eng.register_chunk("home0", holder=0, length=2048)
        fetchy = dict(m_q=1, expected_reuse_steps=100_000)
        eng.schedule_step([Request(0, home=0, chunk_ids=["cold"], **fetchy)])
        assert eng.store.resident_on("cold", 0)
        # instance 0 pool now: 2048 canonical + 2048 replica = full
        eng.schedule_step([Request(1, home=0, chunk_ids=["hot"], **fetchy)])
        eng.schedule_step([Request(2, home=0, chunk_ids=["hot"], **fetchy)])
        assert eng.store.resident_on("hot", 0)       # newcomer fit...
        assert not eng.store.resident_on("cold", 0)  # ...by retiring LRU
        assert eng.stats[-1].evictions + eng.stats[-2].evictions >= 1

    def test_canonical_copy_never_retires(self):
        eng = _engine(n=2, pool=2048 + 1024)
        eng.register_chunk("canon", holder=0, length=2048)
        eng.register_chunk("big", holder=1, length=2048)
        recs = eng.schedule_step([Request(0, home=0, chunk_ids=["big"],
                                          m_q=1,
                                          expected_reuse_steps=100_000)])
        # no room (canonical is not evictable): fetch still dispatched but
        # nothing became resident and nothing was evicted
        assert not eng.store.resident_on("big", 0)
        assert eng.store.resident_on("canon", 0)
        # and the price is the FULL pull+splice: a copy that cannot persist
        # cannot amortise
        want = cm.t_fetch(C.fabric("tpu_ici"), 2048)
        assert recs[0].est_cost_s == pytest.approx(want, rel=1e-9)

    def test_orphan_rehome_respects_pool(self):
        eng = _engine(n=2, pool=2100)
        eng.register_chunk("a", holder=1, length=2048)
        eng.register_chunk("b", holder=0, length=2048)
        eng.fail_instance(1)
        recs = eng.schedule_step([Request(0, home=0, chunk_ids=["a"])])
        assert recs[0].primitive == "local"
        # home pool ~full: the chunk could not re-home, stays orphaned
        assert not eng.store.resident_on("a", 0)


class TestFanInCap:
    def test_mixed_vote_group_still_respects_elbow(self):
        # 9 ROUTE voters + 3 FETCH voters in one group: the dispatched
        # route batch must not exceed fanin_cap requesters (the seed of
        # this class of bug: vote counts mixed with group sizes)
        eng = _engine(n=16, pool=10**6)
        eng.register_chunk("doc", holder=1, length=2048)
        reqs = [Request(i, home=2 + (i % 13), chunk_ids=["doc"], m_q=256)
                for i in range(9)]
        reqs += [Request(100 + i, home=2 + i, chunk_ids=["doc"], m_q=1,
                         expected_reuse_steps=100_000) for i in range(3)]
        recs = eng.schedule_step(reqs)
        for r in recs:
            if r.primitive == "route":
                assert r.n_requesters <= eng.cfg.fanin_cap

    def test_overdrawn_budget_does_not_corrupt_later_subgroups(self):
        # replica spawn FAILS for the first (intra-pod) sub-group (every
        # pod-0 pool is a full canonical chunk), overdrawing the budget;
        # the cross-pod sub-group must then replicate ALL its requesters
        # (keep=0), not slice with a negative index
        eng = _engine(n=16, ipp=8, pool=2048)
        eng.register_chunk("doc", holder=0, length=2048)
        for i in range(1, 8):
            eng.register_chunk(f"fill{i}", holder=i, length=2048)
        # pod-1 homes have room (only 8..15 pools are empty)
        reqs = [Request(i, home=1 + (i % 7), chunk_ids=["doc"], m_q=256)
                for i in range(10)]                      # intra-pod, no room
        reqs += [Request(100 + i, home=8 + i, chunk_ids=["doc"], m_q=256)
                 for i in range(4)]                      # cross-pod, room
        recs = eng.schedule_step(reqs)
        cross = [r for r in recs if r.primitive == "route"
                 and not r.backup and r.n_requesters == 4]
        # the 4 cross-pod requesters must NOT have routed as a group of 4
        # minus a negative slice; they go to a replica instead
        assert not cross
        assert any(r.primitive == "fetch_replica" and r.holder >= 8
                   for r in recs)

    def test_budget_shared_across_fabric_subgroups(self):
        # requesters from two pods (two fabric sub-groups) share ONE
        # holder compute budget per chunk
        eng = _engine(n=16, ipp=8, pool=10**6)
        eng.register_chunk("doc", holder=1, length=2048)
        reqs = [Request(i, home=(2 + i) if i < 6 else (8 + i % 8),
                        chunk_ids=["doc"], m_q=64) for i in range(12)]
        recs = eng.schedule_step(reqs)
        routed = sum(r.n_requesters for r in recs
                     if r.primitive == "route" and not r.backup)
        assert routed <= eng.cfg.fanin_cap


class TestLocalAttribution:
    def test_local_runs_at_requester_not_holder(self):
        # tiny chunk + no transport advantage: LOCAL wins; the dispatch
        # must land on the REQUESTER and ignore the holder's slowdown
        eng = _engine(n=4, pool=10**6)
        eng.register_chunk("tiny", holder=1, length=8)
        eng.set_straggler(1, 100.0)
        recs = eng.schedule_step([Request(0, home=2, chunk_ids=["tiny"],
                                          m_q=4096)])
        local = [r for r in recs if r.primitive == "local" and not r.backup]
        if local:       # predicate picked LOCAL for this geometry
            assert local[0].holder == 2
            assert local[0].est_cost_s == pytest.approx(
                cm.t_local(8), rel=1e-9)


class TestWorkloadDriver:
    def test_trace_is_deterministic(self):
        cfg = WorkloadConfig(n_steps=5, agents=8, n_corpus_chunks=8, seed=3)
        e1 = _engine(n=4)
        e2 = _engine(n=4)
        c1, c2 = register_corpus(e1, cfg), register_corpus(e2, cfg)
        t1 = [[(r.req_id, r.home, tuple(r.chunk_ids), r.m_q) for r in step]
              for step in agentic_trace(cfg, e1, c1)]
        t2 = [[(r.req_id, r.home, tuple(r.chunk_ids), r.m_q) for r in step]
              for step in agentic_trace(cfg, e2, c2)]
        assert t1 == t2

    def test_steady_state_residency_grows(self):
        # sustained agentic traffic: persistence + replication push the
        # resident (free local attention) fraction up over the run
        eng = _engine(n=8, ipp=4)
        cfg = WorkloadConfig(n_steps=80, agents=48, n_corpus_chunks=16,
                             session_steps=(16, 64), seed=0)
        cids = register_corpus(eng, cfg)
        # selection_frac sessions carry k_selected with no selector: the
        # warn-once fallback is intentional — assert it, don't leak it
        with pytest.warns(RuntimeWarning, match="k_selected"):
            stats = eng.run(agentic_trace(cfg, eng, cids))
        assert len(stats) == 80
        early = sum(s.n_resident for s in stats[:10]) / \
            max(1, sum(s.n_pairs for s in stats[:10]))
        late = sum(s.n_resident for s in stats[-10:]) / \
            max(1, sum(s.n_pairs for s in stats[-10:]))
        assert late > early
        assert all(s.latency_s > 0 for s in stats)
        # residency can make individual steps predicate-free; in aggregate
        # the scheduler must have priced work at a nonzero rate
        assert sum(s.n_priced for s in stats) > 0
        assert any(s.decisions_per_sec > 0 for s in stats)

    def test_run_respects_max_steps(self):
        eng = _engine(n=4)
        cfg = WorkloadConfig(n_steps=50, agents=8, n_corpus_chunks=8)
        cids = register_corpus(eng, cfg)
        stats = eng.run(agentic_trace(cfg, eng, cids), max_steps=7)
        assert len(stats) == 7 and eng.step_idx == 7
