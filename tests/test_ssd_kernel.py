"""Fused SSD intra-chunk Pallas kernel vs the pure-jnp oracle, plus the
end-to-end ssd_chunked(use_kernel=True) path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk import ssd_intra_chunk, ssd_intra_chunk_ref
from repro.models import ssm as S


def _inputs(b, nc, Q, H, P, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, nc, Q, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, Q, H)))
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, nc, Q, N))
    C = jax.random.normal(ks[4], (b, nc, Q, N))
    return x, dt, A, B, C


class TestSSDKernel:
    @pytest.mark.parametrize("b,nc,Q,H,P,N,hb",
                             [(1, 2, 16, 4, 8, 16, 4),
                              (2, 2, 32, 8, 16, 32, 8),
                              (1, 1, 64, 8, 32, 64, 4)])
    def test_matches_ref(self, b, nc, Q, H, P, N, hb):
        x, dt, A, B, C = _inputs(b, nc, Q, H, P, N, seed=Q)
        y, st, cum = ssd_intra_chunk(x, dt, A, B, C, hb=hb)
        yr, str_, cumr = ssd_intra_chunk_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(cum), np.asarray(cumr),
                                   atol=1e-5)

    def test_head_block_invariance(self):
        x, dt, A, B, C = _inputs(1, 2, 16, 8, 8, 16)
        outs = [ssd_intra_chunk(x, dt, A, B, C, hb=hb) for hb in (2, 4, 8)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0][0]),
                                       np.asarray(o[0]), atol=1e-5)

    def test_ssd_chunked_with_kernel_matches_naive(self):
        cfg = S.Mamba2Config(d_model=64, d_state=16, head_dim=8, expand=2,
                             chunk=8)
        b, s, h, p, n = 2, 32, cfg.n_heads, cfg.head_dim, cfg.d_state
        ks = jax.random.split(jax.random.PRNGKey(7), 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(0.5 * jax.random.normal(ks[2], (h,)))
        B = jax.random.normal(ks[3], (b, s, n))
        C = jax.random.normal(ks[4], (b, s, n))
        y0, h0 = S.ssd_chunked(cfg, x, dt, A, B, C, use_kernel=False)
        y1, h1 = S.ssd_chunked(cfg, x, dt, A, B, C, use_kernel=True)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                                   atol=2e-4, rtol=1e-3)

    def test_traffic_reduction_napkin(self):
        """The point of the fusion (§Perf M1): HBM traffic = I/O only.
        jnp path materializes ~5 (Q,Q,H)-sized tensors per chunk; kernel
        writes none. Quantify for mamba2-370m geometry."""
        Q, H, P, N = 128, 32, 64, 128
        f32 = 4
        qq_h = Q * Q * H * f32
        jnp_intermediates = 5 * qq_h          # expo, Lmat, CB-bcast, G, tmp
        kernel_io = (Q * H * P + Q * H + 2 * Q * N      # inputs
                     + Q * H * P + H * P * N + Q * H) * f32   # outputs
        ratio = (jnp_intermediates + kernel_io) / kernel_io
        assert ratio > 3.0, ratio             # >= 3x traffic reduction
