"""The distributed indexer subsystem (ISSUE 4): score -> select ->
scatter-attend through the scheduler.

* DISTRIBUTED == GLOBAL — per-holder local top-k + requester merge equals
  the single-instance ranking of every block (the §5.4 claim that the
  distributed selection is exact, not approximate).
* SELECTION EXACTNESS — JaxExecBackend selection-regime decode reproduces
  single-instance selection_k attention (the DSA path of models/model.py)
  to float round-off, for every primitive the planner picks.
* REPLAY PARITY — AnalyticBackend StepStats are bit-identical between a
  plan built with live indexer masks and the same plan replayed from a
  recorded selection trace (the acceptance criterion).
* GOLDEN TRACE — the frozen selection scenario's verdicts and StepStats
  are pinned to tests/fixtures/selection_trace.json.

Regenerate the fixture after an INTENTIONAL model change:

    PYTHONPATH=src python tests/test_selection_service.py
"""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from engine_scenarios import selection_scenario
from repro.core import cost_model as cm
from repro.core import constants as C
from repro.serving import timeline as TL
from repro.serving.backends import JaxExecBackend, TINY_MLA
from repro.serving.backends.jax_exec import (max_oracle_err, oracle_partial,
                                             query_for,
                                             selection_oracle_partial)
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.selection import (IndexerService, ReplaySelector,
                                     SelectionConfig, save_selection_trace,
                                     selection_trace_payload)
from repro.models.mla import absorbed_partial

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "selection_trace.json"
RTOL, ATOL = 2e-5, 1e-6
REL_TOL = 1e-9

# StepStats fields that are deterministic closed forms (wall-clock stays
# out, as in the engine goldens)
STAT_FIELDS = ("step", "n_requests", "n_pairs", "n_priced", "n_resident",
               "n_dispatches", "primitives", "latency_s", "max_dispatch_s",
               "serial_stage_s", "stage_totals", "n_selected",
               "selection_fallbacks", "replicas_spawned", "evictions")


def _run(backend=None, selector=None):
    eng, steps = selection_scenario(backend, selector)
    for reqs in steps:
        eng.schedule_step(reqs)
    return eng, steps


def _stat_dict(s):
    return {f: getattr(s, f) for f in STAT_FIELDS}


# ---------------------------------------------------------------------------
# Distributed top-k == global top-k.
# ---------------------------------------------------------------------------

class TestDistributedTopk:
    def test_select_equals_global_on_scenario(self):
        svc = IndexerService()
        eng, steps = selection_scenario(selector=svc)
        for step_no, reqs in enumerate(steps, start=1):
            for rq in reqs:
                if rq.k_selected is None:
                    continue
                dist = svc.select_request(eng.store, rq, step_no)
                glob = svc.global_select(eng.store, rq, step_no)
                assert dist.blocks == glob.blocks, (step_no, rq.req_id)
                for cid in rq.chunk_ids:
                    np.testing.assert_array_equal(dist.masks[cid],
                                                  glob.masks[cid])

    def test_budget_rounds_up_to_blocks(self):
        """k_selected=96 at 64-token blocks selects ceil(96/64)=2 blocks
        (NSA granularity rounds the token budget up), and a partial tail
        block is selectable (the topk_blocks bugfix)."""
        svc = IndexerService()
        eng, _ = selection_scenario(selector=svc)
        rq = Request(1, home=0, chunk_ids=["sel2"], m_q=1, k_selected=96)
        sel = svc.select_request(eng.store, rq, 1)
        # sel2 is 160 tokens = blocks of 64, 64, 32 — all three addressable
        assert sum(len(b) for b in sel.blocks.values()) == 2
        assert sel.masks["sel2"].shape == (160,)
        assert all(b in (0, 1, 2) for b in sel.blocks["sel2"])


# ---------------------------------------------------------------------------
# Exec exactness: scheduler scatter-attend == single-instance selection_k.
# ---------------------------------------------------------------------------

class TestSelectionExactness:
    def test_exec_matches_selection_oracle(self):
        """Every step of the frozen selection trace: selection requests
        reproduce the selection_k oracle, the dense rider the dense
        oracle — end-to-end through the scheduler."""
        eng, steps = selection_scenario(JaxExecBackend(), IndexerService())
        for reqs in steps:
            eng.schedule_step(reqs)
            assert max_oracle_err(eng, reqs, eng.step_idx) < 1e-4
            # at least one request actually ran under selection
            assert eng.plans[-1].selections

    def test_matches_model_dsa_path(self):
        """block_tokens=1, m_q=1: the service degenerates to token-level
        top-k with the EXACT scoring rule of models/model.py's
        _mla_decode_cached (mean-head latent query . latent c^KV band,
        lax.top_k, attend the gathered entries) — the scheduler output
        equals that single-instance DSA decode to float round-off."""
        k = 5
        svc = IndexerService(SelectionConfig(block_tokens=1))
        eng = ServingEngine(2, pool_tokens=10**5,
                            backend=JaxExecBackend(), selector=svc)
        eng.register_chunk("doc", holder=1, length=48)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=1, k_selected=k)
        eng.schedule_step([rq])
        got = eng.outputs_of(1)[0]

        # the DSA path, verbatim on the serving cache
        mcfg = TINY_MLA
        q = query_for(mcfg, rq, 1)                        # (1, H, d_qk)
        ckv = eng.store.lookup("doc").data                # (S, d_qk)
        qi = jnp.mean(q[..., : mcfg.kv_lora_rank], axis=1)        # (1, d_c)
        scores = jnp.einsum("qc,sc->qs", qi,
                            ckv[:, : mcfg.kv_lora_rank])
        _, sel_idx = jax.lax.top_k(scores[0], k)
        sel_ckv = jnp.take(ckv, sel_idx, axis=0)
        want = absorbed_partial(mcfg, q, sel_ckv)
        np.testing.assert_allclose(got.o, want.o, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(got.m, want.m, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(got.l, want.l, rtol=RTOL, atol=ATOL)

    def test_fetch_selected_gathers_and_never_persists(self):
        """FETCH under selection executes as the scattered gather (selected
        entries at canonical positions, no splice) and leaves NO replica —
        a selection is re-chosen every step, there is nothing to amortise."""
        eng = ServingEngine(2, pool_tokens=10**5,
                            backend=JaxExecBackend(),
                            selector=IndexerService())
        eng.register_chunk("doc", holder=1, length=160)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=2, k_selected=96)
        plan = eng.plan_step([rq])
        assert len(plan.records) == 1 and plan.selections
        # re-express the planned dispatch as the gather path
        fetch_plan = dataclasses.replace(
            plan, records=[dataclasses.replace(plan.records[0],
                                               primitive="fetch")])
        ex = eng.backend.execute(eng, fetch_plan)
        want = selection_oracle_partial(TINY_MLA, eng.store, rq,
                                        plan.selections[0], plan.step)
        np.testing.assert_allclose(ex.outputs[0].o, want.o,
                                   rtol=RTOL, atol=ATOL)
        assert not eng.store.lookup("doc").replica_data
        assert eng.store.lookup("doc").replicas == []

    def test_empty_holder_selection_is_identity(self):
        """A holder the indexer chose nothing from still joins the fan-out;
        its masked partial is the merge identity and the merged output
        still equals the oracle (k_selected=64 over two chunks: one chunk
        necessarily gets zero blocks)."""
        eng = ServingEngine(4, pool_tokens=10**5,
                            backend=JaxExecBackend(),
                            selector=IndexerService())
        eng.register_chunk("a", holder=1, length=64)
        eng.register_chunk("b", holder=2, length=64)
        rq = Request(0, home=0, chunk_ids=["a", "b"], m_q=2, k_selected=64)
        eng.schedule_step([rq])
        sel = eng.plans[-1].selections[0]
        assert sorted(sel.kb_on(c) for c in ("a", "b")) == [0, 1]
        assert max_oracle_err(eng, [rq], 1) < 1e-4


# ---------------------------------------------------------------------------
# Analytic replay parity (acceptance criterion) + golden trace.
# ---------------------------------------------------------------------------

class TestReplayParity:
    def test_analytic_stats_bit_identical_live_vs_replay(self, tmp_path):
        svc = IndexerService()
        live, _ = _run(selector=svc)
        trace = tmp_path / "sel.json"
        save_selection_trace(trace, svc.log, svc.block_tokens, svc.d_index)

        rep, _ = _run(selector=ReplaySelector(str(trace)))
        for a, b in zip(live.stats, rep.stats):
            assert _stat_dict(a) == _stat_dict(b)       # bit-identical
        assert [(r.step, r.primitive, r.chunk_id, r.holder, r.est_cost_s,
                 r.stages, r.req_ids) for r in live.log] \
            == [(r.step, r.primitive, r.chunk_id, r.holder, r.est_cost_s,
                 r.stages, r.req_ids) for r in rep.log]

    def test_replay_rejects_world_mismatch(self, tmp_path):
        svc = IndexerService()
        _run(selector=svc)
        trace = tmp_path / "sel.json"
        save_selection_trace(trace, svc.log, svc.block_tokens, svc.d_index)
        eng, _ = selection_scenario(selector=ReplaySelector(str(trace)))
        with pytest.raises(KeyError, match="no request"):
            eng.schedule_step([Request(99, home=0, chunk_ids=["sel0"],
                                       m_q=1, k_selected=64)])

    def test_replay_rejects_unknown_chunk(self, tmp_path):
        """A chunk id the trace never recorded for a request is a
        trace/world mismatch and raises — it must NOT silently de-select
        (all-False masks would complete the run with wrong pricing)."""
        svc = IndexerService()
        _run(selector=svc)
        trace = tmp_path / "sel.json"
        save_selection_trace(trace, svc.log, svc.block_tokens, svc.d_index)
        eng, _ = selection_scenario(selector=ReplaySelector(str(trace)))
        eng.register_chunk("other", holder=1, length=64)
        with pytest.raises(KeyError, match="no entry for chunks"):
            # request 0 exists in step 1, but with different chunks
            eng.schedule_step([Request(0, home=0, chunk_ids=["other"],
                                       m_q=4, k_selected=128)])


def _golden_payload():
    svc = IndexerService()
    eng, _ = _run(selector=svc)
    payload = selection_trace_payload(
        svc.log, svc.block_tokens, svc.d_index,
        meta={"scenario": "selection_scenario"})
    payload["stats"] = [_stat_dict(s) for s in eng.stats]
    return payload


def _assert_close(got, want, path):
    if isinstance(want, float) and isinstance(got, (int, float)):
        assert got == pytest.approx(want, rel=REL_TOL), \
            f"{path}: {got} != {want}"
    elif isinstance(want, dict):
        assert isinstance(got, dict) and sorted(got) == sorted(want), \
            f"{path}: keys {sorted(got)} != {sorted(want)}"
        for k in want:
            _assert_close(got[k], want[k], f"{path}.{k}")
    elif isinstance(want, (list, tuple)):
        got = list(got)
        assert len(got) == len(want), f"{path}: {len(got)} != {len(want)}"
        for i, (g, w) in enumerate(zip(got, list(want))):
            _assert_close(g, w, f"{path}[{i}]")
    else:
        assert got == want, f"{path}: {got!r} != {want!r}"


class TestGoldenSelectionTrace:
    def test_golden(self):
        assert FIXTURE.exists(), \
            f"missing {FIXTURE}; regenerate: python {__file__}"
        want = json.loads(FIXTURE.read_text())
        got = _golden_payload()
        # the selected blocks must match EXACTLY (they are the trace)
        assert json.loads(json.dumps(got["steps"])) == want["steps"]
        _assert_close(got["stats"], want["stats"], "stats")

    def test_fixture_replays_through_planner(self):
        """The checked-in fixture IS a valid selection trace: feeding it
        back through a ReplaySelector reproduces the frozen StepStats."""
        want = json.loads(FIXTURE.read_text())
        eng, _ = _run(selector=ReplaySelector(str(FIXTURE)))
        _assert_close([_stat_dict(s) for s in eng.stats], want["stats"],
                      "replayed-stats")


# ---------------------------------------------------------------------------
# Fallback: k_selected with no selector — warn once, record always.
# ---------------------------------------------------------------------------

class TestSelectionFallback:
    def test_warns_once_and_records(self):
        eng = ServingEngine(2, pool_tokens=10**5)
        eng.register_chunk("doc", holder=1, length=2048)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=8, k_selected=512)
        with pytest.warns(RuntimeWarning, match="no selection service"):
            eng.schedule_step([rq])
        # second step: recorded again, but no second warning
        import warnings as W
        with W.catch_warnings():
            W.simplefilter("error")
            eng.schedule_step([rq])
        assert [s.selection_fallbacks for s in eng.stats] == [1, 1]
        assert all(s.n_selected == 0 for s in eng.stats)
        assert all(not p.selections for p in eng.plans)

    def test_fallback_exec_stays_dense_exact(self):
        """Without a selector the exec backend attends the full chunk, and
        the DENSE oracle still holds — the fallback changes nothing but
        the telemetry (that is the point of recording it)."""
        eng = ServingEngine(2, pool_tokens=10**5, backend=JaxExecBackend())
        eng.register_chunk("doc", holder=1, length=64)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=2, k_selected=32)
        with pytest.warns(RuntimeWarning):
            eng.schedule_step([rq])
        got = eng.outputs_of(1)[0]
        want = oracle_partial(TINY_MLA, eng.store, rq, 1)
        np.testing.assert_allclose(got.o, want.o, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# The index sidecar in the chunk store.
# ---------------------------------------------------------------------------

class TestIndexSidecar:
    def test_attach_validates_length(self):
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(2, 10**4)
        st.register("c", holder=0, length=8)
        with pytest.raises(ValueError):
            st.attach_index_keys("c", np.zeros((9, 4)))
        st.attach_index_keys("c", np.zeros((8, 4)))
        assert st.index_keys_on("c", 0).shape == (8, 4)
        assert st.index_keys_on("c", 1) is None

    def test_replica_and_eviction_lifecycle(self):
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(2, 10**4)
        st.register("c", holder=0, length=8)
        st.attach_index_keys("c", np.ones((8, 4)))
        st.add_replica("c", 1)
        st.set_replica_index_keys("c", 1, np.ones((8, 4)) * 2)
        assert float(st.index_keys_on("c", 1)[0, 0]) == 2.0
        st.evict_replica("c", 1)
        assert st.index_keys_on("c", 1) is None

    def test_holder_failure_promotes_sidecar(self):
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(2, 10**4)
        st.register("c", holder=0, length=8)
        st.attach_index_keys("c", np.ones((8, 4)))
        st.add_replica("c", 1)
        st.set_replica_index_keys("c", 1, np.ones((8, 4)) * 3)
        assert st.drop_holder(0) == []
        assert float(st.lookup("c").index_keys[0, 0]) == 3.0

    def test_replica_sidecar_rides_fetch(self):
        """A persisted dense FETCH moves the index sidecar with the cache
        bytes: the replica instance can score locally afterwards (keys
        are position-invariant — the delta splice never touches them)."""
        svc = IndexerService()
        eng = ServingEngine(4, pool_tokens=10**5,
                            backend=JaxExecBackend(), selector=svc)
        eng.register_chunk("doc", holder=1, length=64)
        svc.ensure_index_keys(eng.store, "doc")
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=2,
                     expected_reuse_steps=100_000)
        assert [r.primitive for r in eng.schedule_step([rq])] == ["fetch"]
        rep_keys = eng.store.index_keys_on("doc", 0)
        assert rep_keys is not None
        np.testing.assert_array_equal(
            rep_keys, np.asarray(eng.store.lookup("doc").index_keys))

    def test_service_materializes_sidecar(self):
        svc = IndexerService()
        eng, _ = selection_scenario(selector=svc)
        keys = svc.ensure_index_keys(eng.store, "sel0")
        assert keys.shape == (192, svc.d_index)
        assert eng.store.lookup("sel0").index_keys is not None
        # second touch is a cache hit (same object)
        assert svc.ensure_index_keys(eng.store, "sel0") is not None


# ---------------------------------------------------------------------------
# Cost model: the index stage and the selected stage chains.
# ---------------------------------------------------------------------------

class TestSelectionCosts:
    def test_index_is_a_wire_stage(self):
        assert "index" in TL.WIRE_STAGES

    def test_route_selected_stage_sum_is_closed_form(self):
        fab = C.fabric("tpu_dcn")
        for frac in (0.0, 0.25, 1.0):
            # identical positional args on both sides: the signatures are
            # kept in lockstep on purpose
            stages = cm.route_selected_stages(fab, 16, 0, frac, 4, 16)
            assert cm.stages_total_s(stages) == pytest.approx(
                cm.t_route_selected_full(fab, 16, 0, frac, 4, 16), rel=1e-12)
        assert stages[0][0] == "index"

    def test_fetch_selected_stage_sum_is_closed_form(self):
        fab = C.fabric("tpu_dcn")
        stages = cm.fetch_selected_stages(fab, 96, 16, 2, 16)
        assert cm.stages_total_s(stages) == pytest.approx(
            cm.t_fetch_selected(fab, 96, 16, 2, 16), rel=1e-12)
        assert [n for n, _ in stages] == ["index", "gather"]

    def test_gather_sum_over_holders_is_scattered_closed_form(self):
        """Selection FETCH split across M holders reproduces the Fig 4a
        closed form exactly: M gather stages == t_fetch_scattered(K, M)."""
        fab = C.fabric("h100_ibgda")
        K, M = 2048, 7
        per_holder = cm.fetch_selected_stages(fab, K / M, 256, 32, 64)
        gather = dict(per_holder)["gather"] * M
        assert gather == pytest.approx(cm.t_fetch_scattered(fab, K, M),
                                       rel=1e-12)

    def test_selection_step_prices_index_on_the_timeline(self):
        eng, _ = _run(selector=IndexerService())
        sel_steps = [s for s in eng.stats if s.n_selected]
        assert sel_steps
        for s in sel_steps:
            assert s.stage_totals.get("index", 0.0) > 0.0
        # holder compute is scaled by the budget, not the store: a
        # selection route's compute stage is strictly below the dense one
        dense_compute = dict(cm.route_stages(C.fabric("tpu_ici"), 4))
        for r in eng.log:
            if r.req_ids and r.req_ids[0] in eng.plans[r.step - 1].selections \
                    and r.primitive == "route":
                assert dict(r.stages)["compute"] \
                    < dense_compute["compute"] + 1e-12


# ---------------------------------------------------------------------------
# Serve CLI: the selection flags.
# ---------------------------------------------------------------------------

class TestServeSelectionCLI:
    WORLD = ["--instances", "4", "--pods", "2", "--chunks", "6",
             "--chunk-tokens", "128", "--agents", "6", "--steps", "3"]
    ARGS = WORLD + ["--selection-frac", "0.5", "--selection-k", "128"]

    def test_selection_exec_verify_and_replay(self, tmp_path, capsys):
        from repro.launch import serve
        trace = tmp_path / "sel.json"
        serve.main(self.ARGS + ["--selection", "--backend", "exec",
                                "--verify",
                                "--save-selection-trace", str(trace)])
        out = capsys.readouterr().out
        assert "selector=indexer" in out and "selected pairs" in out
        for line in out.splitlines():
            if "max|err|" in line:
                assert float(line.rsplit("max|err| ", 1)[1]) < 1e-4
        # the recorded trace replays through the (numpy-only) planner —
        # WITHOUT the selection flags: the trace's meta must reconstruct
        # the recorded k/frac (they flow into pricing), like --trace does
        # for the corpus geometry
        serve.main(self.WORLD + ["--selection-trace", str(trace)])
        out2 = capsys.readouterr().out
        assert "selector=replay" in out2
        assert "--selection-trace meta overrides --selection-frac" in out2
        assert "--selection-trace meta overrides --selection-k" in out2
        # identical makespans line-for-line (same masks -> same plans)
        def makespans(text):
            return [ln.split("makespan ")[1].split(",")[0]
                    for ln in text.splitlines() if "makespan" in ln]
        assert makespans(out) == makespans(out2)

    def test_flag_conflicts(self, tmp_path):
        from repro.launch import serve
        with pytest.raises(SystemExit, match="cannot be combined"):
            serve.main(self.ARGS + ["--selection", "--selection-trace",
                                    str(tmp_path / "x.json")])
        with pytest.raises(SystemExit, match="requires --selection"):
            serve.main(self.ARGS + ["--save-selection-trace",
                                    str(tmp_path / "y.json")])


if __name__ == "__main__":
    FIXTURE.parent.mkdir(exist_ok=True)
    FIXTURE.write_text(json.dumps(_golden_payload(), indent=1) + "\n")
    print(f"wrote {FIXTURE}")
