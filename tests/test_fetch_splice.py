"""FETCH-side correctness (§2.2, §3.3):

* delta-rotation re-homes a contiguous chunk exactly (rope composition);
* the splice is inadmissible under scattered selection: re-homing a selected
  set to contiguous offsets *diverges* from the reference (paper: 25-56%).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.splice import splice_delta_rotate
from repro.models import layers as L
from repro.models import mla as M
from repro.models.module import KeyGen, split


CFG = M.MLAConfig(d_model=256, n_heads=4, kv_lora_rank=64,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)


@pytest.fixture(scope="module")
def setup():
    kg = KeyGen(jax.random.PRNGKey(0))
    params, _ = split(M.init_mla(kg, CFG, dtype=jnp.float32))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 64, CFG.d_model),
                                jnp.float32)
    return params, x


class TestDeltaRotation:
    def test_rehome_contiguous_chunk_exact(self, setup):
        """Entries cached at positions [0..S) re-homed by delta == entries
        computed natively at [delta..S+delta)."""
        params, x = setup
        pos0 = jnp.arange(64)[None]
        cached = M.latent_cache_entries(params, CFG, x, pos0)
        # atol grows with delta: f32 angle representation error is linear in
        # position; even at delta=100k the error (5e-4) is 100x below the
        # bf16 wire noise floor the paper reports against (0.05).
        for delta, atol in ((1, 1e-6), (17, 1e-6), (1000, 3e-5),
                            (100_000, 1e-3)):
            spliced = splice_delta_rotate(cached, delta, CFG)
            native = M.latent_cache_entries(params, CFG, x, pos0 + delta)
            np.testing.assert_allclose(np.asarray(spliced),
                                       np.asarray(native), atol=atol)

    def test_latent_columns_untouched(self, setup):
        # Position-invariance of the latent (what makes cross-session reuse
        # possible at all, §2.1).
        params, x = setup
        cached = M.latent_cache_entries(params, CFG, x, jnp.arange(64)[None])
        spliced = splice_delta_rotate(cached, 12345, CFG)
        np.testing.assert_array_equal(
            np.asarray(spliced[..., :CFG.kv_lora_rank]),
            np.asarray(cached[..., :CFG.kv_lora_rank]))

    def test_zero_delta_identity(self, setup):
        # §6.3: a true-prefix re-home (delta = 0) is the identity.
        params, x = setup
        cached = M.latent_cache_entries(params, CFG, x, jnp.arange(64)[None])
        spliced = splice_delta_rotate(cached, 0, CFG)
        np.testing.assert_allclose(np.asarray(spliced), np.asarray(cached),
                                   atol=1e-6)

    def test_rotation_composes(self, setup):
        # R(a) . R(b) = R(a+b) — the algebra behind the flat splice.
        params, x = setup
        cached = M.latent_cache_entries(params, CFG, x, jnp.arange(64)[None])
        ab = splice_delta_rotate(splice_delta_rotate(cached, 100, CFG), 23, CFG)
        once = splice_delta_rotate(cached, 123, CFG)
        np.testing.assert_allclose(np.asarray(ab), np.asarray(once),
                                   atol=2e-5, rtol=1e-4)


class TestSelectionDivergence:
    def test_rehoming_scattered_selection_diverges(self, setup):
        """§3.3: re-homing a scattered selection to contiguous offsets (the
        delta-rotation a contiguous-reuse FETCH applies) diverges from the
        reference by 25-56% — splice is a property of contiguous reuse, not
        of selection."""
        # Direct construction with a position-sensitive rope band (a trained
        # model attends by relative position; random init would not, so we
        # build keys whose rope logits carry the position structure).
        S, H, d_r = 256, 4, CFG.qk_rope_head_dim
        rng_k = jax.random.PRNGKey(9)
        base_k = jax.random.normal(rng_k, (d_r,))
        cos, sin = L.rope_cos_sin(jnp.arange(S).astype(jnp.float32), d_r)
        band = L.apply_rope(jnp.broadcast_to(base_k, (S, d_r)), cos, sin)
        latent = 0.05 * jax.random.normal(jax.random.PRNGKey(10),
                                          (S, CFG.kv_lora_rank))
        entries = jnp.concatenate([latent, band], axis=-1)
        # query at position S, rope-encoded
        qr_base = jax.random.normal(jax.random.PRNGKey(11), (1, H, d_r))
        qcos, qsin = L.rope_cos_sin(jnp.asarray([float(S)]), d_r)
        q_rope = L.apply_rope(qr_base, qcos[None], qsin[None])
        q_lat = 0.05 * jax.random.normal(jax.random.PRNGKey(12),
                                         (1, H, CFG.kv_lora_rank))
        q_abs = jnp.concatenate([q_lat, q_rope], axis=-1)

        rng = np.random.RandomState(0)
        sel = np.sort(rng.choice(S, 16, replace=False))
        selected = entries[sel]

        # Reference: attend the selection at canonical positions (what the
        # sparse kernel does — no adaptation).
        ref = M.absorbed_partial(CFG, q_abs, selected)

        # Wrong: re-home entry i from its canonical position sel[i] to a
        # contiguous offset i (per-entry delta), then attend.
        deltas = jnp.asarray(np.arange(16) - sel, jnp.float32)
        band = selected[:, CFG.kv_lora_rank:]
        cos, sin = L.rope_cos_sin(deltas, CFG.qk_rope_head_dim, CFG.rope_theta)
        rehomed_band = L.apply_rope(band, cos, sin)
        rehomed = jnp.concatenate([selected[:, :CFG.kv_lora_rank],
                                   rehomed_band], axis=-1)
        wrong = M.absorbed_partial(CFG, q_abs, rehomed)

        rel = (np.linalg.norm(np.asarray(wrong.o - ref.o))
               / np.linalg.norm(np.asarray(ref.o)))
        assert rel > 0.10, rel   # paper band: 25-56%; assert material divergence

    def test_selection_attended_in_place_is_exact(self, setup):
        # The correct selection-regime FETCH keeps canonical positions: exact.
        params, x = setup
        S = 64
        pos0 = jnp.arange(S)[None]
        entries = M.latent_cache_entries(params, CFG, x, pos0)[0]
        qn, qr = M.project_q(params, CFG, x[:, -1:], pos0[:, -1:] + 1)
        q_abs = M.absorb_query(params, CFG, qn, qr)[:, 0]
        rng = np.random.RandomState(1)
        sel = np.sort(rng.choice(S, 16, replace=False))
        # gather (no rotation) == masked attention over the full set
        g = M.absorbed_partial(CFG, q_abs, entries[sel])
        mask = np.zeros(S, bool); mask[sel] = True
        m = M.absorbed_partial(CFG, q_abs, entries, jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(g.o), np.asarray(m.o), atol=2e-6)
