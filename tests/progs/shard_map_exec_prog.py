"""ShardMapExecBackend end-to-end on a real 8-device mesh (subprocess-only:
forces 8 host devices, so it must NOT run inside the main pytest process).

The ISSUE 7 acceptance gate:

* all three dense golden scenarios + the selection scenario execute with
  real collectives and reproduce the single-instance oracles to float
  round-off;
* planner StepStats are bit-identical to the AnalyticBackend run
  (sched_wall_s excepted — wall clock);
* every transporting step yields a measured-vs-analytic MeasuredReport
  whose flow structure matches the analytic schedule stage-for-stage;
* the mesh indexer service (ShardMapIndexerService) returns the SAME
  verdicts as the host IndexerService;
* a dead holder mid-run (fail_instance) still reproduces the oracle
  through the promoted replica;
* shard-shape mismatches fail up front with named shards, not as opaque
  XLA lowering errors.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np

from repro.core.merge import Partial
from repro.core.routing import check_route_shards
from repro.serving import timeline as TL
from repro.serving.backends import (AnalyticBackend, JaxExecBackend,
                                    ShardMapExecBackend)
from repro.serving.backends.jax_exec import max_oracle_err
from repro.serving.backends.shard_map import check_instance_shards
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.selection import (IndexerService, SelectionConfig,
                                     ShardMapIndexerService)

from engine_scenarios import SCENARIOS, selection_scenario

TOL = 2e-5


def stats_dict(st):
    d = dataclasses.asdict(st)
    d.pop("sched_wall_s")          # wall clock: the one non-deterministic
    return d


def run_engine(eng, steps):
    for reqs in steps:
        eng.schedule_step(reqs)
    return eng


def check_measured(eng, name):
    """Every step got a MeasuredReport; its analytic side IS the step's
    accounted timeline and the measured side mirrors the flow structure."""
    for st, rep in zip(eng.stats, eng.measured_reports):
        assert rep is not None, (name, st.step)
        assert rep.analytic.makespan_s == st.latency_s, (name, st.step)
        a_names = set(st.stage_totals)
        m_names = set(rep.measured.stage_totals())
        assert m_names == a_names, (name, st.step, a_names, m_names)
        if a_names:                 # a transporting step measured real time
            assert rep.measured.makespan_s > 0.0, (name, st.step)
            assert rep.wall_s > 0.0, (name, st.step)


def test_dense_scenarios():
    for name, build in SCENARIOS.items():
        eng_a = run_engine(*build(backend=AnalyticBackend()))
        eng_m = run_engine(*build(backend=ShardMapExecBackend()))
        assert [stats_dict(s) for s in eng_a.stats] \
            == [stats_dict(s) for s in eng_m.stats], name
        _, steps = build()
        for reqs, st in zip(steps, eng_m.stats):
            err = max_oracle_err(eng_m, reqs, st.step)
            assert err <= TOL, (name, st.step, err)
        check_measured(eng_m, name)
        last = eng_m.measured_reports[-1]
        print(f"  {name}: StepStats parity + oracle exact "
              f"(last-step makespan ratio x{last.makespan_ratio:.2f})")
    print(eng_m.measured_reports[0].summary())


def test_selection_scenario():
    eng_a = run_engine(*selection_scenario(
        backend=AnalyticBackend(), selector=IndexerService()))
    eng_r = run_engine(*selection_scenario(
        backend=JaxExecBackend(), selector=IndexerService()))
    eng_m = run_engine(*selection_scenario(
        backend=ShardMapExecBackend(), selector=ShardMapIndexerService()))
    # mesh indexer == host indexer, verdict for verdict
    assert eng_m.selector.log.keys() == eng_r.selector.log.keys()
    for step, verd in eng_r.selector.log.items():
        mverd = eng_m.selector.log[step]
        assert verd.keys() == mverd.keys(), step
        for rid in verd:
            assert verd[rid].blocks == mverd[rid].blocks, (step, rid)
    # identical selections -> identical plans -> StepStats parity
    assert [stats_dict(s) for s in eng_a.stats] \
        == [stats_dict(s) for s in eng_m.stats]
    _, steps = selection_scenario()
    for reqs, st in zip(steps, eng_m.stats):
        err = max_oracle_err(eng_m, reqs, st.step)
        assert err <= TOL, ("selection", st.step, err)
    check_measured(eng_m, "selection")
    assert any(dt > 0.0 for dt in eng_m.selector.measured_index_s.values())
    print("  selection: mesh indexer verdict parity + selection oracle "
          "exact")


def test_fanout_group():
    """One dispatch group whose requesters span THREE homes: the fanout
    (all_gather / all_to_all) route schedule, not the pairwise one."""
    eng = ServingEngine(8, pool_tokens=10**6, cfg=EngineConfig(),
                        instances_per_pod=8, backend=ShardMapExecBackend())
    eng.register_chunk("fan", holder=0, length=256)
    reqs = [Request(i, home=1 + i, chunk_ids=["fan"], m_q=8)
            for i in range(3)]
    eng.schedule_step(reqs)
    grp = [r for r in eng.plans[0].records
           if r.primitive == "route" and not r.backup]
    assert any(r.n_requesters == 3 for r in grp), grp
    err = max_oracle_err(eng, reqs, 1)
    assert err <= TOL, err
    print(f"  fanout group (3 homes, 1 dispatch): max|err| = {err:.2e}")


def test_dead_holder():
    """fail_instance mid-run: the promoted replica serves the next step's
    plan and the mesh execution still reproduces the oracle (exec-mode
    failover — ISSUE 7 satellite)."""
    eng, steps = SCENARIOS["fetch_heavy"](backend=ShardMapExecBackend())
    eng.schedule_step(steps[0])          # replicas persist on home 0
    eng.fail_instance(1)                 # doc0's canonical holder dies
    reqs = [Request(7, home=3, chunk_ids=["doc0"], m_q=4)]
    eng.schedule_step(reqs)
    err = max_oracle_err(eng, reqs, eng.stats[-1].step)
    assert err <= TOL, err
    print(f"  dead holder -> promoted replica: max|err| = {err:.2e}")


def test_shape_validation():
    # per-requester route shard mismatch names the shard and both shapes
    q = jnp.zeros((4, 2, 24))
    ckv = jnp.zeros((64, 16))            # wrong d_qk
    try:
        check_route_shards("instance", q, ckv, shard=3)
        raise AssertionError("ragged route shard was accepted")
    except ValueError as e:
        msg = str(e)
        assert "shard 3" in msg and "24" in msg and "16" in msg, msg
    # ragged per-instance assembly names the shard and both shapes
    try:
        check_instance_shards({0: np.zeros((8, 4)), 2: np.zeros((7, 4))},
                              (8, 4), 8)
        raise AssertionError("ragged instance shard was accepted")
    except ValueError as e:
        msg = str(e)
        assert "shard 2" in msg and "(7, 4)" in msg and "(8, 4)" in msg, msg
    # a valid mask that disagrees with the cache raises the NAMED error at
    # trace time, not an opaque XLA lowering failure
    backend = ShardMapExecBackend()
    eng = ServingEngine(4, pool_tokens=10**6, backend=backend)
    eng.register_chunk("v", holder=1, length=64)
    eng.schedule_step([Request(0, home=0, chunk_ids=["v"], m_q=2)])
    try:
        check_route_shards("instance", jnp.zeros((2, 2, 24)),
                           jnp.zeros((64, 24)), jnp.zeros(63, bool))
        raise AssertionError("ragged valid mask was accepted")
    except ValueError as e:
        assert "disagree" in str(e), e
    print("  shape validation: named-shard ValueErrors up front")


if __name__ == "__main__":
    test_dense_scenarios()
    test_selection_scenario()
    test_fanout_group()
    test_dead_holder()
    test_shape_validation()
    print("SHARD-MAP-EXEC-OK")
