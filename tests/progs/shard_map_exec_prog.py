"""ShardMapExecBackend end-to-end on a real 8-device mesh (subprocess-only:
forces 8 host devices, so it must NOT run inside the main pytest process).

The ISSUE 7 acceptance gate:

* all three dense golden scenarios + the selection scenario execute with
  real collectives and reproduce the single-instance oracles to float
  round-off;
* planner StepStats are bit-identical to the AnalyticBackend run
  (sched_wall_s excepted — wall clock);
* every transporting step yields a measured-vs-analytic MeasuredReport
  whose flow structure matches the analytic schedule stage-for-stage;
* the mesh indexer service (ShardMapIndexerService) returns the SAME
  verdicts as the host IndexerService;
* a dead holder mid-run (fail_instance) still reproduces the oracle
  through the promoted replica;
* shard-shape mismatches fail up front with named shards, not as opaque
  XLA lowering errors.

Extended by ISSUE 8 (overlapped dispatch-group execution):

* every dense/selection check above runs in BOTH execution modes —
  fused/overlapped (the default) and the serial staged_call chain
  (`fused=False`, the A/B kill switch) — with identical StepStats;
* fused-vs-serial outputs agree to <= 1e-6 on the golden scenarios AND
  on randomized agentic workloads (several seeds);
* the fused path apportions stage walls without gaps: stage_fills == 0
  on every planned step (the _measured_flow silent-zero fix);
* fetched committed copies live in a BOUNDED pool that retires entries
  with their replicas (evict listener).

Extended by ISSUE 9 (flight recorder): one traced run must export planned
AND measured track groups, publish the exec-side metric series, and the
drift monitor must be LOUD on forced host devices (whose walls sit orders
of magnitude off the fabric model — silence there would mean the monitor
is broken).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np

from repro.core.merge import Partial
from repro.core.routing import check_route_shards
from repro.serving import timeline as TL
from repro.serving.backends import (AnalyticBackend, JaxExecBackend,
                                    ShardMapExecBackend)
from repro.serving.backends.jax_exec import max_oracle_err
from repro.serving.backends.shard_map import check_instance_shards
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.selection import (IndexerService, SelectionConfig,
                                     ShardMapIndexerService)

from engine_scenarios import SCENARIOS, selection_scenario

TOL = 2e-5


def stats_dict(st):
    d = dataclasses.asdict(st)
    d.pop("sched_wall_s")          # wall clock: the one non-deterministic
    return d


def run_engine(eng, steps):
    for reqs in steps:
        eng.schedule_step(reqs)
    return eng


def check_measured(eng, name):
    """Every step got a MeasuredReport; its analytic side IS the step's
    accounted timeline and the measured side mirrors the flow structure."""
    for st, rep in zip(eng.stats, eng.measured_reports):
        assert rep is not None, (name, st.step)
        assert rep.analytic.makespan_s == st.latency_s, (name, st.step)
        a_names = set(st.stage_totals)
        m_names = set(rep.measured.stage_totals())
        assert m_names == a_names, (name, st.step, a_names, m_names)
        if a_names:                 # a transporting step measured real time
            assert rep.measured.makespan_s > 0.0, (name, st.step)
            assert rep.wall_s > 0.0, (name, st.step)


def max_ab_err(eng_f, eng_s, step):
    """Worst |fused output - serial output| over one step's requests."""
    outs_f, outs_s = eng_f.outputs_of(step), eng_s.outputs_of(step)
    assert outs_f.keys() == outs_s.keys(), step
    worst = 0.0
    for rid, p in outs_f.items():
        worst = max(worst, float(jnp.max(jnp.abs(p.o - outs_s[rid].o))))
    return worst


def test_dense_scenarios():
    for name, build in SCENARIOS.items():
        eng_a = run_engine(*build(backend=AnalyticBackend()))
        eng_f = run_engine(*build(backend=ShardMapExecBackend()))
        eng_s = run_engine(*build(backend=ShardMapExecBackend(fused=False)))
        # BOTH modes must leave the planner's accounting untouched
        want = [stats_dict(s) for s in eng_a.stats]
        assert want == [stats_dict(s) for s in eng_f.stats], name
        assert want == [stats_dict(s) for s in eng_s.stats], name
        _, steps = build()
        ab = 0.0
        for reqs, st in zip(steps, eng_f.stats):
            for eng in (eng_f, eng_s):
                err = max_oracle_err(eng, reqs, st.step)
                assert err <= TOL, (name, st.step, err,
                                    eng.backend.fused)
            ab = max(ab, max_ab_err(eng_f, eng_s, st.step))
        assert ab <= 1e-6, (name, ab)
        for eng, mode in ((eng_f, "fused"), (eng_s, "serial")):
            check_measured(eng, name)
            for rep in eng.measured_reports:
                assert rep.mode == mode, (name, rep.step, rep.mode)
                # S6: apportioning covered every planned stage — a fill
                # on these golden traces would mean a silent 0.0 again
                assert rep.stage_fills == 0, (name, rep.step,
                                              rep.stage_fills)
        last = eng_f.measured_reports[-1]
        print(f"  {name}: StepStats parity both modes + oracle exact, "
              f"fused-vs-serial max|err| {ab:.2e} "
              f"(last-step makespan ratio x{last.makespan_ratio:.2f})")
    print(eng_f.measured_reports[0].summary())


def test_randomized_ab():
    """Fused vs serial on randomized agentic workloads (ISSUE 8 S3): the
    SAME generated trace through both modes — bit-identical StepStats,
    outputs within 1e-6, no apportioning gaps."""
    from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                        materialize_trace, register_corpus)
    for seed in (0, 7, 23):
        def build(backend, seed=seed):
            eng = ServingEngine(8, pool_tokens=24 * 256,
                                cfg=EngineConfig(), instances_per_pod=4,
                                backend=backend)
            w = WorkloadConfig(n_steps=6, agents=6, n_corpus_chunks=10,
                               chunk_tokens=256, session_steps=(2, 6),
                               selection_frac=0.0, seed=seed)
            cids = register_corpus(eng, w)
            return eng, materialize_trace(agentic_trace(w, eng, cids))

        eng_f, steps = build(ShardMapExecBackend())
        eng_s, _ = build(ShardMapExecBackend(fused=False))
        ab = 0.0
        for reqs in steps:
            eng_f.schedule_step(reqs)
            eng_s.schedule_step(reqs)
            step = eng_f.stats[-1].step
            assert stats_dict(eng_f.stats[-1]) \
                == stats_dict(eng_s.stats[-1]), (seed, step)
            err = max_oracle_err(eng_f, reqs, step)
            assert err <= TOL, (seed, step, err)
            ab = max(ab, max_ab_err(eng_f, eng_s, step))
        assert ab <= 1e-6, (seed, ab)
        assert all(r.stage_fills == 0 for r in eng_f.measured_reports
                   if r is not None), seed
        print(f"  randomized A/B seed {seed}: {len(steps)} steps, "
              f"fused-vs-serial max|err| {ab:.2e}")


def test_pipelined_ab():
    """ISSUE 10: the pipelined engine (plan N+1 under execute N, deferred
    barrier) against the depth-1 lockstep oracle on the real mesh —
    bit-identical StepStats/records/residency at depths {2, 4}, outputs
    still §3.3-exact, and the warm steps demonstrably hide planner wall
    under the device barrier."""
    def rec_key(r):
        return (r.step, r.primitive, r.chunk_id, r.holder, r.n_requesters,
                r.m_q_total, r.backup, r.fabric_idx, r.link_instance,
                r.home, r.req_ids, r.est_cost_s, r.stages)

    for name, build in SCENARIOS.items():
        base, steps = build(backend=ShardMapExecBackend())
        base.run(iter(steps))
        for depth in (2, 4):
            eng, steps_d = build(
                backend=ShardMapExecBackend(),
                cfg=EngineConfig(pipeline_depth=depth))
            eng.run(iter(steps_d))
            assert [stats_dict(s) for s in base.stats] \
                == [stats_dict(s) for s in eng.stats], (name, depth)
            assert [rec_key(r) for r in base.log] \
                == [rec_key(r) for r in eng.log], (name, depth)
            assert base.store.residency_snapshot() \
                == eng.store.residency_snapshot(), (name, depth)
            assert eng.misspeculation_replans == 0, (name, depth)
            for reqs, st in zip(steps_d, eng.stats):
                err = max_oracle_err(eng, reqs, st.step)
                assert err <= TOL, (name, depth, st.step, err)

    # warm overlap: same trace repeated — after compile warm-up the
    # deferred barrier must actually hide planner wall (ISSUE 10 gate
    # proper lives in bench_serving_steadystate --exec-bench; this is the
    # functional floor: SOME wall was hidden)
    from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                        materialize_trace, register_corpus)

    def wl_build(depth):
        eng = ServingEngine(8, pool_tokens=24 * 256,
                            cfg=EngineConfig(pipeline_depth=depth),
                            instances_per_pod=4,
                            backend=ShardMapExecBackend())
        w = WorkloadConfig(n_steps=6, agents=6, n_corpus_chunks=10,
                           chunk_tokens=256, session_steps=(2, 6),
                           selection_frac=0.0, seed=7)
        cids = register_corpus(eng, w)
        return eng, materialize_trace(agentic_trace(w, eng, cids))

    base, steps = wl_build(1)
    base.run(iter(steps))
    pipe, steps_p = wl_build(2)
    pipe.run(iter(steps_p))
    assert [stats_dict(s) for s in base.stats] \
        == [stats_dict(s) for s in pipe.stats], "randomized pipelined A/B"
    assert pipe.planner_overlap_s > 0.0, \
        "depth 2 on the mesh hid no planner wall at all"
    print(f"  pipelined A/B depths {{2,4}}: bit-identical to lockstep + "
          f"oracle exact; randomized depth-2 run hid "
          f"{pipe.planner_overlap_s*1e3:.2f}ms of planner wall")


def test_pool_retirement():
    """S1: fetch persistence fills the committed-copy pool; evicting the
    replica (LRU path / fail_instance) retires the pooled buffer too."""
    backend = ShardMapExecBackend()
    eng, steps = SCENARIOS["fetch_heavy"](backend=backend)
    eng.schedule_step(steps[0])            # three FETCHes persist on home 0
    rep = eng.measured_reports[-1]
    # 3 fetched copies on home 0 + 3 staged canonical copies at holders
    assert rep.pool_entries == 6, rep.pool_entries
    assert rep.pool_bytes > 0, rep.pool_bytes
    assert ("doc0", 0) in backend._pool
    eng.store.evict_replica("doc0", 0)
    assert ("doc0", 0) not in backend._pool, "evict listener did not fire"
    assert len(backend._pool) == 5
    eng.fail_instance(0)                   # drop_holder retires the rest
    assert not any(inst == 0 for _, inst in backend._pool), backend._pool
    # the surviving canonical holders keep their committed copies
    assert len(backend._pool) == 3, backend._pool
    print("  pool retirement: evict_replica + fail_instance both drain "
          "the committed-copy pool")


def test_selection_scenario():
    eng_a = run_engine(*selection_scenario(
        backend=AnalyticBackend(), selector=IndexerService()))
    eng_r = run_engine(*selection_scenario(
        backend=JaxExecBackend(), selector=IndexerService()))
    eng_m = run_engine(*selection_scenario(
        backend=ShardMapExecBackend(), selector=ShardMapIndexerService()))
    # mesh indexer == host indexer, verdict for verdict
    assert eng_m.selector.log.keys() == eng_r.selector.log.keys()
    for step, verd in eng_r.selector.log.items():
        mverd = eng_m.selector.log[step]
        assert verd.keys() == mverd.keys(), step
        for rid in verd:
            assert verd[rid].blocks == mverd[rid].blocks, (step, rid)
    # identical selections -> identical plans -> StepStats parity
    assert [stats_dict(s) for s in eng_a.stats] \
        == [stats_dict(s) for s in eng_m.stats]
    _, steps = selection_scenario()
    for reqs, st in zip(steps, eng_m.stats):
        err = max_oracle_err(eng_m, reqs, st.step)
        assert err <= TOL, ("selection", st.step, err)
    check_measured(eng_m, "selection")
    assert any(dt > 0.0 for dt in eng_m.selector.measured_index_s.values())
    print("  selection: mesh indexer verdict parity + selection oracle "
          "exact")


def test_fanout_group():
    """One dispatch group whose requesters span THREE homes: the fanout
    (all_gather / all_to_all) route schedule, not the pairwise one."""
    eng = ServingEngine(8, pool_tokens=10**6, cfg=EngineConfig(),
                        instances_per_pod=8, backend=ShardMapExecBackend())
    eng.register_chunk("fan", holder=0, length=256)
    reqs = [Request(i, home=1 + i, chunk_ids=["fan"], m_q=8)
            for i in range(3)]
    eng.schedule_step(reqs)
    grp = [r for r in eng.plans[0].records
           if r.primitive == "route" and not r.backup]
    assert any(r.n_requesters == 3 for r in grp), grp
    err = max_oracle_err(eng, reqs, 1)
    assert err <= TOL, err
    print(f"  fanout group (3 homes, 1 dispatch): max|err| = {err:.2e}")


def test_dead_holder():
    """fail_instance mid-run: the promoted replica serves the next step's
    plan and the mesh execution still reproduces the oracle (exec-mode
    failover — ISSUE 7 satellite)."""
    eng, steps = SCENARIOS["fetch_heavy"](backend=ShardMapExecBackend())
    eng.schedule_step(steps[0])          # replicas persist on home 0
    eng.fail_instance(1)                 # doc0's canonical holder dies
    reqs = [Request(7, home=3, chunk_ids=["doc0"], m_q=4)]
    eng.schedule_step(reqs)
    err = max_oracle_err(eng, reqs, eng.stats[-1].step)
    assert err <= TOL, err
    print(f"  dead holder -> promoted replica: max|err| = {err:.2e}")


def test_flight_recorder():
    """ISSUE 9 on the real mesh: the tracer renders planned AND measured
    track groups from one run, the registry picks up the exec-side series
    (phase walls, stage_fills, pool occupancy), and the drift monitor
    folds every MeasuredReport. Forced host devices run 10-5000x slower
    than the fabric model, so drift MUST trip at the calibrated 7% — we
    assert the trip (the monitor is loud where it should be) instead of
    pretending the fit holds here."""
    from repro.obs import DriftConfig, DriftError, DriftMonitor, Obs, Tracer
    from repro.obs.trace import PID_MEASURED, PID_PLANNED, validate_trace

    eng, steps = SCENARIOS["mixed_congested"](backend=ShardMapExecBackend())
    obs = Obs(tracer=Tracer(), drift=DriftMonitor(DriftConfig(
        threshold=0.07, min_samples=1)))
    eng.obs = obs
    obs.bind_engine(eng)
    run_engine(eng, steps)

    doc = obs.tracer.export()
    assert validate_trace(doc) == [], validate_trace(doc)
    steps_by_pid = {
        pid: [e for e in doc["traceEvents"] if e["ph"] == "X"
              and e["pid"] == pid and e.get("cat") == "step"]
        for pid in (PID_PLANNED, PID_MEASURED)}
    assert len(steps_by_pid[PID_PLANNED]) == len(steps), doc
    assert len(steps_by_pid[PID_MEASURED]) == len(steps), \
        "measured track group missing — MeasuredReports not traced"

    snap = obs.metrics.snapshot()
    assert obs.metrics.counter_value("exec.stage_fills") == 0.0
    assert any(k.startswith("exec.phase_wall_s{") for k in snap["gauges"])
    assert snap["histograms"]["exec.measured_ratio"]["count"] == len(steps)
    assert obs.drift.n_reports == len(steps)
    assert obs.drift.n_unmatched == 0
    try:
        obs.drift.check()
        raise AssertionError("host-device walls inside 7% of the model?!")
    except DriftError as e:
        assert "ewma" in str(e)
    print(f"  flight recorder: planned+measured track groups, "
          f"{len(snap['counters'])} counters, drift loud on host devices "
          f"(worst cell |ewma| {max(abs(s.ewma) for s in obs.drift.cells.values()):.0f})")


def test_shape_validation():
    # per-requester route shard mismatch names the shard and both shapes
    q = jnp.zeros((4, 2, 24))
    ckv = jnp.zeros((64, 16))            # wrong d_qk
    try:
        check_route_shards("instance", q, ckv, shard=3)
        raise AssertionError("ragged route shard was accepted")
    except ValueError as e:
        msg = str(e)
        assert "shard 3" in msg and "24" in msg and "16" in msg, msg
    # ragged per-instance assembly names the shard and both shapes
    try:
        check_instance_shards({0: np.zeros((8, 4)), 2: np.zeros((7, 4))},
                              (8, 4), 8)
        raise AssertionError("ragged instance shard was accepted")
    except ValueError as e:
        msg = str(e)
        assert "shard 2" in msg and "(7, 4)" in msg and "(8, 4)" in msg, msg
    # a valid mask that disagrees with the cache raises the NAMED error at
    # trace time, not an opaque XLA lowering failure
    backend = ShardMapExecBackend()
    eng = ServingEngine(4, pool_tokens=10**6, backend=backend)
    eng.register_chunk("v", holder=1, length=64)
    eng.schedule_step([Request(0, home=0, chunk_ids=["v"], m_q=2)])
    try:
        check_route_shards("instance", jnp.zeros((2, 2, 24)),
                           jnp.zeros((64, 24)), jnp.zeros(63, bool))
        raise AssertionError("ragged valid mask was accepted")
    except ValueError as e:
        assert "disagree" in str(e), e
    print("  shape validation: named-shard ValueErrors up front")


if __name__ == "__main__":
    test_dense_scenarios()
    test_randomized_ab()
    test_pipelined_ab()
    test_pool_retirement()
    test_selection_scenario()
    test_fanout_group()
    test_dead_holder()
    test_flight_recorder()
    test_shape_validation()
    print("SHARD-MAP-EXEC-OK")
