"""Dry-run machinery integration test on a small real mesh (subprocess:
8 host devices, (2,2)+(2,2,2) meshes): build_lowered -> compile -> roofline
extraction works end-to-end for train/prefill/decode kinds, and the
multi-pod 'pod' axis shards."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro.launch.dryrun import analyse, build_lowered, roofline_terms
from repro.launch.mesh import make_mesh

# importing repro.launch.dryrun re-sets XLA_FLAGS to 512 (its mandated
# first lines); flags are read at backend init, so restore 8 before any
# jax device query
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


def check(arch, shape, mesh, kind):
    lowered, meta = build_lowered(arch, shape, mesh)
    compiled = lowered.compile()
    rec = analyse(lowered, compiled, mesh, meta)
    terms = roofline_terms(rec)
    assert meta["kind"] == kind
    assert rec["hlo_flops"] and rec["hlo_flops"] > 0
    assert rec["hlo_bytes"] and rec["hlo_bytes"] > 0
    assert terms["dominant"] is not None
    print(f"  {arch}/{shape} on {dict(mesh.shape)}: ok "
          f"(dominant={terms['dominant']}, "
          f"collectives={rec['collectives']['counts']and True})")
    return rec


def main():
    mesh1 = make_mesh((2, 2), ("data", "model"))
    check("mamba2-370m", "decode_32k", mesh1, "decode")
    check("deepseek-v2-lite", "prefill_32k", mesh1, "prefill")
    rec1 = check("deepseek-v2-lite", "train_4k", mesh1, "train")

    # multi-pod: the pod axis must shard (more devices -> fewer per-device
    # flops for the same global problem)
    mesh2 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rec2 = check("deepseek-v2-lite", "train_4k", mesh2, "train")
    assert rec2["hlo_flops"] < rec1["hlo_flops"], \
        (rec1["hlo_flops"], rec2["hlo_flops"])
    print(f"  pod-axis sharding: flops/device {rec1['hlo_flops']:.2e} -> "
          f"{rec2['hlo_flops']:.2e}")
    print("DIST-DRYRUN-OK")


if __name__ == "__main__":
    assert jax.device_count() == 8
    main()
