"""Distributed routing correctness on a real 8-device mesh (subprocess-only:
forces 8 host devices, so it must NOT run inside the main pytest process).

Verifies §3.3 on the production shard_map transport: fanout, ring, pairwise
routing all reproduce single-instance attention over the concatenated cache;
TPLA rank-pairing (§8) halves/quarters per-rank inter-instance bytes.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.merge import Partial
from repro.core.routing import (route_fanout, route_pairwise,
                                route_pairwise_tpla, route_ring)
from repro.distributed.hlo_analysis import parse_collectives
from repro.models import mla as M
from repro.models.module import KeyGen, split

CFG = M.MLAConfig(d_model=256, n_heads=4, kv_lora_rank=64,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
NI = 8           # instances
B, S_LOCAL = 2, 64
S = NI * S_LOCAL


def build_inputs(seed=0):
    kg = KeyGen(jax.random.PRNGKey(seed))
    params, _ = split(M.init_mla(kg, CFG, dtype=jnp.float32))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                (1, S, CFG.d_model), jnp.float32)
    pos = jnp.arange(S)[None]
    ckv = M.latent_cache_entries(params, CFG, x, pos)[0]          # (S, 576')
    # per-instance decode queries: NI*B rows total
    xq = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 2),
                                 (1, NI * B, CFG.d_model), jnp.float32)
    qn, qr = M.project_q(params, CFG, xq,
                         jnp.full((1, NI * B), S, jnp.float32))
    q_abs = M.absorb_query(params, CFG, qn, qr)[0]                # (NI*B, H, d)
    return q_abs, ckv


def test_fanout_and_ring():
    mesh = jax.make_mesh((NI,), ("instance",))
    q_abs, ckv = build_inputs()
    valid = jnp.ones(S, bool)

    def fan(q, c, v):
        return route_fanout(CFG, q, c, v, axis="instance")

    def ring(q, c, v):
        return route_ring(CFG, q, c, v, axis="instance")

    specs = (P("instance"), P("instance"), P("instance"))
    out_specs = Partial(o=P("instance"), m=P("instance"), l=P("instance"))
    for name, fn in (("fanout", fan), ("ring", ring)):
        shmapped = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=specs,
                                         out_specs=out_specs))
        got = shmapped(q_abs, ckv, valid)
        want = M.absorbed_partial(CFG, q_abs, ckv)
        err = np.max(np.abs(np.asarray(got.o) - np.asarray(want.o)))
        assert err <= 5e-6, (name, err)
        np.testing.assert_allclose(np.asarray(got.l), np.asarray(want.l),
                                   rtol=1e-5)
        print(f"  {name}: max|err| = {err:.2e}")

    # scattered residency (§5.4): random disjoint valid masks, same exactness
    rng = np.random.RandomState(0)
    owner = rng.randint(0, NI, S)
    valid_scattered = jnp.asarray(
        (owner == (np.arange(S) // S_LOCAL)))   # each owns subset of own range
    shmapped = jax.jit(compat.shard_map(fan, mesh=mesh, in_specs=specs,
                                     out_specs=out_specs))
    got = shmapped(q_abs, ckv, valid_scattered)
    want = M.absorbed_partial(CFG, q_abs, ckv,
                              jnp.asarray(np.asarray(valid_scattered))[None, None, :])
    err = np.max(np.abs(np.asarray(got.o) - np.asarray(want.o)))
    assert err <= 5e-6, err
    print(f"  fanout scattered: max|err| = {err:.2e}")


def test_pairwise():
    mesh = jax.make_mesh((NI,), ("instance",))
    q_abs, ckv = build_inputs(seed=7)
    requester, holder = 0, 3

    def pw(q, c):
        # requester's local partial over its own resident shard
        local = M.absorbed_partial(CFG, q, c)
        return route_pairwise(CFG, q, c, local, holder=holder,
                              requester=requester, axis="instance")

    out_specs = Partial(o=P("instance"), m=P("instance"), l=P("instance"))
    shmapped = jax.jit(compat.shard_map(pw, mesh=mesh,
                                     in_specs=(P("instance"), P("instance")),
                                     out_specs=out_specs))
    got = shmapped(q_abs, ckv)
    # requester's rows: merged over shard(requester) + shard(holder)
    mine = slice(requester * B, (requester + 1) * B)
    own = ckv[requester * S_LOCAL:(requester + 1) * S_LOCAL]
    his = ckv[holder * S_LOCAL:(holder + 1) * S_LOCAL]
    want = M.absorbed_partial(CFG, q_abs[mine],
                              jnp.concatenate([own, his], axis=0))
    err = np.max(np.abs(np.asarray(got.o)[mine] - np.asarray(want.o)))
    assert err <= 5e-6, err
    print(f"  pairwise: max|err| = {err:.2e}")


def test_tpla_rank_pairing():
    NTP = 4
    mesh = jax.make_mesh((2, NTP), ("instance", "tp"))
    q_abs, ckv = build_inputs(seed=11)
    q_abs = q_abs[: 2 * B]
    holder_cache = ckv[:S_LOCAL]
    d_c, d_r = CFG.kv_lora_rank, CFG.qk_rope_head_dim

    # column-partition: rank r gets [latent_r | rope_r]
    def rank_slice(arr):
        lat = arr[..., :d_c].reshape(*arr.shape[:-1], NTP, d_c // NTP)
        rope = arr[..., d_c:].reshape(*arr.shape[:-1], NTP, d_r // NTP)
        out = jnp.concatenate([lat, rope], axis=-1)       # (..., NTP, cols)
        return jnp.moveaxis(out, -2, 0)                   # (NTP, ..., cols)

    q_sl = rank_slice(q_abs)                  # (NTP, 2B, H, 144)
    c_sl = rank_slice(holder_cache)           # (NTP, S_LOCAL, 144)
    # broadcast the holder's cache slices to both instances (holder=1 uses it)
    c_both = jnp.broadcast_to(c_sl[None], (2,) + c_sl.shape)   # (2, NTP, S, 144)
    q_both = q_sl.reshape(NTP, 2, B, CFG.n_heads, -1).transpose(1, 0, 2, 3, 4)

    def tpla(q, c):
        q, c = q[0, 0], c[0, 0]               # strip mapped dims
        part = route_pairwise_tpla(CFG, q, c, holder=1, requester=0,
                                   instance_axis="instance", tp_axis="tp")
        return part.o[None, None], part.m[None, None], part.l[None, None]

    fn = jax.jit(compat.shard_map(
        tpla, mesh=mesh,
        in_specs=(P("instance", "tp"), P("instance", "tp")),
        out_specs=(P("instance", "tp", None, None, None),
                   P("instance", "tp", None, None),
                   P("instance", "tp", None, None))))
    o, m, l = fn(q_both, c_both)
    # requester = instance 0: concat rank slices of o -> (B, H, d_c)
    o_req = np.concatenate([np.asarray(o[0, r]) for r in range(NTP)], axis=-1)
    want = M.absorbed_partial(CFG, q_abs[:B], holder_cache)
    err = np.max(np.abs(o_req[:B].reshape(B, CFG.n_heads, d_c)
                        - np.asarray(want.o[:B])))
    assert err <= 5e-6, err
    print(f"  tpla rank-paired: max|err| = {err:.2e}")

    # §8: per-rank inter-instance bytes fall by 1/N. Count collective-permute
    # bytes in the compiled HLO and compare against the unsliced pairwise.
    hlo_tpla = fn.lower(q_both, c_both).compile().as_text()
    cp_tpla = parse_collectives(hlo_tpla).result_bytes.get(
        "collective-permute", 0)

    mesh1 = jax.make_mesh((2, NTP), ("instance", "tp"))
    def plain(q, c):
        q, c = q[0, 0], c[0, 0]
        part = route_pairwise(CFG, q, c,
                              Partial.identity(q.shape[:-1], d_c),
                              holder=1, requester=0, axis="instance")
        return part.o[None, None], part.m[None, None], part.l[None, None]
    q_rep = jnp.broadcast_to(q_abs[:B][None, None],
                             (2, NTP) + q_abs[:B].shape)
    c_rep = jnp.broadcast_to(holder_cache[None, None],
                             (2, NTP) + holder_cache.shape)
    fn2 = jax.jit(compat.shard_map(
        plain, mesh=mesh1,
        in_specs=(P("instance", "tp"), P("instance", "tp")),
        out_specs=(P("instance", "tp", None, None, None),
                   P("instance", "tp", None, None),
                   P("instance", "tp", None, None))))
    hlo_plain = fn2.lower(q_rep, c_rep).compile().as_text()
    cp_plain = parse_collectives(hlo_plain).result_bytes.get(
        "collective-permute", 0)
    ratio = cp_tpla / cp_plain
    print(f"  tpla permute bytes ratio: {ratio:.3f} (expect ~1/{NTP})")
    assert 0.15 < ratio < 0.40, ratio


if __name__ == "__main__":
    assert jax.device_count() == NI, jax.device_count()
    test_fanout_and_ring()
    test_pairwise()
    test_tpla_rank_pairing()
    print("DIST-ROUTING-OK")
