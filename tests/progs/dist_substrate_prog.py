"""Distributed substrate checks on a real 8-device mesh (subprocess-only):

* elastic checkpoint: save under mesh (8,), restore under mesh (4, 2) with
  different shardings — values identical (node-failure/rescale recovery);
* int8 error-feedback compressed gradient sync over a 'pod' axis:
  training parity with full-precision DP within tolerance, wire bytes /4.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.hlo_costs import analyse_hlo
from repro.optim.compress import compressed_psum_with_feedback


def mk_mesh(shape, axes):
    return compat.make_mesh(shape, axes)


def test_elastic_checkpoint():
    mesh_a = mk_mesh((8,), ("data",))
    w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
    w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", None)))
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, {"w": w_a}, blocking=True)
        # "rescale": restore on a DIFFERENT topology + sharding
        mesh_b = mk_mesh((4, 2), ("data", "model"))
        sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
        back = cm.restore(1, {"w": w_a}, shardings=sh)
        np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(w))
        assert back["w"].sharding.mesh.shape == {"data": 4, "model": 2}
    print("  elastic checkpoint: OK")


def test_compressed_dp_parity():
    mesh = mk_mesh((8,), ("pod",))
    # toy regression model, data sharded over 'pod'
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (64, 16))
    y_true = X @ jax.random.normal(jax.random.PRNGKey(1), (16, 1))

    def loss(w, xb, yb):
        return jnp.mean(jnp.square(xb @ w - yb))

    def make_train(compressed):
        def step(w, e, xb, yb):
            g = jax.grad(loss)(w, xb, yb)
            if compressed:
                (g,), (e,) = compressed_psum_with_feedback(
                    (g,), (e,), "pod")
            else:
                g = lax.pmean(g, "pod")
            return w - 0.05 * g, e
        # unchecked: old jax cannot statically infer that the error-feedback
        # state stays replicated through the quantize/dequantize ops
        return jax.jit(compat.shard_map_unchecked(
            step, mesh=mesh,
            in_specs=(P(), P(), P("pod"), P("pod")),
            out_specs=(P(), P())))

    w0 = jnp.zeros((16, 1))
    e0 = jnp.zeros((16, 1))
    ws = {}
    for mode in (False, True):
        train = make_train(mode)
        w, e = w0, e0
        # 300 steps: the PRNG (and so the conditioning of X) varies across
        # jax releases; converge well past the loosest draw's horizon
        for i in range(300):
            w, e = train(w, e, X, y_true)
        ws[mode] = np.asarray(w)
        final = float(loss(jnp.asarray(ws[mode]), X, y_true))
        print(f"  compressed={mode}: final loss {final:.6f}")
        assert final < 1e-3, final
    # error feedback keeps the trajectories close
    assert np.max(np.abs(ws[True] - ws[False])) < 0.05

    # wire accounting: the compressed step's all-reduce payload is int8
    txt = make_train(True).lower(w0, e0, X, y_true).compile().as_text()
    assert "s8[" in txt or "s32[" in txt
    print("  compressed DP parity: OK")


def test_collective_matmul_overlap():
    """Beyond-paper TP overlap: ppermute-pipelined all-gather matmul ==
    the barrier all-gather matmul == the dense reference (DESIGN.md §5)."""
    from repro.distributed.collective_matmul import (
        allgather_matmul_barrier, allgather_matmul_overlapped)
    mesh = mk_mesh((8,), ("tp",))
    m, d, n = 32, 16, 64
    x = jax.random.normal(jax.random.PRNGKey(2), (m, d))
    w = jax.random.normal(jax.random.PRNGKey(3), (d, n))

    for fn in (allgather_matmul_overlapped, allgather_matmul_barrier):
        sm = jax.jit(compat.shard_map(
            lambda xs, wb: fn(xs, wb, "tp"), mesh=mesh,
            in_specs=(P("tp", None), P(None, "tp")),
            out_specs=P("tp", None)))
        got = sm(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=2e-5, atol=2e-5)
    # the overlapped form uses ppermute (pipelined), not one big all-gather
    sm_o = jax.jit(compat.shard_map(
        lambda xs, wb: allgather_matmul_overlapped(xs, wb, "tp"), mesh=mesh,
        in_specs=(P("tp", None), P(None, "tp")), out_specs=P("tp", None)))
    txt = sm_o.lower(x, w).compile().as_text()
    assert "collective-permute" in txt
    print("  collective matmul overlap: OK")


if __name__ == "__main__":
    assert jax.device_count() == 8
    test_elastic_checkpoint()
    test_compressed_dp_parity()
    test_collective_matmul_overlap()
    print("DIST-SUBSTRATE-OK")
