"""Online-softmax merge algebra (§3.2, §3.3): commutativity, zero-weight
identity, associativity/partition-invariance — bit-level and property-based."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.merge import (Partial, merge2, merge_stacked, merge_tree,
                              partial_from_logits)

jax.config.update("jax_enable_x64", False)


def _rand_partial(key, shape=(2, 4), d_v=8, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    logits = scale * jax.random.normal(k1, shape + (16,))
    values = jax.random.normal(k2, shape + (16, d_v))
    return partial_from_logits(logits, values)


def _assert_partial_close(a: Partial, b: Partial, atol=1e-6):
    np.testing.assert_allclose(a.o, b.o, atol=atol)
    np.testing.assert_allclose(a.l, b.l, rtol=1e-5)


class TestMergeAlgebra:
    def test_commutativity_bit_identical(self):
        # §3.3: "verified in unit tests for commutativity".
        a = _rand_partial(jax.random.PRNGKey(0))
        b = _rand_partial(jax.random.PRNGKey(1))
        ab, ba = merge2(a, b), merge2(b, a)
        # merge2 is symmetric up to the addition order in wa+wb; assert
        # bit-identical outputs (addition of two floats is commutative).
        assert np.array_equal(np.asarray(ab.o), np.asarray(ba.o))
        assert np.array_equal(np.asarray(ab.l), np.asarray(ba.l))
        assert np.array_equal(np.asarray(ab.m), np.asarray(ba.m))

    def test_zero_weight_identity(self):
        # §3.3: "the zero-weight identity".
        a = _rand_partial(jax.random.PRNGKey(2))
        ident = Partial.identity(a.m.shape, a.o.shape[-1])
        _assert_partial_close(merge2(a, ident), a, atol=0)
        _assert_partial_close(merge2(ident, a), a, atol=0)

    def test_identity_merge_identity(self):
        ident = Partial.identity((3,), 4)
        out = merge2(ident, ident)
        assert not np.any(np.isnan(out.o))
        assert np.all(np.asarray(out.l) == 0)

    def test_merge_equals_full_softmax(self):
        # Partition a logit row arbitrarily; merged == softmax over the whole.
        key = jax.random.PRNGKey(3)
        logits = jax.random.normal(key, (2, 3, 64))
        values = jax.random.normal(jax.random.PRNGKey(4), (2, 3, 64, 8))
        full = partial_from_logits(logits, values)
        cuts = [0, 7, 13, 40, 64]
        parts = [partial_from_logits(logits[..., a:b], values[..., a:b, :])
                 for a, b in zip(cuts[:-1], cuts[1:])]
        _assert_partial_close(merge_tree(parts), full, atol=1e-6)

    def test_partition_invariance_fp32(self):
        # §3.3: invariant to M up to 8 and to how the set is partitioned,
        # to fp32 round-off (<= 4e-7 max-absolute).
        key = jax.random.PRNGKey(5)
        logits = jax.random.normal(key, (4, 512))
        values = jax.random.normal(jax.random.PRNGKey(6), (4, 512, 16))
        full = partial_from_logits(logits, values)
        rng = np.random.RandomState(0)
        for m in range(2, 9):
            cuts = np.sort(rng.choice(np.arange(1, 512), m - 1, replace=False))
            cuts = [0] + list(cuts) + [512]
            parts = [partial_from_logits(logits[..., a:b], values[..., a:b, :])
                     for a, b in zip(cuts[:-1], cuts[1:])]
            merged = merge_tree(parts)
            err = np.max(np.abs(np.asarray(merged.o) - np.asarray(full.o)))
            assert err <= 4e-6, (m, err)   # fp32 round-off scale

    def test_stacked_matches_tree(self):
        parts = [_rand_partial(jax.random.PRNGKey(i)) for i in range(5)]
        ident = Partial.identity(parts[0].m.shape, parts[0].o.shape[-1])
        stacked = Partial(
            o=jnp.stack([p.o for p in parts] + [ident.o]),
            m=jnp.stack([p.m for p in parts] + [ident.m]),
            l=jnp.stack([p.l for p in parts] + [ident.l]),
        )
        _assert_partial_close(merge_stacked(*stacked), merge_tree(parts),
                              atol=1e-6)

    def test_empty_shard_is_harmless(self):
        # A holder whose resident mask is empty returns identity.
        logits = jnp.full((2, 8), -jnp.inf)
        values = jnp.zeros((2, 8, 4))
        p = partial_from_logits(logits, values)
        assert np.all(np.asarray(p.l) == 0)
        a = _rand_partial(jax.random.PRNGKey(7), shape=(2,), d_v=4)
        _assert_partial_close(merge2(a, p), a, atol=0)


class TestMergeProperties:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 8),
           st.floats(0.1, 20.0))
    def test_partition_invariance_property(self, seed, m, scale):
        # Property: any M-way split of any (scaled) logit set merges to the
        # full softmax. Large scales stress the max-shift path.
        key = jax.random.PRNGKey(seed)
        s = 128
        logits = scale * jax.random.normal(key, (2, s))
        values = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, s, 4))
        full = partial_from_logits(logits, values)
        rng = np.random.RandomState(seed % 2**16)
        cuts = np.sort(rng.choice(np.arange(1, s), m - 1, replace=False))
        cuts = [0] + list(cuts) + [s]
        parts = [partial_from_logits(logits[..., a:b], values[..., a:b, :])
                 for a, b in zip(cuts[:-1], cuts[1:])]
        merged = merge_tree(parts)
        np.testing.assert_allclose(merged.o, full.o, atol=2e-5)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_merge_associativity_property(self, seed):
        a = _rand_partial(jax.random.PRNGKey(seed))
        b = _rand_partial(jax.random.PRNGKey(seed + 1))
        c = _rand_partial(jax.random.PRNGKey(seed + 2))
        left = merge2(merge2(a, b), c)
        right = merge2(a, merge2(b, c))
        np.testing.assert_allclose(left.o, right.o, atol=1e-5)
        np.testing.assert_allclose(left.l, right.l, rtol=1e-5)
