"""MLA correctness: absorbed decode == decompressed train-form attention;
routed/simulated partition == single-instance attention (§3.3); bf16 wire
quantization stays inside the paper's noise floor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.merge import merge_tree
from repro.core.routing import route_simulated
from repro.models import mla as M
from repro.models.module import KeyGen, split


CFG = M.MLAConfig(d_model=256, n_heads=4, kv_lora_rank=64,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)


@pytest.fixture(scope="module")
def setup():
    kg = KeyGen(jax.random.PRNGKey(0))
    params_ax = M.init_mla(kg, CFG, dtype=jnp.float32)
    params, _ = split(params_ax)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 33, CFG.d_model),
                                jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(33)[None], (2, 33))
    return params, x, positions


class TestAbsorbedEquivalence:
    def test_absorbed_decode_matches_train_form(self, setup):
        params, x, positions = setup
        out_train, entries = M.mla_attention(params, CFG, x, positions)
        # decode the last token against the cache of the first S-1 entries
        out_dec, new_entry = M.absorbed_decode(
            params, CFG, x[:, -1:], entries[:, :-1], positions[:, -1:])
        np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                                   np.asarray(out_train[:, -1]),
                                   atol=2e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(new_entry[:, 0]),
                                   np.asarray(entries[:, -1]), atol=1e-5)

    def test_absorbed_query_width_is_wire_row(self, setup):
        params, x, positions = setup
        qn, qr = M.project_q(params, CFG, x, positions)
        q_abs = M.absorb_query(params, CFG, qn, qr)
        assert q_abs.shape[-1] == CFG.d_qk == CFG.kv_lora_rank + CFG.qk_rope_head_dim

    def test_v2_dims_give_paper_payload(self):
        cfg = M.MLAConfig()   # defaults = V2 geometry
        assert cfg.d_qk == 576
        assert cfg.kv_lora_rank == 512


class TestRoutedPartition:
    """§3.3: routed + merged == single-instance over the concatenated cache."""

    def _qc(self, s=96, seed=0):
        kg = KeyGen(jax.random.PRNGKey(seed))
        params, _ = split(M.init_mla(kg, CFG, dtype=jnp.float32))
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                    (1, s, CFG.d_model), jnp.float32)
        pos = jnp.arange(s)[None]
        entries = M.latent_cache_entries(params, CFG, x, pos)[0]   # (S, 576)
        qn, qr = M.project_q(params, CFG, x[:, -1:], pos[:, -1:])
        q_abs = M.absorb_query(params, CFG, qn, qr)[:, 0]          # (1, H, 576)
        return q_abs, entries

    def test_two_instance_route_merge_fp32(self):
        q, ckv = self._qc()
        full = M.absorbed_partial(CFG, q, ckv)
        half = ckv.shape[0] // 2
        merged = route_simulated(CFG, q, [ckv[:half], ckv[half:]])
        err = np.max(np.abs(np.asarray(merged.o) - np.asarray(full.o)))
        assert err <= 4e-6   # fp32 round-off (paper: <=4e-7 at fp64 ref)

    def test_multiholder_partition_invariant_m_up_to_8(self):
        q, ckv = self._qc(s=128)
        full = M.absorbed_partial(CFG, q, ckv)
        rng = np.random.RandomState(0)
        for m in (2, 3, 5, 8):
            cuts = [0] + sorted(rng.choice(range(1, 128), m - 1,
                                           replace=False)) + [128]
            shards = [ckv[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
            merged = route_simulated(CFG, q, shards)
            err = np.max(np.abs(np.asarray(merged.o) - np.asarray(full.o)))
            assert err <= 4e-6, (m, err)

    def test_scattered_disjoint_subsets(self):
        # Scattered (non-contiguous) residency: same exactness (§3.3).
        q, ckv = self._qc(s=128)
        full = M.absorbed_partial(CFG, q, ckv)
        rng = np.random.RandomState(1)
        assign = rng.randint(0, 4, 128)
        shards, masks = [], None
        parts = []
        for j in range(4):
            idx = np.where(assign == j)[0]
            parts.append(M.absorbed_partial(CFG, q, ckv[idx]))
        merged = merge_tree(parts)
        err = np.max(np.abs(np.asarray(merged.o) - np.asarray(full.o)))
        assert err <= 4e-6

    def test_bf16_wire_inside_noise_floor(self):
        # §3.3: route over a bf16 wire reproduces the fp32 reference inside
        # the bf16 noise floor (paper: 0.0014 << 0.05 floor).
        q, ckv = self._qc(s=128)
        full = M.absorbed_partial(CFG, q, ckv)
        # quantize the routed query and returned partial to bf16
        qw = q.astype(jnp.bfloat16).astype(jnp.float32)
        half = 64
        parts = []
        for sh in (ckv[:half], ckv[half:]):
            p = M.absorbed_partial(CFG, qw, sh)
            parts.append(type(p)(o=p.o.astype(jnp.bfloat16).astype(jnp.float32),
                                  m=p.m, l=p.l))
        merged = merge_tree(parts)
        err = np.max(np.abs(np.asarray(merged.o) - np.asarray(full.o)))
        # bf16 has ~3 decimal digits: noise floor ~5e-2 for O(1) outputs
        assert err < 5e-2
        assert err > 0   # the wire actually quantized something
