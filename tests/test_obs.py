"""The flight recorder's organs (ISSUE 9): metrics registry semantics,
drift-monitor verdicts (threshold trip / EWMA decay / per-stage keying /
the injected mis-calibrated fabric table), and the two engine contracts —
observability NEVER changes planner behavior, and a disabled recorder
costs (near) nothing on the step path."""

import dataclasses
import math

import pytest

from engine_scenarios import SCENARIOS
from repro.obs import (NULL_OBS, DriftConfig, DriftError, DriftMonitor,
                       Obs, Tracer)
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.serving import timeline as TL


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_labels(self):
        m = MetricsRegistry()
        m.counter("x", fabric="ici").inc()
        m.counter("x", fabric="ici").inc(2.5)
        m.counter("x", fabric="dcn").inc()
        m.gauge("g", i=0).set(7)
        m.gauge("g", i=0).set(3)          # last write wins
        snap = m.snapshot()
        assert snap["counters"]["x{fabric=ici}"] == 3.5
        assert snap["counters"]["x{fabric=dcn}"] == 1.0
        assert snap["gauges"]["g{i=0}"] == 3.0

    def test_label_order_is_canonical(self):
        m = MetricsRegistry()
        assert m.counter("y", b=1, a=2) is m.counter("y", a=2, b=1)

    def test_interned_reference_is_live(self):
        m = MetricsRegistry()
        c = m.counter("hot")
        for _ in range(5):
            c.inc()
        assert m.counter_value("hot") == 5.0

    def test_histogram_streams_without_sample_storage(self):
        h = Histogram()
        n_buckets = len(h.buckets)
        for i in range(10_000):
            h.observe(1e-6 * (1 + i % 100))
        # bounded memory: the bucket array never grows
        assert len(h.buckets) == n_buckets
        s = h.summary()
        assert s["count"] == 10_000
        assert s["min"] == pytest.approx(1e-6)
        assert s["max"] == pytest.approx(1e-4)
        # log-bucket interpolation: p50 within a bucket-width of the true
        # median (~5.05e-5 for the uniform 1..100 multiplier)
        assert 2e-5 < s["p50"] < 8e-5
        assert s["p99"] <= s["max"]
        assert s["p50"] >= s["min"]

    def test_histogram_clamps_outliers(self):
        h = Histogram()
        h.observe(0.0)            # below span -> first bucket
        h.observe(1e9)            # above span -> last bucket
        s = h.summary()
        assert s["count"] == 2 and s["min"] == 0.0 and s["max"] == 1e9
        assert s["p50"] <= 1e9 and not math.isnan(s["p50"])

    def test_snapshot_deterministic(self):
        def build():
            m = MetricsRegistry()
            m.counter("b").inc(2)
            m.counter("a", z=1).inc()
            m.histogram("h").observe(0.5)
            m.gauge("g").set(1)
            return m.to_json()
        assert build() == build()


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------

KEY = ("route", 0, "transfer")


class TestDrift:
    def test_threshold_trip(self):
        d = DriftMonitor(DriftConfig(threshold=0.07, min_samples=3))
        for _ in range(4):
            d.observe_residual(KEY, 0.5)
        assert [k for k, _ in d.tripped()] == [KEY]
        with pytest.raises(DriftError, match="route/f0/transfer"):
            d.check()

    def test_min_samples_gate(self):
        d = DriftMonitor(DriftConfig(threshold=0.07, min_samples=3))
        d.observe_residual(KEY, 5.0)
        d.observe_residual(KEY, 5.0)
        assert d.tripped() == []          # loud but not yet conclusive
        d.observe_residual(KEY, 5.0)
        assert d.tripped() != []

    def test_ewma_decay(self):
        cfg = DriftConfig(threshold=0.07, alpha=0.25, min_samples=1)
        d = DriftMonitor(cfg)
        d.observe_residual(KEY, 1.0)      # transient spike
        assert d.tripped() != []
        ew = 1.0
        for _ in range(20):               # calibration healthy again
            d.observe_residual(KEY, 0.0)
            ew *= (1 - cfg.alpha)
            assert d.cells[KEY].ewma == pytest.approx(ew)
        assert d.tripped() == []          # the spike decayed out
        assert d.cells[KEY].worst == 1.0  # ... but stays on record

    def test_per_stage_keying(self):
        d = DriftMonitor(DriftConfig(threshold=0.07, min_samples=2))
        other = ("route", 0, "probe")
        cross = ("route", 1, "transfer")
        for _ in range(3):
            d.observe_residual(KEY, 0.9)
            d.observe_residual(other, 0.01)
            d.observe_residual(cross, -0.01)
        tripped = dict(d.tripped())
        assert KEY in tripped
        assert other not in tripped and cross not in tripped

    def test_negative_drift_trips_too(self):
        d = DriftMonitor(DriftConfig(threshold=0.07, min_samples=2))
        for _ in range(3):
            d.observe_residual(KEY, -0.2)  # model OVERprices: still drift
        assert [k for k, _ in d.tripped()] == [KEY]

    def test_injected_miscalibrated_fabric_table(self):
        """The acceptance scenario: a fabric table whose bandwidth fit
        rotted by 2.5x inflates every wire-stage wall by 2.5x relative to
        the model. Feeding those measured flows through the monitor must
        trip exactly the wire-stage cells, while compute/merge cells
        (whose calibration did not change) stay inside the envelope."""
        eng, steps = SCENARIOS["mixed_congested"]()
        reports = []
        for reqs in steps:
            eng.schedule_step(reqs)
            analytic = eng.timelines[-1]
            measured_flows = []
            for f in analytic.flows:
                stages = tuple(
                    dataclasses.replace(
                        s, duration_s=s.duration_s
                        * (2.5 if s.name in TL.WIRE_STAGES else 1.0))
                    for s in f.stages)
                measured_flows.append(dataclasses.replace(f, stages=stages))
            reports.append(TL.measured_vs_analytic(
                eng.step_idx, analytic, measured_flows))
        d = DriftMonitor(DriftConfig(threshold=0.07, min_samples=1))
        for rep in reports:
            assert d.observe_report(rep) > 0
        tripped = dict(d.tripped())
        assert tripped, "mis-calibrated wire constants must trip"
        wire_cells = [k for k in tripped if k[2] in TL.WIRE_STAGES]
        assert wire_cells, f"expected wire-stage cells, got {tripped}"
        # attribution: untouched (non-wire) stage cells stay healthy
        assert all(k[2] in TL.WIRE_STAGES for k in tripped), tripped
        # the injected 150% inflation is what the EWMA converged to
        for k in wire_cells:
            assert d.cells[k].ewma == pytest.approx(1.5, abs=1e-9)
        with pytest.raises(DriftError):
            d.check()

    def test_healthy_report_does_not_trip(self):
        """measured == analytic (residual 0 everywhere): silence."""
        eng, steps = SCENARIOS["routed_only"]()
        d = DriftMonitor(DriftConfig(threshold=0.07, min_samples=1))
        for reqs in steps:
            eng.schedule_step(reqs)
            analytic = eng.timelines[-1]
            d.observe_report(TL.measured_vs_analytic(
                eng.step_idx, analytic, list(analytic.flows)))
        assert d.n_residuals > 0
        assert d.tripped() == []
        d.check()                          # must not raise


# ---------------------------------------------------------------------------
# engine contracts: no behavior change, (near-)zero disabled cost
# ---------------------------------------------------------------------------


def _stats_signature(eng):
    """Everything in StepStats except the wall clock."""
    return [dataclasses.replace(s, sched_wall_s=0.0) for s in eng.stats]


class TestEngineContracts:
    def test_default_engine_uses_null_obs(self):
        eng, _ = SCENARIOS["routed_only"]()
        assert eng.obs is NULL_OBS
        assert NULL_OBS.enabled is False

    def test_obs_never_changes_decisions(self):
        """Active tracer+metrics+drift: StepStats, records, and residency
        stay bit-identical to the bare engine on every golden scenario."""
        for name, build in SCENARIOS.items():
            eng_a, steps = build()
            eng_b, _ = build()
            obs = Obs(tracer=Tracer(), drift=DriftMonitor())
            eng_b.obs = obs
            obs.bind_engine(eng_b)
            for reqs in steps:
                ra = eng_a.schedule_step(reqs)
                rb = eng_b.schedule_step(reqs)
                assert ra == rb, name
            assert _stats_signature(eng_a) == _stats_signature(eng_b), name
            assert obs.metrics.counter_value("engine.steps") == len(steps)

    def test_disabled_recorder_near_zero_overhead(self):
        """The hot-path guarantee: with observability off the step path
        pays one identity check. We pin the mechanism (default obs IS the
        inert singleton, planner caches count via plain ints) and bound
        the wall-clock ratio generously — the binding perf gate is the CI
        planner-bench floor, which runs the 128x64 workload."""
        build = SCENARIOS["routed_only"]
        import time

        def run(with_obs):
            eng, steps = build()
            if with_obs:
                obs = Obs(tracer=Tracer(), drift=DriftMonitor())
                eng.obs = obs
                obs.bind_engine(eng)
            t0 = time.perf_counter()
            for _ in range(30):
                for reqs in steps:
                    eng.schedule_step(reqs)
            return time.perf_counter() - t0, eng

        base_t, base_eng = run(False)
        obs_t, obs_eng = run(True)
        assert base_eng.obs is NULL_OBS
        # planner cache counters run unconditionally and agree
        assert (base_eng.planner_cache_stats()
                == obs_eng.planner_cache_stats())
        # sched_wall (plan+execute, obs excluded by construction) within
        # noise; the enabled run's EXTRA work lives outside that window
        base_wall = sum(s.sched_wall_s for s in base_eng.stats)
        obs_wall = sum(s.sched_wall_s for s in obs_eng.stats)
        assert obs_wall < base_wall * 3 + 0.05, (base_wall, obs_wall)

    def test_on_step_publishes_registry(self):
        eng, steps = SCENARIOS["mixed_congested"]()
        obs = Obs()
        eng.obs = obs
        obs.bind_engine(eng)
        for reqs in steps:
            eng.schedule_step(reqs)
        m = obs.metrics
        snap = m.snapshot()
        # decisions by verdict: all three primitives appear in the mix
        assert m.counter_value("engine.dispatches", primitive="route") > 0
        assert m.counter_value("engine.dispatches", primitive="local") > 0
        # bytes by fabric flow onto the wire counters
        assert any(k.startswith("engine.wire_bytes{")
                   for k in snap["counters"])
        # the §8 congested link (K=4 on holder 1) is visible
        assert m.counter_value("engine.congested_links") > 0
        # planner cache + schedule memo gauges published
        assert "planner.cache.sig_hit" in snap["gauges"]
        assert "planner.sim_memo.miss" in snap["gauges"]
        # store occupancy gauges per instance
        assert "store.pool_used_tokens{instance=0}" in snap["gauges"]

    def test_store_churn_counters_via_listener(self):
        eng, steps = SCENARIOS["fetch_heavy"]()
        obs = Obs()
        eng.obs = obs
        obs.bind_engine(eng)
        for reqs in steps:
            eng.schedule_step(reqs)
        # force churn: evict a fetched replica, then kill its holder
        evicted_before = sum(
            v for k, v in obs.metrics.snapshot()["counters"].items()
            if k.startswith("store.copy_retirements"))
        replicated = [cid for cid in ("doc0", "doc1", "doc2")
                      if len(eng.store.holders_of(cid)) > 1]
        assert replicated, "fetch_heavy must have spawned replicas"
        cid = replicated[0]
        extra = [h for h in eng.store.holders_of(cid)
                 if h != eng.store.lookup(cid).holder][0]
        eng.store.evict_replica(cid, extra)
        after = sum(
            v for k, v in obs.metrics.snapshot()["counters"].items()
            if k.startswith("store.copy_retirements"))
        assert after == evicted_before + 1
