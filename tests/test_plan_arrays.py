"""ISSUE 6: the columnar plan -> timeline path is an exact re-expression
of the object path, not an approximation. Three layers of lockdown:

  * scheduler exactness — simulate_arrays() equals simulate() stage-for-
    stage (same schedule order, same start/end floats, same aggregates)
    on randomized flow sets, zero-duration stages included; negative
    durations delegate to the object oracle by contract;
  * planner A/B — EngineConfig.vectorized_plan False vs True produces
    bitwise-identical DispatchRecords and StepStats (sched_wall_s aside)
    over every golden scenario, the selection trace, and a multi-step
    randomized workload with evictions and replica spawns;
  * round trip — StepPlanArrays.to_records()/from_records() loses
    nothing: records -> arrays -> records is the identity on the golden
    traces.

The randomized scheduler properties run under hypothesis (dev-only; that
class skips without it — requirements-dev.txt). Everything else is
deterministic and always on."""

import dataclasses

import numpy as np
import pytest

from engine_scenarios import SCENARIOS, selection_scenario
from repro.serving import plan as PL
from repro.serving import timeline as TL
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.selection import IndexerService
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    materialize_trace, register_corpus)

# ---------------------------------------------------------------------------
# Shared drivers.
# ---------------------------------------------------------------------------


def _drive(eng, steps):
    """Run a trace and return everything the A/B compares: records (as
    tuples — bitwise, floats included), StepStats minus wall-clock, and
    the final residency map."""
    for reqs in steps:
        eng.schedule_step(reqs)
    recs = [dataclasses.astuple(r) for r in eng.log]
    stats = []
    for s in eng.stats:
        d = dataclasses.asdict(s)
        d.pop("sched_wall_s")           # the only non-simulated field
        stats.append(d)
    residency = sorted(
        (cid, c.holder, tuple(sorted(c.replicas)), c.last_access)
        for cid, c in eng.store._chunks.items())
    return recs, stats, residency


def _scenario(name, vectorized):
    if name == "selection":
        eng, steps = selection_scenario(selector=IndexerService())
    else:
        eng, steps = SCENARIOS[name]()
    eng.cfg.vectorized_plan = vectorized
    return eng, steps


GOLDEN_NAMES = sorted(SCENARIOS) + ["selection"]


# ---------------------------------------------------------------------------
# Planner A/B: object oracle vs array path, bit for bit.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", GOLDEN_NAMES)
def test_ab_bit_identical_golden(name):
    a = _drive(*_scenario(name, vectorized=True))
    b = _drive(*_scenario(name, vectorized=False))
    assert a == b


def test_ab_bit_identical_workload():
    """A multi-step randomized workload — session churn, evictions,
    replica spawns, congestion — planned through both paths. The pool is
    sized below the working set on purpose so replacement runs."""
    def build(vec):
        eng = ServingEngine(8, pool_tokens=24 * 2048,
                            cfg=EngineConfig(vectorized_plan=vec),
                            instances_per_pod=4)
        w = WorkloadConfig(n_steps=24, agents=16, n_corpus_chunks=20,
                           chunk_tokens=2048, session_steps=(4, 12),
                           selection_frac=0.0, seed=7)
        cids = register_corpus(eng, w)
        steps = materialize_trace(agentic_trace(w, eng, cids))
        return eng, steps

    a = _drive(*build(True))
    b = _drive(*build(False))
    assert len(a[0]) > 0
    assert a == b


# ---------------------------------------------------------------------------
# StepPlanArrays round trip on the golden traces.
# ---------------------------------------------------------------------------


def _arrays_equal(x: PL.StepPlanArrays, y: PL.StepPlanArrays) -> None:
    assert x.step == y.step
    assert x.chunk_ids == y.chunk_ids
    for f in ("prim", "holder", "chunk", "n_requesters", "m_q_total",
              "est_cost_s", "backup", "fabric_idx", "link_instance",
              "home", "stage_off", "stage_code", "stage_dur", "req_off",
              "req_ids"):
        a, b = getattr(x, f), getattr(y, f)
        assert a.dtype == b.dtype, f
        assert np.array_equal(a, b), f


@pytest.mark.parametrize("name", GOLDEN_NAMES)
def test_round_trip_golden(name):
    """records -> StepPlanArrays -> records is the identity (bitwise: the
    dataclass == compares est_cost_s and stage floats exactly), and the
    arrays themselves survive a second columnarization."""
    eng, steps = _scenario(name, vectorized=True)
    saw_records = 0
    for reqs in steps:
        recs = eng.schedule_step(reqs)
        arr = eng.plans[-1].arrays
        assert arr is not None           # the array path planned this step
        assert recs == arr.to_records()
        rt = PL.StepPlanArrays.from_records(arr.step, recs)
        assert rt.to_records() == recs
        _arrays_equal(rt, PL.StepPlanArrays.from_records(arr.step,
                                                         rt.to_records()))
        saw_records += len(recs)
    assert saw_records > 0


# ---------------------------------------------------------------------------
# Scheduler exactness: simulate_arrays == simulate, deterministic corners.
# ---------------------------------------------------------------------------


def _assert_schedules_identical(flows):
    want = TL.simulate(flows)
    got = TL.simulate_arrays(TL.FlowArrays.from_flows(flows))
    assert isinstance(got, TL.ArrayTimeline)
    # the schedule itself: same stages, same resources, same start/end
    # floats, in the same pop order
    assert got.scheduled == want.scheduled
    assert got.makespan_s == want.makespan_s
    assert got.serial_s == want.serial_s
    # the one-pass aggregates (satellite: Timeline caches these too)
    assert got.stage_totals() == want.stage_totals()
    assert got.busy_s() == want.busy_s()
    assert got.link_flow_counts() == want.link_flow_counts()
    for f in flows:
        assert got.flow_end_s(f.key) == want.flow_end_s(f.key)
    assert got.max_flow_serial_s == want.max_flow_serial_s
    assert got.overlap_efficiency == want.overlap_efficiency


def _mk_flows(spec):
    """spec: per flow, (primitive, link or None, holder, requester,
    durations)."""
    flows = []
    for i, (prim, link, holder, req, durs) in enumerate(spec):
        names = {"route": ("probe", "transfer", "compute", "return",
                           "merge"),
                 "fetch": ("pull", "splice"),
                 "local": ("prefill",)}[prim]
        stages = tuple(zip(names, durs))
        flows.append(TL.transport_flow(
            f"{prim}#{i}", stages,
            link_res=TL.link(*link) if link else None,
            holder_sm=TL.sm(holder), requester_sm=TL.sm(req),
            primitive=prim))
    return flows


def test_exact_zero_durations():
    """Zero-duration stages (the selection regime emits them when
    sel_frac is 0) schedule identically — ties resolve by flow index in
    both schedulers."""
    flows = _mk_flows([
        ("route", (0, 0), 0, 1, (0.0, 0.0, 0.0, 0.0, 0.0)),
        ("route", (0, 0), 0, 2, (0.0, 1e-6, 0.0, 1e-6, 0.0)),
        ("fetch", (0, 1), 0, 1, (0.0, 0.0)),
        ("local", None, 1, 1, (0.0,)),
    ])
    _assert_schedules_identical(flows)


def test_exact_contended_link():
    """Several flows queueing on one link: starts serialize in index
    order, exactly as the object scan does."""
    flows = _mk_flows([
        ("route", (1, 0), 1, i, (1e-6, 5e-6, 2e-6, 5e-6, 1e-6))
        for i in range(4)
    ] + [("fetch", (1, 0), 1, 0, (8e-6, 3e-6))])
    _assert_schedules_identical(flows)


def test_negative_durations_delegate_to_oracle():
    """Negative durations break the heap's monotonicity argument; the
    array scheduler hands that never-emitted corner to simulate()."""
    flows = _mk_flows([("fetch", (0, 0), 0, 1, (-1e-6, 1e-6))])
    out = TL.simulate_arrays(TL.FlowArrays.from_flows(flows))
    assert isinstance(out, TL.Timeline)


def test_empty_flow_set():
    _assert_schedules_identical([])


# ---------------------------------------------------------------------------
# Randomized scheduler equality (hypothesis, dev-only).
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover - dev-only dep
    st = None

if st is not None:
    durations = st.one_of(
        st.just(0.0),
        st.floats(min_value=1e-9, max_value=1e-2,
                  allow_nan=False, allow_infinity=False))

    @st.composite
    def flow_sets(draw):
        n = draw(st.integers(min_value=0, max_value=10))
        spec = []
        for _ in range(n):
            prim = draw(st.sampled_from(["route", "fetch", "local"]))
            n_stages = {"route": 5, "fetch": 2, "local": 1}[prim]
            durs = tuple(draw(durations) for _ in range(n_stages))
            link = (None if prim == "local"
                    else (draw(st.integers(0, 2)),
                          draw(st.integers(0, 1))))
            spec.append((prim, link,
                         draw(st.integers(0, 3)), draw(st.integers(0, 3)),
                         durs))
        return _mk_flows(spec)

    @given(flow_sets())
    @settings(max_examples=200, deadline=None)
    def test_simulate_arrays_equals_simulate(flows):
        _assert_schedules_identical(flows)
else:
    @pytest.mark.skip(
        reason="property tests need hypothesis (requirements-dev.txt)")
    def test_simulate_arrays_equals_simulate():
        pass
