"""The three frozen serving-engine scenarios shared by the golden
regression tests (tests/test_engine_golden.py) and the backend
parity/exactness tests (tests/test_backends.py).

Keep these REPRODUCIBLE-BY-CONSTRUCTION: fixed request lists, no RNG, no
wall-clock. Each builder takes an optional ExecutionBackend so the SAME
trace can drive the analytic engine (golden fixtures) and the exec engine
(real-array execution).
"""

from repro.serving.engine import EngineConfig, Request, ServingEngine


def _routed_only(backend=None):
    """Decode-shaped traffic (m_q moderate, reuse 1): every pair ROUTEs;
    two pods exercise per-fabric dispatch splitting."""
    eng = ServingEngine(8, pool_tokens=10**6, cfg=EngineConfig(),
                        instances_per_pod=4, backend=backend)
    for i in range(6):
        eng.register_chunk(f"c{i}", holder=i % 4, length=2048)
    steps = [
        [Request(0, home=4, chunk_ids=["c0", "c1"], m_q=64),
         Request(1, home=5, chunk_ids=["c2"], m_q=128),
         Request(2, home=1, chunk_ids=["c0"], m_q=32)],
        [Request(0, home=4, chunk_ids=["c0", "c1"], m_q=64),
         Request(3, home=6, chunk_ids=["c3", "c4"], m_q=16)],
        [Request(4, home=2, chunk_ids=["c5"], m_q=256)],
    ]
    return eng, steps


def _fetch_heavy(backend=None):
    """Long reuse horizons (m_q=1): FETCH wins, persists, then the SAME
    requests go resident — the last step is empty (no transport at all)."""
    eng = ServingEngine(4, pool_tokens=10**6, cfg=EngineConfig(),
                        backend=backend)
    for i in range(3):
        eng.register_chunk(f"doc{i}", holder=1 + (i % 3), length=2048)
    reqs = [Request(i, home=0, chunk_ids=[f"doc{i}"], m_q=1,
                    expected_reuse_steps=100_000) for i in range(3)]
    return eng, [reqs, reqs, reqs]


def _mixed_congested(backend=None):
    """One holder serving 4 routed chunks (K=4 on its link: the §8 premium
    derived from occupancy), a fetchy long-reuse reader, and a tiny chunk
    whose re-prefill undercuts transport (LOCAL) — all three primitives and
    the congestion path in one trace."""
    eng = ServingEngine(8, pool_tokens=10**6, cfg=EngineConfig(),
                        instances_per_pod=8, backend=backend)
    for i in range(4):
        eng.register_chunk(f"hot{i}", holder=1, length=2048)
    eng.register_chunk("cold", holder=2, length=2048)
    eng.register_chunk("tiny", holder=1, length=8)
    steps = [
        [Request(i, home=3 + i, chunk_ids=[f"hot{i}"], m_q=1024)
         for i in range(4)]
        + [Request(10, home=7, chunk_ids=["cold"], m_q=1,
                   expected_reuse_steps=100_000),
           Request(11, home=6, chunk_ids=["tiny"], m_q=4096)],
        [Request(i, home=3 + i, chunk_ids=[f"hot{i}"], m_q=1024)
         for i in range(2)]
        + [Request(10, home=7, chunk_ids=["cold"], m_q=1,
                   expected_reuse_steps=100_000)],
    ]
    return eng, steps


SCENARIOS = {
    "routed_only": _routed_only,
    "fetch_heavy": _fetch_heavy,
    "mixed_congested": _mixed_congested,
}
