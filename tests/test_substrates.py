"""Data pipeline, checkpointing, fault-tolerant loop, optimizer, and the
predicate-driven serving engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import constants as C
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import model as MD
from repro.models.module import split
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainConfig, make_train_step


class TestData:
    def test_deterministic_resume(self):
        p = SyntheticPipeline(DataConfig(vocab=100, seq_len=8,
                                         global_batch=4))
        a = p.batch_at(7)
        b = p.batch_at(7)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = p.batch_at(8)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))

    def test_targets_are_shifted_tokens(self):
        p = SyntheticPipeline(DataConfig(vocab=100, seq_len=8,
                                         global_batch=2))
        b = p.batch_at(0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["targets"][:, :-1]))


class TestOptim:
    def test_adamw_first_step_is_lr_sized(self):
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9)
        params = {"w": jnp.ones((4, 4))}
        grads = {"w": jnp.full((4, 4), 0.5)}
        st = adamw_init(params, cfg)
        new_p, st, mets = adamw_update(params, grads, st, cfg)
        # bias-corrected first step: delta ~ lr * sign(g)
        np.testing.assert_allclose(np.asarray(params["w"] - new_p["w"]),
                                   1e-2, rtol=1e-3)

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1.0)
        params = {"w": jnp.zeros((8,))}
        grads = {"w": jnp.full((8,), 100.0)}
        st = adamw_init(params, cfg)
        _, _, mets = adamw_update(params, grads, st, cfg)
        assert float(mets["grad_norm"]) > 1.0   # reported pre-clip

    def test_bf16_states_track_f32(self):
        k = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(k, (16, 16))}
        grads = {"w": 0.01 * jax.random.normal(k, (16, 16))}
        outs = {}
        for dt in (jnp.float32, jnp.bfloat16):
            cfg = AdamWConfig(state_dtype=dt)
            st = adamw_init(params, cfg)
            p2, _, _ = adamw_update(params, grads, st, cfg)
            outs[dt] = np.asarray(p2["w"])
        np.testing.assert_allclose(outs[jnp.float32], outs[jnp.bfloat16],
                                   atol=1e-4)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
                "b": {"c": jnp.ones((4,), jnp.float32)}}
        cm.save(10, tree, blocking=True)
        back = cm.restore(10, tree)
        np.testing.assert_array_equal(np.asarray(back["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        assert cm.latest_step() == 10

    def test_gc_keeps_last_k(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.zeros((2,))}
        for s in (1, 2, 3, 4):
            cm.save(s, tree, blocking=True)
        assert cm.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        tree = {"a": jnp.zeros((1000,))}
        cm.save(5, tree, blocking=False)
        cm.wait()
        assert cm.latest_step() == 5


class TestFaultTolerantLoop:
    def test_loop_survives_induced_failure(self, tmp_path):
        cfg = get_smoke_config("qwen2_5_32b")
        params, _ = split(MD.init_model(cfg, jax.random.PRNGKey(0)))
        ocfg = AdamWConfig(lr=1e-3)
        opt_state = adamw_init(params, ocfg)
        step_fn = jax.jit(make_train_step(cfg, ocfg))
        pipe = SyntheticPipeline.for_model(cfg, seq_len=16, global_batch=2)
        cm = CheckpointManager(tmp_path)
        fired = {"done": False}

        def fault(step):
            if step == 7 and not fired["done"]:
                fired["done"] = True
                raise RuntimeError("induced node failure")

        params, opt_state, log = train_loop(
            step_fn, params, opt_state, pipe, cm,
            LoopConfig(total_steps=12, ckpt_every=5, log_every=1),
            fault_hook=fault)
        events = [e for e in log if e.get("event") == "restored"]
        assert len(events) == 1
        steps = [e["step"] for e in log if "loss" in e]
        # steps 5 and 6 replayed after restore-to-5
        assert steps.count(5) == 2 and steps.count(6) == 2
        assert max(steps) == 11
        # loss is finite throughout and the replayed data was identical
        losses = {(e["step"], round(e["loss"], 5)) for e in log
                  if "loss" in e}
        by_step = {}
        dup_consistent = True
        for s, l in losses:
            if s in by_step and by_step[s] != l:
                dup_consistent = False
            by_step[s] = l
        assert dup_consistent    # exact replay from the stateless pipeline


class TestServingEngine:
    def _engine(self, n=4, ipp=0):
        eng = ServingEngine(n, pool_tokens=100_000, instances_per_pod=ipp)
        eng.register_chunk("case_law_42", holder=1, length=2048)
        return eng

    def test_route_at_decode(self):
        eng = self._engine()
        recs = eng.schedule_step([Request(0, home=0,
                                          chunk_ids=["case_law_42"])])
        assert len(recs) == 1 and recs[0].primitive == "route"

    def test_resident_is_free(self):
        eng = self._engine()
        recs = eng.schedule_step([Request(0, home=1,
                                          chunk_ids=["case_law_42"])])
        assert recs == []

    def test_cross_request_batching(self):
        # the §5.3 dispatcher-batching reduction: one dispatch per holder
        eng = self._engine(n=8)
        reqs = [Request(i, home=i % 4, chunk_ids=["case_law_42"], m_q=4)
                for i in range(4)]
        recs = eng.schedule_step(reqs)
        routes = [r for r in recs if r.primitive == "route"]
        assert len(routes) == 1
        assert routes[0].m_q_total == 12   # home=1 is resident (free)

    def test_fanin_cap_spawns_replica(self):
        # §6.3: beyond the N~8 elbow a replica (amortised FETCH) appears
        eng = self._engine(n=16)
        reqs = [Request(i, home=(i % 15) if (i % 15) != 1 else 2,
                        chunk_ids=["case_law_42"])
                for i in range(12)]
        recs = eng.schedule_step(reqs)
        kinds = {r.primitive for r in recs}
        assert "fetch_replica" in kinds
        assert 2 in eng.store.holders_of("case_law_42") or \
               len(eng.store.holders_of("case_law_42")) == 2

    def test_straggler_backup(self):
        eng = self._engine(n=4)
        eng.store.add_replica("case_law_42", 3)
        eng.set_straggler(1, 5.0)
        recs = eng.schedule_step([Request(0, home=0,
                                          chunk_ids=["case_law_42"])])
        assert any(r.backup for r in recs)
        # the backup caps the critical path
        assert eng.step_latency(eng.step_idx) < max(
            r.est_cost_s for r in recs if not r.backup) + 1e-12

    def test_holder_failure_rehomes(self):
        eng = self._engine(n=4)
        eng.store.add_replica("case_law_42", 2)
        orphaned = eng.fail_instance(1)
        assert orphaned == []    # replica promoted
        assert eng.store.lookup("case_law_42").holder == 2
        recs = eng.schedule_step([Request(0, home=0,
                                          chunk_ids=["case_law_42"])])
        assert all(r.holder != 1 for r in recs)

    def test_orphaned_chunk_goes_local(self):
        eng = self._engine(n=4)
        eng.fail_instance(1)     # only copy dies
        recs = eng.schedule_step([Request(0, home=0,
                                          chunk_ids=["case_law_42"])])
        assert recs[0].primitive == "local"

    def test_cross_pod_uses_dcn_probe(self):
        eng = ServingEngine(8, 100_000, instances_per_pod=4)
        eng.register_chunk("x", holder=6, length=2048)
        recs = eng.schedule_step([Request(0, home=0, chunk_ids=["x"])])
        dcn = C.fabric("tpu_dcn")
        assert recs[0].est_cost_s > dcn.t_probe_s


class TestGradCompression:
    def test_error_feedback_quantization(self):
        from repro.optim.compress import quantize, dequantize
        g = jax.random.normal(jax.random.PRNGKey(0), (128,))
        q, s = quantize(g)
        err = np.abs(np.asarray(dequantize(q, s) - g))
        assert err.max() <= float(s) * 0.5 + 1e-6
