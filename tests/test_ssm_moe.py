"""Mamba2 SSD and MoE dispatch oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as MOE
from repro.models import ssm as S
from repro.models.module import KeyGen, split


class TestSSD:
    CFG = S.Mamba2Config(d_model=64, d_state=16, head_dim=8, expand=2,
                         chunk=8)

    def _naive(self, x, dt, A, B, C):
        b, s, h, p = x.shape
        n = B.shape[-1]
        hst = np.zeros((b, h, p, n), np.float32)
        ys = []
        for t in range(s):
            a_t = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
            upd = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                            np.asarray(B[:, t]), np.asarray(x[:, t]))
            hst = hst * a_t[:, :, None, None] + upd
            ys.append(np.einsum("bn,bhpn->bhp", np.asarray(C[:, t]), hst))
        return np.stack(ys, 1), hst

    def test_chunked_equals_naive(self):
        cfg = self.CFG
        b, s, h, p, n = 2, 32, cfg.n_heads, cfg.head_dim, cfg.d_state
        k = jax.random.PRNGKey(0)
        ks = jax.random.split(k, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B = jax.random.normal(ks[3], (b, s, n))
        C = jax.random.normal(ks[4], (b, s, n))
        y, hf = S.ssd_chunked(cfg, x, dt, A, B, C)
        y_ref, h_ref = self._naive(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(hf), h_ref, atol=2e-4, rtol=1e-3)

    def test_decode_matches_forward(self):
        cfg = self.CFG
        kg = KeyGen(jax.random.PRNGKey(1))
        params, _ = split(S.init_mamba2(kg, cfg, dtype=jnp.float32))
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                                    jnp.float32)
        y_full, (h_full, _) = S.mamba2_forward(params, cfg, x)
        # replay token-by-token through the decode recurrence
        h = jnp.zeros((2, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32)
        conv = jnp.zeros((2, cfg.d_conv - 1, cfg.d_inner + 2 * cfg.d_state),
                         jnp.float32)
        outs = []
        state = (h, conv)
        for t in range(16):
            y_t, state = S.mamba2_decode(params, cfg, x[:, t:t + 1], state)
            outs.append(y_t)
        y_dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                                   atol=3e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(state[0]), np.asarray(h_full),
                                   atol=3e-4, rtol=1e-3)

    def test_state_carry_across_segments(self):
        # prefill in two segments == one pass (the SSM state handoff that
        # replaces chunk routing for this family, DESIGN.md §4)
        cfg = self.CFG
        kg = KeyGen(jax.random.PRNGKey(3))
        params, _ = split(S.init_mamba2(kg, cfg, dtype=jnp.float32))
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (1, 32, cfg.d_model),
                                    jnp.float32)
        y_full, (h_full, _) = S.mamba2_forward(params, cfg, x)
        y1, (h1, conv1) = S.mamba2_forward(params, cfg, x[:, :16])
        y2, (h2, _) = S.mamba2_forward(params, cfg, x[:, 16:], h0=h1,
                                       conv_state=conv1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), atol=3e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                                   atol=3e-4, rtol=1e-3)


class TestMoE:
    CFG = MOE.MoEConfig(d_model=32, d_expert=64, n_experts=8, top_k=2,
                        n_shared=1, capacity_factor=8.0)  # no drops

    def _dense_ref(self, p, cfg, x):
        """Reference: every expert on every token, weighted by router."""
        xt = x.reshape(-1, x.shape[-1])
        logits = xt.astype(jnp.float32) @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / w.sum(-1, keepdims=True)
        y = jnp.zeros_like(xt, dtype=jnp.float32)
        for e in range(cfg.n_experts):
            h = jax.nn.silu(xt @ p["gate"][e]) * (xt @ p["up"][e])
            oe = (h @ p["down"][e]).astype(jnp.float32)
            we = jnp.sum(jnp.where(idx == e, w, 0.0), -1)
            y = y + oe * we[:, None]
        if cfg.n_shared:
            h = jax.nn.silu(xt @ p["sh_gate"]) * (xt @ p["sh_up"])
            y = y + (h @ p["sh_down"]).astype(jnp.float32)
        return y.reshape(x.shape)

    def test_sorted_dispatch_matches_dense(self):
        cfg = self.CFG
        kg = KeyGen(jax.random.PRNGKey(0))
        params, _ = split(MOE.init_moe(kg, cfg, dtype=jnp.float32))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                              jnp.float32)
        y, aux = MOE.moe_apply(params, cfg, x)
        ref = self._dense_ref(params, cfg, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-4, rtol=1e-3)
        assert np.isfinite(float(aux))

    def test_capacity_drops_are_bounded(self):
        # with tight capacity some tokens drop — output stays finite and
        # close-ish to the dense ref (lost tokens only)
        cfg = MOE.MoEConfig(d_model=32, d_expert=64, n_experts=8, top_k=2,
                            capacity_factor=1.0)
        kg = KeyGen(jax.random.PRNGKey(2))
        params, _ = split(MOE.init_moe(kg, cfg, dtype=jnp.float32))
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, cfg.d_model),
                              jnp.float32)
        y, _ = MOE.moe_apply(params, cfg, x)
        assert np.all(np.isfinite(np.asarray(y)))
