"""Per-kernel validation (task spec): shape/dtype sweeps + hypothesis
property tests, assert_allclose against the ref.py pure-jnp oracles.
All kernels run in interpret mode on CPU (TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.merge import merge_stacked
from repro.kernels.delta_rotate import delta_rotate_band, delta_rotate_ref
from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
from repro.kernels.mla_decode import mla_decode, mla_decode_ref
from repro.kernels.softmax_merge import softmax_merge, softmax_merge_ref
from repro.kernels.sparse_select import (sparse_select_decode,
                                         sparse_select_ref)

SCALE = 1.0 / np.sqrt(192.0)


def _qc(key, B, H, S, D=64, d_v=48, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    q = jax.random.normal(k1, (B, H, D), dtype)
    ckv = jax.random.normal(k2, (B, S, D), dtype)
    return q, ckv


class TestMlaDecode:
    @pytest.mark.parametrize("B,H,S,bs", [(1, 4, 128, 64), (2, 16, 256, 128),
                                          (3, 8, 512, 512), (2, 128, 256, 64)])
    def test_shapes_sweep(self, B, H, S, bs):
        q, ckv = _qc(B * 1000 + S, B, H, S)
        got = mla_decode(q, ckv, d_v=48, scale=SCALE, block_s=bs)
        o, m, l = mla_decode_ref(q, ckv, 48, SCALE)
        np.testing.assert_allclose(np.asarray(got.o), np.asarray(o),
                                   atol=2e-6, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(got.l), np.asarray(l),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(got.m), np.asarray(m))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, ckv = _qc(7, 2, 8, 256, dtype=dtype)
        got = mla_decode(q, ckv, d_v=48, scale=SCALE)
        o, m, l = mla_decode_ref(q, ckv, 48, SCALE)
        atol = 2e-6 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got.o), np.asarray(o),
                                   atol=atol)

    def test_ragged_lengths(self, ):
        # residency mask: each batch row has its own valid cache length
        q, ckv = _qc(11, 3, 4, 256)
        lengths = jnp.asarray([64, 192, 256], jnp.int32)
        got = mla_decode(q, ckv, lengths, d_v=48, scale=SCALE, block_s=64)
        for b in range(3):
            o, m, l = mla_decode_ref(q[b:b+1], ckv[b:b+1, :int(lengths[b])],
                                     48, SCALE)
            np.testing.assert_allclose(np.asarray(got.o[b:b+1]),
                                       np.asarray(o), atol=2e-6, rtol=1e-5)

    def test_paper_payload_geometry(self):
        # the real wire geometry: d_qk=576, d_v=512, h=16 (V2-Lite)
        q, ckv = _qc(13, 2, 16, 512, D=576, d_v=512)
        got = mla_decode(q, ckv, d_v=512, scale=SCALE)
        o, m, l = mla_decode_ref(q, ckv, 512, SCALE)
        np.testing.assert_allclose(np.asarray(got.o), np.asarray(o),
                                   atol=5e-6, rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 8), st.integers(1, 8))
    def test_property_random_shapes(self, B, H, nblk):
        S = 64 * nblk
        q, ckv = _qc(B * 100 + H * 10 + nblk, B, H, S)
        got = mla_decode(q, ckv, d_v=48, scale=SCALE, block_s=64)
        o, m, l = mla_decode_ref(q, ckv, 48, SCALE)
        np.testing.assert_allclose(np.asarray(got.o), np.asarray(o),
                                   atol=2e-6, rtol=1e-5)


class TestSparseSelect:
    @pytest.mark.parametrize("B,H,S,KB", [(1, 4, 512, 4), (2, 16, 1024, 8),
                                          (2, 128, 2048, 32)])
    def test_shapes_sweep(self, B, H, S, KB):
        q, ckv = _qc(B * 31 + KB, B, H, S)
        rng = np.random.RandomState(B + KB)
        idx = jnp.asarray(
            np.stack([np.sort(rng.choice(S // 64, KB, replace=False))
                      for _ in range(B)]))
        got = sparse_select_decode(q, ckv, idx, d_v=48, scale=SCALE)
        o, m, l = sparse_select_ref(q, ckv, idx, 48, 64, SCALE)
        np.testing.assert_allclose(np.asarray(got.o), np.asarray(o),
                                   atol=2e-6, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(got.l), np.asarray(l),
                                   rtol=1e-5)

    def test_selection_budget_invariance(self):
        # §6.3: cost tracks the selection budget, not the store size —
        # and the result only depends on the selected entries.
        B, H, KB = 1, 4, 4
        q, ckv_small = _qc(17, B, H, 512)
        pad = jax.random.normal(jax.random.PRNGKey(99), (B, 1536, 64))
        ckv_big = jnp.concatenate([ckv_small, pad], axis=1)
        idx = jnp.asarray([[0, 2, 5, 7]])
        a = sparse_select_decode(q, ckv_small, idx, d_v=48, scale=SCALE)
        b = sparse_select_decode(q, ckv_big, idx, d_v=48, scale=SCALE)
        np.testing.assert_allclose(np.asarray(a.o), np.asarray(b.o),
                                   atol=1e-6)

    def test_matches_dense_over_selected_set(self):
        # kernel == dense decode over the gathered selection (§3.3)
        q, ckv = _qc(23, 2, 8, 512)
        idx = jnp.asarray([[1, 3], [0, 7]])
        got = sparse_select_decode(q, ckv, idx, d_v=48, scale=SCALE)
        for b in range(2):
            blocks = ckv[b].reshape(-1, 64, 64)
            sel = blocks[np.asarray(idx[b])].reshape(1, -1, 64)
            o, m, l = mla_decode_ref(q[b:b+1], sel, 48, SCALE)
            np.testing.assert_allclose(np.asarray(got.o[b:b+1]),
                                       np.asarray(o), atol=2e-6, rtol=1e-5)


class TestDeltaRotate:
    @pytest.mark.parametrize("S,d_r", [(128, 16), (1024, 64), (2048, 64)])
    def test_matches_ref(self, S, d_r):
        band = jax.random.normal(jax.random.PRNGKey(S), (S, d_r))
        for delta in (0, 1, 1000):
            got = delta_rotate_band(band, jnp.float32(delta), head_dim=d_r)
            ref = delta_rotate_ref(band, jnp.float32(delta), d_r)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-5)

    def test_splice_correctness_via_kernel(self):
        # end-to-end: core.splice with the Pallas rotate_fn re-homes exactly
        from repro.core.splice import splice_delta_rotate
        from repro.models import mla as M
        from repro.models.module import KeyGen, split
        cfg = M.MLAConfig(d_model=128, n_heads=4, kv_lora_rank=32,
                          qk_nope_head_dim=16, qk_rope_head_dim=16,
                          v_head_dim=16)
        params, _ = split(M.init_mla(KeyGen(jax.random.PRNGKey(0)), cfg,
                                     dtype=jnp.float32))
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 64, 128))
        pos = jnp.arange(64)[None]
        cached = M.latent_cache_entries(params, cfg, x, pos)
        rot = lambda band, d: delta_rotate_band(
            band[0], jnp.float32(d), head_dim=cfg.qk_rope_head_dim)[None]
        spliced = splice_delta_rotate(cached, 77, cfg, rotate_fn=rot)
        native = M.latent_cache_entries(params, cfg, x, pos + 77)
        np.testing.assert_allclose(np.asarray(spliced), np.asarray(native),
                                   atol=2e-5)


class TestSoftmaxMerge:
    @pytest.mark.parametrize("M,B,H,dv", [(2, 1, 4, 32), (8, 3, 16, 64),
                                          (16, 2, 8, 128)])
    def test_matches_ref(self, M, B, H, dv):
        k = jax.random.PRNGKey(M * 100 + B)
        ks = jax.random.split(k, 3)
        o = jax.random.normal(ks[0], (M, B, H, dv))
        m = jax.random.normal(ks[1], (M, B, H))
        l = jax.nn.softplus(jax.random.normal(ks[2], (M, B, H))) + 0.1
        got = softmax_merge(o, m, l)
        ref = softmax_merge_ref(o, m, l)
        np.testing.assert_allclose(np.asarray(got.o), np.asarray(ref.o),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(got.l), np.asarray(ref.l),
                                   rtol=1e-6)

    def test_identity_slots(self):
        # zero-weight identity partials (empty holders) are no-ops (§3.3)
        o = jnp.stack([jnp.ones((1, 2, 4)), jnp.zeros((1, 2, 4))])
        m = jnp.stack([jnp.zeros((1, 2)), jnp.full((1, 2), -jnp.inf)])
        l = jnp.stack([jnp.ones((1, 2)), jnp.zeros((1, 2))])
        got = softmax_merge(o, m, l)
        np.testing.assert_allclose(np.asarray(got.o), 1.0)
        np.testing.assert_allclose(np.asarray(got.l), 1.0)

    def test_kernel_equals_routed_oracle(self):
        # merge(kernel partials from disjoint shards) == full attention
        q, ckv = _qc(29, 2, 8, 512)
        p1 = mla_decode(q, ckv[:, :256], d_v=48, scale=SCALE, block_s=64)
        p2 = mla_decode(q, ckv[:, 256:], d_v=48, scale=SCALE, block_s=64)
        merged = softmax_merge(jnp.stack([p1.o, p2.o]),
                               jnp.stack([p1.m, p2.m]),
                               jnp.stack([p1.l, p2.l]))
        o, m, l = mla_decode_ref(q, ckv, 48, SCALE)
        np.testing.assert_allclose(np.asarray(merged.o), np.asarray(o),
                                   atol=2e-6, rtol=1e-5)


class TestFlashPrefill:
    @pytest.mark.parametrize("B,Sq,Sk,H", [(1, 64, 64, 2), (2, 128, 256, 4),
                                           (1, 256, 256, 8)])
    def test_causal_matches_ref(self, B, Sq, Sk, H):
        k1, k2 = jax.random.split(jax.random.PRNGKey(Sq + Sk))
        q = jax.random.normal(k1, (B, Sq, H, 64))
        ckv = jax.random.normal(k2, (B, Sk, 64))
        got = flash_prefill(q, ckv, d_v=48, scale=SCALE, block_q=64,
                            block_k=64)
        ref = flash_prefill_ref(q, ckv, 48, SCALE)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=3e-6, rtol=1e-5)

    def test_block_shape_invariance(self):
        # tiling must not change the math
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64))
        ckv = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 64))
        outs = [flash_prefill(q, ckv, d_v=48, scale=SCALE, block_q=bq,
                              block_k=bk)
                for bq, bk in ((64, 64), (128, 256), (256, 128))]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=2e-6, rtol=1e-5)
