"""Validate the cost model + predicate against the paper's own headline
numbers (§4.3, §5.1, §5.2, §7, §8). These are the reproduction's ground truth:
the closed form with measured constants must reproduce every number the paper
reports from it."""

import numpy as np
import pytest

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core import predicate as P


IBGDA = C.fabric("h100_ibgda")


class TestPayload:
    def test_mla_payload_bytes(self):
        # §3.2: q = 576*2 = 1152 B, p = 512*2 + 2*4 = 1032 B.
        assert cm.MLA_PAYLOAD.q_bytes == 1152
        assert cm.MLA_PAYLOAD.p_bytes == 1032
        assert cm.MLA_PAYLOAD.qp_bytes == 2184

    def test_payload_from_dims(self):
        p = cm.payload_for(d_qk=576, d_v=512, n_layers=27)
        assert p == cm.MLA_PAYLOAD

    def test_all_layer_chunk_bytes(self):
        # §5.4: ~64 MB at top-2048, L=27.
        assert 60e6 < cm.fetch_wire_bytes(2048, all_layers=True) < 68e6


class TestRouteCost:
    def test_route_116us_at_1024(self):
        # §4.3: ~116 us measured at M_q=1024; model 16 + M_q(q+p)/BW ~ 105,
        # +9 us turnaround -> ~114.5.
        t = cm.t_route_transport(IBGDA, 1024, include_launch=True)
        assert t == pytest.approx(116e-6, rel=0.05)

    def test_route_388us_at_4096(self):
        # §7: ~388 us at M_q=4096.
        t = cm.t_route_transport(IBGDA, 4096, include_launch=True)
        assert t == pytest.approx(388e-6, rel=0.05)

    def test_probe_floor_small_mq(self):
        # §7: T_route holds near its ~16 us probe floor for M_q <= 128.
        t = cm.t_route_transport(IBGDA, 128)
        assert t < 2.5 * IBGDA.t_probe_s

    def test_route_26x_cheaper_than_splice_at_1024(self):
        # §4.3: ~26x cheaper than the ~3 ms splice at M_q=1024, ~125x at M_q=1.
        ratio = cm.t_splice(2048) / cm.t_route_transport(IBGDA, 1024,
                                                         include_launch=True)
        assert ratio == pytest.approx(26, rel=0.10)
        ratio1 = cm.t_splice(2048) / cm.t_route_transport(IBGDA, 1,
                                                          include_launch=True)
        assert 100 < ratio1 < 150

    def test_decode_point_five_fabrics_cluster(self):
        # §8/Fig 6b: at M_q=256 the five fabrics cluster within 1.5x, ~31-48us.
        names = ["h100_ibgda", "h100_nvlink4", "a100_nvlink3",
                 "rtx6000_pcie5", "a40_pcie4"]
        ts = [cm.t_route_transport(C.fabric(n), 256, include_launch=True)
              for n in names]
        assert max(ts) / min(ts) < 1.5
        assert 25e-6 < min(ts) and max(ts) < 55e-6


class TestFetchLocal:
    def test_splice_flat_in_chunk_size(self):
        # §7: 2.77/2.78/2.91/3.06 ms at c_t=55/1024/2048/4096; ~10% growth.
        s = [cm.t_splice(ct) for ct in (55, 1024, 2048, 4096)]
        measured = [2.77e-3, 2.78e-3, 2.91e-3, 3.06e-3]
        assert cm.mape(s, measured) < 0.03
        assert s[-1] / s[0] < 1.15

    def test_pull_2_5ms_at_2048(self):
        # §2.2: all-layer pull ~2.5 ms at 25 GB/s.
        assert cm.t_pull(IBGDA, 2048) == pytest.approx(2.5e-3, rel=0.05)

    def test_fetch_local_crossover_band(self):
        # §5.1: local overtakes fetch only above ~75-220 tokens.
        lo, hi = P.fetch_local_crossover_ct(IBGDA)
        assert 60 <= lo <= 90
        assert 180 <= hi <= 240

    def test_prefix_elides_splice(self):
        # §6.3: true-prefix re-home (delta=0) pays pull only.
        full = cm.t_fetch(IBGDA, 2048, contiguous=True)
        prefix = cm.t_fetch(IBGDA, 2048, contiguous=False)
        assert full - prefix == pytest.approx(cm.t_splice(2048))


class TestWireBytes:
    def test_byte_breakeven_1080_at_2048(self):
        # §5.2/§5.4: break-even ~1080 rows at c_t=2048, ~270 at top-512.
        assert cm.byte_breakeven_mq(2048) == pytest.approx(1080, abs=2)
        assert cm.byte_breakeven_mq(512) == pytest.approx(270, abs=1)

    def test_76pct_fewer_bytes_at_256(self):
        # §5.2: >= 76% fewer wire bytes at M_q=256, c_t=2048.
        saved = 1 - (cm.route_wire_bytes(256)
                     / cm.fetch_wire_bytes(2048))
        assert saved >= 0.76

    def test_v4_flash_breakeven_above_decode_batch(self):
        # §5.4: even top-512 break-even (~270) stays above a decode batch (256).
        assert cm.byte_breakeven_mq(C.SELECTION_BUDGETS["deepseek_v4_flash"]) > 256


class TestCongestion:
    def test_flat_through_k2(self):
        for mq in (256, 1024):
            t0 = cm.t_route_congested(IBGDA, mq, 0)
            t2 = cm.t_route_congested(IBGDA, mq, 2)
            assert t2 == pytest.approx(t0, rel=0.01)

    def test_k3_rise_119pct_at_1024(self):
        # §8: M_q=1024 114 -> 250 us (+119%) at K=3.
        t0 = cm.t_route_congested(IBGDA, 1024, 0)
        t3 = cm.t_route_congested(IBGDA, 1024, 3)
        assert t3 / t0 == pytest.approx(2.19, rel=0.15)

    def test_congested_still_12x_below_splice(self):
        # §8: even fully congested, M_q=1024 stays ~12x below the splice.
        t3 = cm.t_route_congested(IBGDA, 1024, 3)
        assert cm.t_splice(2048) / t3 > 10


class TestAffineFit:
    def test_refit_recovers_constants(self):
        mqs = [512, 1024, 2048, 4096]
        rts = [cm.t_route_transport(IBGDA, m) for m in mqs]
        fit = cm.fit_affine(mqs, rts)
        assert fit.t_probe_s == pytest.approx(IBGDA.t_probe_s, rel=1e-6)
        assert fit.bw_Bps == pytest.approx(IBGDA.bw_Bps, rel=1e-6)

    def test_mape_7pct_with_turnaround_residual(self):
        # §4.3: the no-refit model tracks measurements (which include a fixed
        # ~9us turnaround) to ~7% MAPE for M_q >= 512, ~3% for M_q >= 2048.
        mqs = [512, 1024, 2048, 4096]
        measured = [cm.t_route_transport(IBGDA, m, include_launch=True)
                    for m in mqs]
        pred = [cm.t_route_transport(IBGDA, m) for m in mqs]
        assert cm.mape(pred, measured) < 0.07
        assert cm.mape(pred[2:], measured[2:]) < 0.04   # "~3%" for M_q>=2048


class TestPredicate:
    def _req(self, **kw):
        kw.setdefault("m_q", 256)
        kw.setdefault("c_t", 2048)
        kw.setdefault("fabric", IBGDA)
        return P.Request(**kw)

    def test_default_route_at_decode(self):
        # §5.5 rule 1: default to ROUTE at decode.
        d = P.decide(self._req(m_q=256))
        assert d.primitive is P.Primitive.ROUTE
        assert d.t_route < d.t_fetch / 10 and d.t_route < d.t_local / 10

    def test_local_for_tiny_chunks(self):
        # §5.5 rule 3: LOCAL only for small chunks — vs FETCH. (Route is
        # excluded: no holder can compute, e.g. disaggregated byte store.)
        d = P.decide(self._req(c_t=30, holder_can_compute=False))
        assert d.primitive is P.Primitive.LOCAL
        d2 = P.decide(self._req(c_t=4096, holder_can_compute=False))
        assert d2.primitive is P.Primitive.FETCH

    def test_fetch_when_amortised(self):
        # §5.5 rule 2: FETCH only to amortise over many local steps.
        d = P.decide(self._req(expected_reuse_steps=100_000, m_q=1))
        assert d.primitive is P.Primitive.FETCH

    def test_route_wins_selection_regime_multiholder(self):
        # §5.4: scattered selection, multi-holder: route stays flat.
        d = P.decide(self._req(k_selected=2048, n_holders=7))
        assert d.primitive is P.Primitive.ROUTE
        # fetch (scattered gather) grows with holders
        d1 = P.decide(self._req(k_selected=2048, n_holders=1))
        assert d.t_fetch > d1.t_fetch * 2

    def test_host_overhead_flips_decode_to_fetch(self):
        # §5.3: at the prototype's host overhead, a *splice-free* bytes-back
        # fetch wins at decode despite route's wire-byte advantage; the three
        # transport reductions (host_overhead=False, our in-graph transport)
        # convert the wire-byte win into the end-to-end win.
        d_host = P.decide(self._req(m_q=256, position_delta=0,
                                    host_overhead=True))
        assert d_host.primitive is P.Primitive.FETCH
        d_reduced = P.decide(self._req(m_q=256, position_delta=0,
                                       host_overhead=False))
        assert d_reduced.primitive is P.Primitive.ROUTE
        # The splice tax is a property of the operation, not the transport:
        # the *semantic* (move-and-adapt) fetch still loses even at host
        # overhead once M_q is large enough to amortise it... but at decode
        # scale it loses by the splice regardless of host regime.
        d_semantic = P.decide(self._req(m_q=256, position_delta=1,
                                        host_overhead=True))
        assert d_semantic.t_fetch > d_host.t_fetch

    def test_fanout_cap_and_replication(self):
        assert P.holder_fanout_cap() == 8
        assert not P.replication_threshold(8)
        assert P.replication_threshold(9)


class TestTPUFabrics:
    def test_ici_route_cheaper_than_dcn(self):
        t_ici = cm.t_route_transport(C.fabric("tpu_ici"), 256)
        t_dcn = cm.t_route_transport(C.fabric("tpu_dcn"), 256)
        assert t_ici < t_dcn

    def test_route_beats_fetch_on_both_tpu_fabrics(self):
        for f in ("tpu_ici", "tpu_dcn"):
            d = P.decide(P.Request(m_q=256, c_t=2048, fabric=C.fabric(f)))
            assert d.primitive is P.Primitive.ROUTE
