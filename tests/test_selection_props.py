"""Property tests for the selection subsystem (ISSUE 4), alongside the
timeline property suite:

  * residency_split / selection_mask round-trips — the union of per-holder
    local masks IS the global mask: no index lost or duplicated at shard
    boundaries (§5.4: the distributed selection covers the chosen set
    exactly once);
  * token_mask / block round-trips at NSA granularity, partial tail
    included;
  * padded topk_blocks == brute force over per-block maxima (the
    S % block_tokens bugfix: the tail block competes);
  * distributed local-top-k + merge == global ranking (the service's
    top-k merge theorem), for any shard split and truncation budget.

Randomized via hypothesis (dev-only; the module skips without it)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import selection as SEL  # noqa: E402
from repro.serving.selection.types import token_mask  # noqa: E402


@st.composite
def split_indices(draw):
    """A global index set + shard bounds partitioning [0, S)."""
    s = draw(st.integers(8, 256))
    n_sel = draw(st.integers(0, min(s, 32)))
    idx = draw(st.lists(st.integers(0, s - 1), min_size=n_sel,
                        max_size=n_sel, unique=True))
    n_shards = draw(st.integers(1, 5))
    cuts = draw(st.lists(st.integers(0, s), min_size=n_shards - 1,
                         max_size=n_shards - 1))
    bounds = [0] + sorted(cuts) + [s]
    return sorted(idx), bounds


@given(split_indices())
@settings(max_examples=120, deadline=None)
def test_residency_split_roundtrip(case):
    """Union of per-holder local masks == global mask; counts preserved;
    nothing lost or duplicated at shard boundaries."""
    idx, bounds = case
    masks = SEL.residency_split(np.asarray(idx, np.int64), bounds)
    assert [len(m) for m in masks] == \
        [bounds[j + 1] - bounds[j] for j in range(len(bounds) - 1)]
    recon = np.concatenate(masks)
    want = np.zeros(bounds[-1], bool)
    if idx:
        want[np.asarray(idx, np.int64)] = True
    np.testing.assert_array_equal(recon, want)
    assert sum(int(m.sum()) for m in masks) == len(idx)


@given(split_indices())
@settings(max_examples=60, deadline=None)
def test_residency_split_agrees_with_selection_mask(case):
    """The jax selection_mask over the global indices equals the
    concatenated residency_split masks."""
    idx, bounds = case
    if not idx:
        return
    global_mask = np.asarray(
        SEL.selection_mask(jnp.asarray([idx]), bounds[-1]))[0]
    masks = SEL.residency_split(np.asarray(idx, np.int64), bounds)
    np.testing.assert_array_equal(np.concatenate(masks), global_mask)


@given(st.integers(1, 300), st.sampled_from([1, 4, 64]),
       st.data())
@settings(max_examples=80, deadline=None)
def test_token_mask_block_roundtrip(length, bt, data):
    """blocks -> token mask -> blocks recovers exactly (partial tail
    truncated, never widened)."""
    n_blocks = -(-length // bt)
    blocks = data.draw(st.lists(st.integers(0, n_blocks - 1),
                                max_size=n_blocks, unique=True))
    mask = token_mask(blocks, bt, length)
    assert mask.shape == (length,)
    got = sorted(int(b) for b in np.unique(np.nonzero(mask)[0] // bt))
    assert got == sorted(blocks)


@given(st.integers(5, 200), st.sampled_from([4, 8, 64]), st.integers(1, 6),
       st.data())
@settings(max_examples=80, deadline=None)
def test_padded_topk_blocks_matches_bruteforce(s, bt, k, data):
    """topk_blocks (jax, padded) picks exactly the blocks with the largest
    per-block maxima — including a partial tail block (pre-fix, the tail
    could never win)."""
    scores = np.asarray(
        data.draw(st.lists(st.floats(-1e3, 1e3, allow_nan=False,
                                     width=32),
                           min_size=s, max_size=s)), np.float32)
    # unique block maxima so the top-k set is unambiguous
    bs = SEL.block_scores(scores, bt)
    if len(np.unique(bs)) != len(bs):
        return
    n_blocks = len(bs)
    kk = min(k, n_blocks)
    got = sorted(np.asarray(SEL.topk_blocks(jnp.asarray(scores), bt, k)))
    want = sorted(np.argsort(-bs)[:kk])
    assert got == [int(b) for b in want]
    # and the mask agrees on the padded length
    mask = np.asarray(SEL.block_mask_to_tokens(
        jnp.asarray([got]), bt, s))[0]
    assert mask.shape == (s,)
    assert int(mask.sum()) == sum(min(bt, s - b * bt) for b in got)


@given(st.integers(1, 4), st.integers(1, 8), st.data())
@settings(max_examples=60, deadline=None)
def test_distributed_topk_merge_equals_global(n_shards, k_blocks, data):
    """Per-shard truncated top-k + total-order merge == global ranking of
    every (shard, block) candidate — the IndexerService merge theorem, on
    arbitrary score tables."""
    shards = []
    for pos in range(n_shards):
        nb = data.draw(st.integers(1, 8))
        shards.append(np.asarray(
            data.draw(st.lists(st.floats(-1e3, 1e3, allow_nan=False,
                                         width=32),
                               min_size=nb, max_size=nb)), np.float32))
    # strict total order key: (-score, shard, block) — ties cannot diverge
    all_cands = sorted((-float(s), pos, b)
                       for pos, bs in enumerate(shards)
                       for b, s in enumerate(bs))
    want = all_cands[:k_blocks]
    local = []
    for pos, bs in enumerate(shards):
        order = np.lexsort((np.arange(len(bs)), -bs))[:k_blocks]
        local.extend((-float(bs[b]), pos, int(b)) for b in order)
    got = sorted(local)[:k_blocks]
    assert got == want
